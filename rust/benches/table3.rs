//! Table III regeneration: oASIS-P vs distributed uniform random on data
//! too large for a single node — Two Moons (paper: 10⁶ points) and
//! Tiny-Images-like (paper: 10⁶ and 4·10⁶ one-channel 32×32 images),
//! sharded over worker threads standing in for the paper's 16 MPI nodes.
//!
//! Reported per method: sampled-entry error, end-to-end select+form wall
//! time, and (for oASIS-P) communication volume. The random baseline pays
//! the ℓ×ℓ pseudo-inverse the paper calls out (no iterative W⁻¹), which is
//! what makes it *slower* end-to-end at large ℓ despite O(1) selection.
//!
//! Default scale runs at ~5–10% of paper size; OASIS_BENCH_SCALE raises it.
//!
//!     cargo bench --bench table3

use oasis::bench_support::curves::scaled;
use oasis::coordinator::{run_oasis_p, OasisPConfig};
use oasis::data::generators::{tiny_images_like, two_moons};
use oasis::data::Dataset;
use oasis::kernels::{Gaussian, Kernel};
use oasis::linalg::pinv_psd;
use oasis::nystrom::{sampled_relative_error, NystromApprox};
use oasis::sampling::ImplicitOracle;
use oasis::util::rng::Pcg64;
use oasis::util::table::{sci, Table};
use oasis::util::timing::{fmt_bytes, Stopwatch};
use std::sync::Arc;

struct Problem {
    name: &'static str,
    ds: Dataset,
    l: usize,
    sigma: f64,
}

fn problems() -> Vec<Problem> {
    vec![
        Problem {
            // paper: 1,000,000 × 2, ℓ = 1,000, σ = 0.5·√3
            name: "Two Moons",
            ds: two_moons(scaled(1_000_000, 5_000) / 10, 0.05, 1),
            l: scaled(1_000, 50) / 2,
            sigma: 0.5 * 3f64.sqrt(),
        },
        Problem {
            // paper: 1,000,000 × 1024, ℓ = 4,500, σ = 20; scaled to 16×16
            // images to keep the kernel evaluations tractable here
            name: "Tiny Images",
            ds: tiny_images_like(scaled(1_000_000, 2_000) / 25, 16, 2),
            l: scaled(4_500, 50) / 15,
            sigma: 20.0 * (256.0 / 1024.0f64).sqrt(), // rescale σ for dim
        },
    ]
}

fn main() {
    let workers = 8; // stand-in for the paper's 16 nodes / 192 cores
    let samples = 100_000;
    println!(
        "Table III — distributed implicit kernels, {workers} workers (scale {}×)\n",
        oasis::bench_support::curves::bench_scale()
    );
    let mut table = Table::new(&[
        "Problem", "n", "ℓ", "oASIS-P err (s)", "Random err (s)", "oASIS-P comm",
    ]);
    for p in problems() {
        let n = p.ds.n();
        let l = p.l.min(n);
        let gk = Gaussian::new(p.sigma);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(p.sigma));
        let oracle = ImplicitOracle::new(&p.ds, &gk);

        // --- oASIS-P (tolerance 1e-4 like the paper's Two Moons run) ---
        let cfg = OasisPConfig::new(l, 10.min(l), workers)
            .with_seed(7)
            .with_tol(1e-4);
        let (approx, report) = run_oasis_p(&p.ds, kernel, &cfg).expect("oasis-p");
        let e_oasis = sampled_relative_error(&oracle, &approx, samples, 11);
        let oasis_cell = format!("{} ({:.1})", sci(e_oasis), report.wall_secs);
        let comm = format!(
            "{}↓ {}↑",
            fmt_bytes(report.metrics.broadcast_bytes()),
            fmt_bytes(report.metrics.gather_bytes())
        );

        // --- distributed uniform random: same ℓ as oASIS-P actually used;
        //     forming columns threaded over "nodes", then the W⁺ cost ---
        let k = approx.k();
        let sw = Stopwatch::start();
        let order = Pcg64::new(7).sample_without_replacement(n, k);
        let mut c = oasis::linalg::Mat::zeros(n, k);
        oasis::util::parallel::for_each_chunk_mut(
            &mut c.data,
            k,
            workers,
            |range, chunk| {
                for (local, i) in range.clone().enumerate() {
                    let zi = p.ds.point(i);
                    for (t, &j) in order.iter().enumerate() {
                        chunk[local * k + t] = gk.eval(zi, p.ds.point(j));
                    }
                }
            },
        );
        let w = c.select_rows(&order);
        let winv = pinv_psd(&w, 1e-12); // W⁺: the step with no iterative form
        let secs_rand = sw.secs();
        let rand = NystromApprox {
            indices: order,
            c,
            winv,
            selection_secs: secs_rand,
        };
        let e_rand = sampled_relative_error(&oracle, &rand, samples, 11);
        let rand_cell = format!("{} ({:.1})", sci(e_rand), secs_rand);

        table.row(vec![
            p.name.to_string(),
            n.to_string(),
            k.to_string(),
            oasis_cell,
            rand_cell,
            comm,
        ]);
    }
    table.print();
    println!(
        "\npaper shape check: on clustered data oASIS-P reaches ~1% of random's\n\
         error at equal ℓ; its per-step communication is a single data point\n\
         (volume independent of n); random's end-to-end time is dominated by\n\
         forming columns plus the ℓ×ℓ pseudo-inverse that cannot use Eq. 5."
    );
}
