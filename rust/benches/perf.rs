//! §Perf microbenchmarks: the per-iteration hot paths of oASIS across the
//! three layers, used for the EXPERIMENTS.md §Perf iteration log.
//!
//!   L3 native : Δ colsum (PaperR) vs incremental Δ update; rank-1 R
//!               update; kernel column generation; end-to-end per-column
//!               selection throughput for both variants.
//!   Methods   : per-method wall-ms / k / est. error on one workload
//!               (the CI bench-smoke trajectory, written to --json).
//!   Tasks     : per-method downstream quality — KRR held-out error and
//!               spectral-clustering accuracy on labeled two-moons (the
//!               BENCH_*.json downstream-accuracy trajectory).
//!   Runtime   : PJRT delta artifact execution vs native Δ sweep.
//!
//!     cargo bench --bench perf                         # full sizes
//!     cargo bench --bench perf -- --quick --json BENCH_ci.json
//!
//! `--quick` shrinks problem sizes and repetitions to CI scale;
//! `--json PATH` additionally writes every result as one JSON document
//! (`{"micro": […], "methods": […], "tasks": […]}`) for the workflow
//! artifact.

use oasis::bench_support::{bench, BenchConfig, BenchResult};
use oasis::data::generators::two_moons;
use oasis::kernels::{kernel_column_into, Gaussian};
use oasis::nystrom::relative_frobenius_error;
use oasis::runtime::Accel;
use oasis::sampling::{
    adaptive_random::AdaptiveRandom,
    farahat::Farahat,
    icd::IncompleteCholesky,
    oasis::{Oasis, Variant},
    sis::Sis,
    ColumnSampler, ImplicitOracle,
};
use oasis::seed::permutation_accuracy;
use oasis::tasks::{FittedTask, TaskConfig, TaskKind, TaskPrediction};
use oasis::util::args::Args;
use oasis::util::json::Json;

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let cfg = BenchConfig {
        warmup: if quick { 0 } else { 1 },
        reps: if quick { 2 } else { 5 },
    };
    let n = args.usize_or("n", if quick { 4_000 } else { 20_000 });
    let k = args.usize_or("k", if quick { 64 } else { 256 });
    let ds = two_moons(n, 0.05, 3);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let mut micro: Vec<BenchResult> = Vec::new();
    let record = |micro: &mut Vec<BenchResult>, res: BenchResult| {
        println!("{}", res.report());
        micro.push(res);
    };

    println!("== L3 hot-path microbenches (n={n}, k={k}) ==");

    // Δ colsum sweep: d − Σ_t c_t∘r_t over live k rows
    let c = vec![0.5f64; k * n];
    let r = vec![0.25f64; k * n];
    let d = vec![1.0f64; n];
    let mut delta = vec![0.0f64; n];
    let res = bench("delta_colsum strided (i-outer, before)", &cfg, || {
        for i in 0..n {
            let mut acc = 0.0;
            for t in 0..k {
                acc += c[t * n + i] * r[t * n + i];
            }
            delta[i] = d[i] - acc;
        }
        delta[0]
    });
    record(&mut micro, res);

    // the shipped streaming version (t-outer, sequential reads)
    let res = bench("delta_colsum streaming (t-outer, after)", &cfg, || {
        delta.copy_from_slice(&d);
        for t in 0..k {
            let ct = &c[t * n..(t + 1) * n];
            let rt = &r[t * n..(t + 1) * n];
            for ((o, &cv), &rv) in delta.iter_mut().zip(ct).zip(rt) {
                *o -= cv * rv;
            }
        }
        delta[0]
    });
    record(&mut micro, res);

    // incremental Δ update: Δ −= s·diff²  (the Variant::Incremental path)
    let diff = vec![0.1f64; n];
    let res = bench("delta_incremental (Δ -= s·diff²)", &cfg, || {
        for i in 0..n {
            delta[i] -= 0.5 * diff[i] * diff[i];
        }
        delta[0]
    });
    record(&mut micro, res);

    // rank-1 R update (Eq. 6): R[0..k] += s·q⊗diff
    let mut rr = vec![0.0f64; k * n];
    let q = vec![0.3f64; k];
    let res = bench("rank1_r_update (Eq. 6)", &cfg, || {
        for t in 0..k {
            let f = 0.5 * q[t];
            let row = &mut rr[t * n..(t + 1) * n];
            for (o, &dv) in row.iter_mut().zip(&diff) {
                *o += f * dv;
            }
        }
        rr[0]
    });
    record(&mut micro, res);

    // kernel column generation (the oracle cost per selection)
    let mut col = vec![0.0f64; n];
    let res = bench("kernel_column (gaussian, m=2)", &cfg, || {
        kernel_column_into(&ds, &kern, n / 2, &mut col);
        col[0]
    });
    record(&mut micro, res);

    // end-to-end per-column selection throughput, both variants
    let (sel_n, sel_cols) = if quick { (1_500, 48) } else { (8_000, 128) };
    let small = two_moons(sel_n, 0.05, 5);
    let skern = Gaussian::with_sigma_fraction(&small, 0.1);
    let oracle = ImplicitOracle::new(&small, &skern);
    for (variant_name, variant) in
        [("PaperR ", Variant::PaperR), ("Increm.", Variant::Incremental)]
    {
        let label =
            format!("oasis_select {variant_name} (ℓ={sel_cols}, n={sel_n})");
        let res = bench(&label, &cfg, || {
            Oasis::new(sel_cols, 10, 1e-14, 7)
                .with_variant(variant)
                .sample(&oracle)
                .unwrap()
                .k()
        });
        record(&mut micro, res);
    }

    // PJRT delta artifact vs native sweep at the artifact shape
    println!("\n== runtime: PJRT delta artifact vs native sweep ==");
    match Accel::try_default() {
        None => println!("(no artifacts — run `make artifacts` to include this bench)"),
        Some(mut accel) => {
            let art = accel
                .manifest
                .best_fit("delta_scores", 4096, &[("l", 512)])
                .expect("delta artifact")
                .clone();
            accel.executor.load(&art).unwrap();
            let (np, lp) = (art.dim("n").unwrap(), art.dim("l").unwrap());
            let c32 = vec![0.5f32; np * lp];
            let r32 = vec![0.25f32; lp * np];
            let d32 = vec![1.0f32; np];
            let res = bench(&format!("pjrt_delta ({np}×{lp})"), &cfg, || {
                accel
                    .executor
                    .run_f32(
                        &art.name,
                        &[
                            (&c32, &[np as i64, lp as i64]),
                            (&r32, &[lp as i64, np as i64]),
                            (&d32, &[np as i64]),
                        ],
                    )
                    .unwrap()[0][0]
            });
            record(&mut micro, res);
            let cc = vec![0.5f64; lp * np];
            let rr2 = vec![0.25f64; lp * np];
            let dd = vec![1.0f64; np];
            let mut out = vec![0.0f64; np];
            let res = bench(&format!("native_delta ({np}×{lp})"), &cfg, || {
                for i in 0..np {
                    let mut acc = 0.0;
                    for t in 0..lp {
                        acc += cc[t * np + i] * rr2[t * np + i];
                    }
                    out[i] = dd[i] - acc;
                }
                out[0]
            });
            record(&mut micro, res);
        }
    }

    // per-method quality trajectory: wall-ms, k, and estimated error on
    // one shared workload — the rows the CI bench-smoke job publishes
    let (mq_n, mq_cols) = if quick { (600, 32) } else { (2_000, 64) };
    println!("\n== method quality (n={mq_n}, ℓ={mq_cols}) ==");
    let mds = two_moons(mq_n, 0.05, 17);
    let mkern = Gaussian::with_sigma_fraction(&mds, 0.05);
    let moracle = ImplicitOracle::new(&mds, &mkern);
    let samplers: Vec<Box<dyn ColumnSampler>> = vec![
        Box::new(Oasis::new(mq_cols, 10, 1e-12, 7)),
        Box::new(Sis::new(mq_cols, 10, 1e-12, 7)),
        Box::new(IncompleteCholesky::new(mq_cols, 1e-12)),
        Box::new(Farahat::new(mq_cols)),
        Box::new(AdaptiveRandom::new(mq_cols, 10, 7)),
    ];
    let mut methods = Vec::new();
    for sampler in samplers {
        let approx = sampler.sample(&moracle).expect("sampler runs");
        let err = relative_frobenius_error(&moracle, &approx);
        let wall_ms = approx.selection_secs * 1e3;
        println!(
            "{:16} {:>9.2} ms  k={:<4} error={:.3e}",
            sampler.name(),
            wall_ms,
            approx.k(),
            err
        );
        methods.push(Json::obj(vec![
            ("method", Json::Str(sampler.name().to_string())),
            ("k", Json::Num(approx.k() as f64)),
            ("wall_ms", Json::Num(wall_ms)),
            ("error", Json::Num(err)),
        ]));
    }

    // downstream-task quality per sampling method (the tasks layer):
    // KRR held-out error and spectral-clustering accuracy on a labeled
    // two-moons workload — BENCH_*.json's downstream-accuracy trajectory
    let (tq_n, tq_cols) = if quick { (500, 32) } else { (1_500, 64) };
    println!("\n== downstream-task quality (n={tq_n}, ℓ={tq_cols}) ==");
    let train = two_moons(tq_n, 0.06, 23);
    let truth: Vec<usize> = (0..tq_n).map(|i| i % 2).collect();
    let labels: Vec<f64> = truth.iter().map(|&t| t as f64).collect();
    // held-out points from the same distribution (fresh noise seed)
    let test = two_moons(tq_n, 0.06, 24);
    let test_points: Vec<Vec<f64>> =
        (0..test.n()).map(|i| test.point(i).to_vec()).collect();
    let test_truth: Vec<f64> = (0..test.n()).map(|i| (i % 2) as f64).collect();
    let tkern = Gaussian::with_sigma_fraction(&train, 0.1);
    let toracle = ImplicitOracle::new(&train, &tkern);
    let task_samplers: Vec<Box<dyn ColumnSampler>> = vec![
        Box::new(Oasis::new(tq_cols, 10, 1e-12, 7)),
        Box::new(Sis::new(tq_cols, 10, 1e-12, 7)),
        Box::new(IncompleteCholesky::new(tq_cols, 1e-12)),
        Box::new(Farahat::new(tq_cols)),
        Box::new(AdaptiveRandom::new(tq_cols, 10, 7)),
    ];
    let mut tasks_quality = Vec::new();
    for sampler in task_samplers {
        let approx = sampler.sample(&toracle).expect("sampler runs");
        let selected = train.select(&approx.indices);
        // KRR: fit on the training labels, score on the held-out set
        let krr = {
            let mut cfg = TaskConfig::new(TaskKind::Krr);
            cfg.labels = Some(labels.clone());
            FittedTask::fit(&approx, &cfg).expect("krr fit")
        };
        let preds = match krr
            .model
            .predict(&tkern, &selected, &test_points)
            .expect("krr predict")
        {
            TaskPrediction::Values(v) => v,
            other => panic!("krr produced {other:?}"),
        };
        let mut sse = 0.0;
        let mut misclassified = 0usize;
        for (p, want) in preds.iter().zip(&test_truth) {
            sse += (p - want) * (p - want);
            if (*p > 0.5) != (*want > 0.5) {
                misclassified += 1;
            }
        }
        let krr_rmse = (sse / preds.len() as f64).sqrt();
        let krr_err = misclassified as f64 / preds.len() as f64;
        // spectral clustering: in-sample accuracy vs the moon labels
        let cluster = {
            let mut cfg = TaskConfig::new(TaskKind::Cluster);
            cfg.clusters = 2;
            cfg.components = 2;
            FittedTask::fit(&approx, &cfg).expect("cluster fit")
        };
        let cluster_acc = permutation_accuracy(
            cluster.cluster_labels.as_ref().expect("in-sample labels"),
            &truth,
            2,
        );
        println!(
            "{:16} k={:<4} krr_test_rmse={:.3e} krr_test_err={:.3} \
             cluster_acc={:.3}",
            sampler.name(),
            approx.k(),
            krr_rmse,
            krr_err,
            cluster_acc
        );
        tasks_quality.push(Json::obj(vec![
            ("method", Json::Str(sampler.name().to_string())),
            ("k", Json::Num(approx.k() as f64)),
            ("krr_test_rmse", Json::Num(krr_rmse)),
            ("krr_test_err", Json::Num(krr_err)),
            ("cluster_acc", Json::Num(cluster_acc)),
        ]));
    }

    // one JSON document for the CI workflow artifact
    if let Some(path) = args.get("json") {
        let doc = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("quick", Json::Bool(quick)),
            (
                "micro",
                Json::Arr(
                    micro
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::Str(r.name.clone())),
                                ("median_ms", Json::Num(r.summary.median * 1e3)),
                                ("min_ms", Json::Num(r.summary.min * 1e3)),
                                ("max_ms", Json::Num(r.summary.max * 1e3)),
                                ("reps", Json::Num(r.summary.n as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("methods", Json::Arr(methods)),
            ("tasks", Json::Arr(tasks_quality)),
        ]);
        std::fs::write(path, format!("{doc}\n")).expect("write --json file");
        println!("\nwrote {path}");
    }
}
