//! §Perf microbenchmarks: the per-iteration hot paths of oASIS across the
//! three layers, used for the EXPERIMENTS.md §Perf iteration log.
//!
//!   L3 native : Δ colsum (PaperR) vs incremental Δ update; rank-1 R
//!               update; kernel column generation; end-to-end per-column
//!               selection throughput for both variants.
//!   Gate pairs: the blocked linalg kernels (matmul / syrk / fused
//!               oASIS step / oracle columns_into) timed against naive
//!               in-bench references — the entries CI's bench-gate job
//!               compares against the committed `BENCH_main.json`.
//!   Methods   : per-method wall-ms / k / est. error on one workload
//!               (the CI bench-smoke trajectory, written to --json).
//!   Tasks     : per-method downstream quality — KRR held-out error and
//!               spectral-clustering accuracy on labeled two-moons (the
//!               BENCH_*.json downstream-accuracy trajectory).
//!   Runtime   : PJRT delta artifact execution vs native Δ sweep.
//!
//!     cargo bench --bench perf                         # full sizes
//!     cargo bench --bench perf -- --quick --json BENCH_ci.json
//!
//! `--quick` shrinks problem sizes and repetitions to CI scale;
//! `--json PATH` additionally writes every result as one JSON document
//! (`{"micro": […], "methods": […], "tasks": […]}`) for the workflow
//! artifact.
//!
//! # The bench-gate pairs and their baseline
//!
//! Each gate pair runs the naive reference and the shipped kernel at
//! the same shape with the same data, **asserts bit-identity between
//! the two results** (the repo's accumulation-order invariant — see
//! `rust/src/linalg/matrix.rs`; a panic here fails bench-smoke), and
//! records `speedup = naive_median / kernel_median` in the `micro`
//! JSON under the stable names `matmul`, `syrk`, `fused_step`, and
//! `columns_into`. The `bench-gate` CI job compares those *ratios*
//! (dimensionless, so slow vs fast runners cancel) against the
//! committed `BENCH_main.json` and fails on a >25% regression.
//!
//! Updating the baseline after an intentional kernel change:
//!   1. let CI's bench-smoke job run on the PR branch,
//!   2. download its `bench-ci` artifact (`BENCH_ci.json`),
//!   3. commit it as `BENCH_main.json` in the same PR.
//!
//! Future kernel edits must keep the per-element increasing-k
//! accumulation order (and therefore bit-identical outputs); the
//! in-bench assertions plus `rust/tests/properties.rs` pin it.

use oasis::bench_support::{bench, BenchConfig, BenchResult};
use oasis::data::generators::two_moons;
use oasis::data::Dataset;
use oasis::kernels::{kernel_column_into, Gaussian, Kernel};
use oasis::linalg::Mat;
use oasis::nystrom::relative_frobenius_error;
use oasis::runtime::Accel;
use oasis::sampling::{
    adaptive_random::AdaptiveRandom,
    farahat::Farahat,
    icd::IncompleteCholesky,
    oasis::{fused_step_update, Oasis, Variant},
    sis::Sis,
    ColumnOracle, ColumnSampler, ImplicitOracle,
};
use oasis::seed::permutation_accuracy;
use oasis::tasks::{FittedTask, TaskConfig, TaskKind, TaskPrediction};
use oasis::util::args::Args;
use oasis::util::json::Json;
use oasis::util::parallel;
use oasis::util::rng::Pcg64;

/// A gated bench pair: the shipped kernel vs its naive in-bench
/// reference at the same shape. `speedup()` is the machine-portable
/// ratio the CI bench-gate compares against the committed baseline.
struct Paired {
    name: &'static str,
    naive: BenchResult,
    fast: BenchResult,
}

impl Paired {
    fn speedup(&self) -> f64 {
        self.naive.summary.median / self.fast.summary.median
    }
}

/// Naive ijk triple loop — the reference the blocked `Mat::matmul` must
/// match bit for bit.
fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0;
            for kk in 0..a.cols {
                s += a.at(i, kk) * b.at(kk, j);
            }
            out.data[i * b.cols + j] = s;
        }
    }
    out
}

/// Naive ΦᵀΦ triple loop (Φ stored k×m like `Mat::syrk` expects).
fn naive_syrk(a: &Mat) -> Mat {
    let (k, m) = (a.rows, a.cols);
    let mut out = Mat::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            let mut s = 0.0;
            for kk in 0..k {
                s += a.at(kk, i) * a.at(kk, j);
            }
            out.data[i * m + j] = s;
        }
    }
    out
}

/// The pre-fusion oASIS step arithmetic: the threaded diff sweep
/// followed by a *separate* threaded Δ pass (what `fused_step_update`
/// replaced — kept here as the gate reference).
#[allow(clippy::too_many_arguments)]
fn two_pass_step_update(
    c: &[f64],
    n: usize,
    q: &[f64],
    col: &[f64],
    s: f64,
    diff: &mut [f64],
    delta: &mut [f64],
    threads: usize,
) {
    parallel::for_each_chunk_mut(diff, 1, threads, |range, chunk| {
        let (lo, hi) = (range.start, range.end);
        for (o, &cv) in chunk.iter_mut().zip(&col[lo..hi]) {
            *o = -cv;
        }
        for (t, &qt) in q.iter().enumerate() {
            if qt == 0.0 {
                continue;
            }
            let ct = &c[t * n + lo..t * n + hi];
            for (o, &cv) in chunk.iter_mut().zip(ct) {
                *o += qt * cv;
            }
        }
    });
    let diff_ro: &[f64] = diff;
    parallel::for_each_chunk_mut(delta, 1, threads, |range, chunk| {
        for (local, i) in range.clone().enumerate() {
            let dv = diff_ro[i];
            chunk[local] -= s * dv * dv;
        }
    });
}

/// The pre-PR `ImplicitOracle::columns_into`: per-entry virtual `eval`
/// calls through strided point access (the gate reference).
fn per_entry_columns_into(
    ds: &Dataset,
    kernel: &dyn Kernel,
    js: &[usize],
    out: &mut Mat,
) {
    let n = ds.n();
    let k = js.len();
    assert_eq!((out.rows, out.cols), (n, k));
    let pts: Vec<&[f64]> = js.iter().map(|&j| ds.point(j)).collect();
    let threads = if n * k >= 16_384 { parallel::default_threads() } else { 1 };
    parallel::for_each_chunk_mut(&mut out.data, k, threads, |range, chunk| {
        for (local, i) in range.clone().enumerate() {
            let zi = ds.point(i);
            let dst = &mut chunk[local * k..(local + 1) * k];
            for (o, &zj) in dst.iter_mut().zip(&pts) {
                *o = kernel.eval(zi, zj);
            }
        }
    });
}

/// The gate pairs' bit-identity assertion: a divergence here means a
/// kernel broke the accumulation-order invariant — fail the bench run.
fn assert_bits_equal(what: &str, a: &[f64], b: &[f64]) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: bit divergence at index {i}: {x:e} vs {y:e}"
        );
    }
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let cfg = BenchConfig {
        warmup: if quick { 0 } else { 1 },
        reps: if quick { 2 } else { 5 },
    };
    let n = args.usize_or("n", if quick { 4_000 } else { 20_000 });
    let k = args.usize_or("k", if quick { 64 } else { 256 });
    let ds = two_moons(n, 0.05, 3);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let mut micro: Vec<BenchResult> = Vec::new();
    let record = |micro: &mut Vec<BenchResult>, res: BenchResult| {
        println!("{}", res.report());
        micro.push(res);
    };

    println!("== L3 hot-path microbenches (n={n}, k={k}) ==");

    // Δ colsum sweep: d − Σ_t c_t∘r_t over live k rows
    let c = vec![0.5f64; k * n];
    let r = vec![0.25f64; k * n];
    let d = vec![1.0f64; n];
    let mut delta = vec![0.0f64; n];
    let res = bench("delta_colsum strided (i-outer, before)", &cfg, || {
        for i in 0..n {
            let mut acc = 0.0;
            for t in 0..k {
                acc += c[t * n + i] * r[t * n + i];
            }
            delta[i] = d[i] - acc;
        }
        delta[0]
    });
    record(&mut micro, res);

    // the shipped streaming version (t-outer, sequential reads)
    let res = bench("delta_colsum streaming (t-outer, after)", &cfg, || {
        delta.copy_from_slice(&d);
        for t in 0..k {
            let ct = &c[t * n..(t + 1) * n];
            let rt = &r[t * n..(t + 1) * n];
            for ((o, &cv), &rv) in delta.iter_mut().zip(ct).zip(rt) {
                *o -= cv * rv;
            }
        }
        delta[0]
    });
    record(&mut micro, res);

    // incremental Δ update: Δ −= s·diff²  (the Variant::Incremental path)
    let diff = vec![0.1f64; n];
    let res = bench("delta_incremental (Δ -= s·diff²)", &cfg, || {
        for i in 0..n {
            delta[i] -= 0.5 * diff[i] * diff[i];
        }
        delta[0]
    });
    record(&mut micro, res);

    // rank-1 R update (Eq. 6): R[0..k] += s·q⊗diff
    let mut rr = vec![0.0f64; k * n];
    let q = vec![0.3f64; k];
    let res = bench("rank1_r_update (Eq. 6)", &cfg, || {
        for t in 0..k {
            let f = 0.5 * q[t];
            let row = &mut rr[t * n..(t + 1) * n];
            for (o, &dv) in row.iter_mut().zip(&diff) {
                *o += f * dv;
            }
        }
        rr[0]
    });
    record(&mut micro, res);

    // kernel column generation (the oracle cost per selection)
    let mut col = vec![0.0f64; n];
    let res = bench("kernel_column (gaussian, m=2)", &cfg, || {
        kernel_column_into(&ds, &kern, n / 2, &mut col);
        col[0]
    });
    record(&mut micro, res);

    // end-to-end per-column selection throughput, both variants
    let (sel_n, sel_cols) = if quick { (1_500, 48) } else { (8_000, 128) };
    let small = two_moons(sel_n, 0.05, 5);
    let skern = Gaussian::with_sigma_fraction(&small, 0.1);
    let oracle = ImplicitOracle::new(&small, &skern);
    for (variant_name, variant) in
        [("PaperR ", Variant::PaperR), ("Increm.", Variant::Incremental)]
    {
        let label =
            format!("oasis_select {variant_name} (ℓ={sel_cols}, n={sel_n})");
        let res = bench(&label, &cfg, || {
            Oasis::new(sel_cols, 10, 1e-14, 7)
                .with_variant(variant)
                .sample(&oracle)
                .unwrap()
                .k()
        });
        record(&mut micro, res);
    }

    // the bench-gate pairs: blocked kernels vs naive references. Stable
    // names (matmul / syrk / fused_step / columns_into) are what
    // .github/scripts/bench_gate.py keys on — keep them when renaming.
    println!("\n== bench-gate pairs (blocked kernels vs naive) ==");
    let gate_cfg = BenchConfig { warmup: 1, reps: if quick { 5 } else { 9 } };
    let mut pairs: Vec<Paired> = Vec::new();
    let mut grng = Pcg64::new(42);

    // matmul: blocked MR×NB row-quad kernel vs the strided ijk loop
    let (mm_m, mm_k, mm_n) =
        if quick { (160, 160, 160) } else { (384, 384, 384) };
    let mut ga = Mat::zeros(mm_m, mm_k);
    grng.fill_normal(&mut ga.data);
    let mut gb = Mat::zeros(mm_k, mm_n);
    grng.fill_normal(&mut gb.data);
    assert_bits_equal("matmul", &naive_matmul(&ga, &gb).data, &ga.matmul(&gb).data);
    let naive = bench(
        &format!("matmul naive ijk ({mm_m}×{mm_k}×{mm_n})"),
        &gate_cfg,
        || naive_matmul(&ga, &gb).data[0],
    );
    let fast = bench(
        &format!("matmul blocked ({mm_m}×{mm_k}×{mm_n})"),
        &gate_cfg,
        || ga.matmul(&gb).data[0],
    );
    pairs.push(Paired { name: "matmul", naive, fast });

    // syrk: the dedicated ΦᵀΦ Gram kernel vs the full ijk product
    let (sy_k, sy_m) = if quick { (1_200, 96) } else { (4_000, 192) };
    let mut phi = Mat::zeros(sy_k, sy_m);
    grng.fill_normal(&mut phi.data);
    assert_bits_equal("syrk", &naive_syrk(&phi).data, &phi.syrk().data);
    let naive = bench(
        &format!("syrk naive ijk ({sy_m}×{sy_m} from k={sy_k})"),
        &gate_cfg,
        || naive_syrk(&phi).data[0],
    );
    let fast = bench(
        &format!("syrk blocked ({sy_m}×{sy_m} from k={sy_k})"),
        &gate_cfg,
        || phi.syrk().data[0],
    );
    pairs.push(Paired { name: "syrk", naive, fast });

    // fused oASIS step: one pass over the new column updating diff and Δ
    // vs the pre-fusion two-sweep arithmetic
    let (fs_n, fs_k) = if quick { (60_000, 8) } else { (200_000, 8) };
    let fs_s = 0.35;
    let fs_threads = parallel::default_threads();
    let mut fs_c = vec![0.0f64; fs_k * fs_n];
    grng.fill_normal(&mut fs_c);
    let mut fs_q = vec![0.0f64; fs_k];
    grng.fill_normal(&mut fs_q);
    let mut fs_col = vec![0.0f64; fs_n];
    grng.fill_normal(&mut fs_col);
    let mut fs_delta0 = vec![0.0f64; fs_n];
    grng.fill_normal(&mut fs_delta0);
    let mut fs_diff = vec![0.0f64; fs_n];
    let mut fs_delta = fs_delta0.clone();
    {
        let (mut diff_b, mut delta_b) = (vec![0.0f64; fs_n], fs_delta0.clone());
        two_pass_step_update(
            &fs_c, fs_n, &fs_q, &fs_col, fs_s, &mut fs_diff, &mut fs_delta,
            fs_threads,
        );
        fused_step_update(
            &fs_c, fs_n, &fs_q, &fs_col, fs_s, &mut diff_b, &mut delta_b,
            fs_threads,
        );
        assert_bits_equal("fused_step diff", &fs_diff, &diff_b);
        assert_bits_equal("fused_step delta", &fs_delta, &delta_b);
    }
    let naive = bench(
        &format!("step_update two-pass (n={fs_n}, k={fs_k})"),
        &gate_cfg,
        || {
            fs_delta.copy_from_slice(&fs_delta0);
            two_pass_step_update(
                &fs_c, fs_n, &fs_q, &fs_col, fs_s, &mut fs_diff, &mut fs_delta,
                fs_threads,
            );
            fs_delta[0]
        },
    );
    let fast = bench(
        &format!("step_update fused (n={fs_n}, k={fs_k})"),
        &gate_cfg,
        || {
            fs_delta.copy_from_slice(&fs_delta0);
            fused_step_update(
                &fs_c, fs_n, &fs_q, &fs_col, fs_s, &mut fs_diff, &mut fs_delta,
                fs_threads,
            );
            fs_delta[0]
        },
    );
    pairs.push(Paired { name: "fused_step", naive, fast });

    // oracle columns_into: shard-local contiguous row blocks through
    // Kernel::eval_rows vs the per-entry virtual-dispatch loop
    let ci_oracle = ImplicitOracle::new(&ds, &kern);
    let ci_js: Vec<usize> = (0..k).map(|t| (t * 97) % n).collect();
    {
        let mut want = Mat::zeros(n, k);
        per_entry_columns_into(&ds, &kern, &ci_js, &mut want);
        let mut got = Mat::zeros(n, k);
        ci_oracle.columns_into(&ci_js, &mut got);
        assert_bits_equal("columns_into", &want.data, &got.data);
    }
    let naive = bench(
        &format!("columns_into per-entry (n={n}, ℓ={k})"),
        &gate_cfg,
        || {
            let mut out = Mat::zeros(n, k);
            per_entry_columns_into(&ds, &kern, &ci_js, &mut out);
            out.data[0]
        },
    );
    let fast = bench(
        &format!("columns_into blocked (n={n}, ℓ={k})"),
        &gate_cfg,
        || {
            let mut out = Mat::zeros(n, k);
            ci_oracle.columns_into(&ci_js, &mut out);
            out.data[0]
        },
    );
    pairs.push(Paired { name: "columns_into", naive, fast });

    for p in &pairs {
        println!("{}", p.naive.report());
        println!("{}", p.fast.report());
        println!("{:14} speedup ×{:.2}", p.name, p.speedup());
    }

    // PJRT delta artifact vs native sweep at the artifact shape
    println!("\n== runtime: PJRT delta artifact vs native sweep ==");
    match Accel::try_default() {
        None => println!("(no artifacts — run `make artifacts` to include this bench)"),
        Some(mut accel) => {
            let art = accel
                .manifest
                .best_fit("delta_scores", 4096, &[("l", 512)])
                .expect("delta artifact")
                .clone();
            accel.executor.load(&art).unwrap();
            let (np, lp) = (art.dim("n").unwrap(), art.dim("l").unwrap());
            let c32 = vec![0.5f32; np * lp];
            let r32 = vec![0.25f32; lp * np];
            let d32 = vec![1.0f32; np];
            let res = bench(&format!("pjrt_delta ({np}×{lp})"), &cfg, || {
                accel
                    .executor
                    .run_f32(
                        &art.name,
                        &[
                            (&c32, &[np as i64, lp as i64]),
                            (&r32, &[lp as i64, np as i64]),
                            (&d32, &[np as i64]),
                        ],
                    )
                    .unwrap()[0][0]
            });
            record(&mut micro, res);
            let cc = vec![0.5f64; lp * np];
            let rr2 = vec![0.25f64; lp * np];
            let dd = vec![1.0f64; np];
            let mut out = vec![0.0f64; np];
            let res = bench(&format!("native_delta ({np}×{lp})"), &cfg, || {
                for i in 0..np {
                    let mut acc = 0.0;
                    for t in 0..lp {
                        acc += cc[t * np + i] * rr2[t * np + i];
                    }
                    out[i] = dd[i] - acc;
                }
                out[0]
            });
            record(&mut micro, res);
        }
    }

    // per-method quality trajectory: wall-ms, k, and estimated error on
    // one shared workload — the rows the CI bench-smoke job publishes
    let (mq_n, mq_cols) = if quick { (600, 32) } else { (2_000, 64) };
    println!("\n== method quality (n={mq_n}, ℓ={mq_cols}) ==");
    let mds = two_moons(mq_n, 0.05, 17);
    let mkern = Gaussian::with_sigma_fraction(&mds, 0.05);
    let moracle = ImplicitOracle::new(&mds, &mkern);
    let samplers: Vec<Box<dyn ColumnSampler>> = vec![
        Box::new(Oasis::new(mq_cols, 10, 1e-12, 7)),
        Box::new(Sis::new(mq_cols, 10, 1e-12, 7)),
        Box::new(IncompleteCholesky::new(mq_cols, 1e-12)),
        Box::new(Farahat::new(mq_cols)),
        Box::new(AdaptiveRandom::new(mq_cols, 10, 7)),
    ];
    let mut methods = Vec::new();
    for sampler in samplers {
        let approx = sampler.sample(&moracle).expect("sampler runs");
        let err = relative_frobenius_error(&moracle, &approx);
        let wall_ms = approx.selection_secs * 1e3;
        println!(
            "{:16} {:>9.2} ms  k={:<4} error={:.3e}",
            sampler.name(),
            wall_ms,
            approx.k(),
            err
        );
        methods.push(Json::obj(vec![
            ("method", Json::Str(sampler.name().to_string())),
            ("k", Json::Num(approx.k() as f64)),
            ("wall_ms", Json::Num(wall_ms)),
            ("error", Json::Num(err)),
        ]));
    }

    // downstream-task quality per sampling method (the tasks layer):
    // KRR held-out error and spectral-clustering accuracy on a labeled
    // two-moons workload — BENCH_*.json's downstream-accuracy trajectory
    let (tq_n, tq_cols) = if quick { (500, 32) } else { (1_500, 64) };
    println!("\n== downstream-task quality (n={tq_n}, ℓ={tq_cols}) ==");
    let train = two_moons(tq_n, 0.06, 23);
    let truth: Vec<usize> = (0..tq_n).map(|i| i % 2).collect();
    let labels: Vec<f64> = truth.iter().map(|&t| t as f64).collect();
    // held-out points from the same distribution (fresh noise seed)
    let test = two_moons(tq_n, 0.06, 24);
    let test_points: Vec<Vec<f64>> =
        (0..test.n()).map(|i| test.point(i).to_vec()).collect();
    let test_truth: Vec<f64> = (0..test.n()).map(|i| (i % 2) as f64).collect();
    let tkern = Gaussian::with_sigma_fraction(&train, 0.1);
    let toracle = ImplicitOracle::new(&train, &tkern);
    let task_samplers: Vec<Box<dyn ColumnSampler>> = vec![
        Box::new(Oasis::new(tq_cols, 10, 1e-12, 7)),
        Box::new(Sis::new(tq_cols, 10, 1e-12, 7)),
        Box::new(IncompleteCholesky::new(tq_cols, 1e-12)),
        Box::new(Farahat::new(tq_cols)),
        Box::new(AdaptiveRandom::new(tq_cols, 10, 7)),
    ];
    let mut tasks_quality = Vec::new();
    for sampler in task_samplers {
        let approx = sampler.sample(&toracle).expect("sampler runs");
        let selected = train.select(&approx.indices);
        // KRR: fit on the training labels, score on the held-out set
        let krr = {
            let mut cfg = TaskConfig::new(TaskKind::Krr);
            cfg.labels = Some(labels.clone());
            FittedTask::fit(&approx, &cfg).expect("krr fit")
        };
        let preds = match krr
            .model
            .predict(&tkern, &selected, &test_points)
            .expect("krr predict")
        {
            TaskPrediction::Values(v) => v,
            other => panic!("krr produced {other:?}"),
        };
        let mut sse = 0.0;
        let mut misclassified = 0usize;
        for (p, want) in preds.iter().zip(&test_truth) {
            sse += (p - want) * (p - want);
            if (*p > 0.5) != (*want > 0.5) {
                misclassified += 1;
            }
        }
        let krr_rmse = (sse / preds.len() as f64).sqrt();
        let krr_err = misclassified as f64 / preds.len() as f64;
        // spectral clustering: in-sample accuracy vs the moon labels
        let cluster = {
            let mut cfg = TaskConfig::new(TaskKind::Cluster);
            cfg.clusters = 2;
            cfg.components = 2;
            FittedTask::fit(&approx, &cfg).expect("cluster fit")
        };
        let cluster_acc = permutation_accuracy(
            cluster.cluster_labels.as_ref().expect("in-sample labels"),
            &truth,
            2,
        );
        println!(
            "{:16} k={:<4} krr_test_rmse={:.3e} krr_test_err={:.3} \
             cluster_acc={:.3}",
            sampler.name(),
            approx.k(),
            krr_rmse,
            krr_err,
            cluster_acc
        );
        tasks_quality.push(Json::obj(vec![
            ("method", Json::Str(sampler.name().to_string())),
            ("k", Json::Num(approx.k() as f64)),
            ("krr_test_rmse", Json::Num(krr_rmse)),
            ("krr_test_err", Json::Num(krr_err)),
            ("cluster_acc", Json::Num(cluster_acc)),
        ]));
    }

    // one JSON document for the CI workflow artifact
    if let Some(path) = args.get("json") {
        let mut micro_json: Vec<Json> = micro
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("median_ms", Json::Num(r.summary.median * 1e3)),
                    ("min_ms", Json::Num(r.summary.min * 1e3)),
                    ("max_ms", Json::Num(r.summary.max * 1e3)),
                    ("reps", Json::Num(r.summary.n as f64)),
                ])
            })
            .collect();
        // gate pairs carry the dimensionless speedup the bench-gate
        // job diffs against BENCH_main.json
        for p in &pairs {
            micro_json.push(Json::obj(vec![
                ("name", Json::Str(p.name.to_string())),
                ("median_ms", Json::Num(p.fast.summary.median * 1e3)),
                ("naive_median_ms", Json::Num(p.naive.summary.median * 1e3)),
                ("speedup", Json::Num(p.speedup())),
                ("reps", Json::Num(p.fast.summary.n as f64)),
            ]));
        }
        let doc = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("quick", Json::Bool(quick)),
            ("micro", Json::Arr(micro_json)),
            ("methods", Json::Arr(methods)),
            ("tasks", Json::Arr(tasks_quality)),
        ]);
        std::fs::write(path, format!("{doc}\n")).expect("write --json file");
        println!("\nwrote {path}");
    }
}
