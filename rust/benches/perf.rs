//! §Perf microbenchmarks: the per-iteration hot paths of oASIS across the
//! three layers, used for the EXPERIMENTS.md §Perf iteration log.
//!
//!   L3 native : Δ colsum (PaperR) vs incremental Δ update; rank-1 R
//!               update; kernel column generation; end-to-end per-column
//!               selection throughput for both variants.
//!   Runtime   : PJRT delta artifact execution vs native Δ sweep.
//!
//!     cargo bench --bench perf

use oasis::bench_support::{bench, BenchConfig};
use oasis::data::generators::two_moons;
use oasis::kernels::{kernel_column_into, Gaussian};
use oasis::runtime::Accel;
use oasis::sampling::{
    oasis::{Oasis, Variant},
    ColumnSampler, ImplicitOracle,
};

fn main() {
    let cfg = BenchConfig { warmup: 1, reps: 5 };
    let n = 20_000;
    let k = 256;
    let ds = two_moons(n, 0.05, 3);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);

    println!("== L3 hot-path microbenches (n={n}, k={k}) ==");

    // Δ colsum sweep: d − Σ_t c_t∘r_t over live k rows
    let c = vec![0.5f64; k * n];
    let r = vec![0.25f64; k * n];
    let d = vec![1.0f64; n];
    let mut delta = vec![0.0f64; n];
    let res = bench("delta_colsum strided (i-outer, before)", &cfg, || {
        for i in 0..n {
            let mut acc = 0.0;
            for t in 0..k {
                acc += c[t * n + i] * r[t * n + i];
            }
            delta[i] = d[i] - acc;
        }
        delta[0]
    });
    println!("{}", res.report());

    // the shipped streaming version (t-outer, sequential reads)
    let res = bench("delta_colsum streaming (t-outer, after)", &cfg, || {
        delta.copy_from_slice(&d);
        for t in 0..k {
            let ct = &c[t * n..(t + 1) * n];
            let rt = &r[t * n..(t + 1) * n];
            for ((o, &cv), &rv) in delta.iter_mut().zip(ct).zip(rt) {
                *o -= cv * rv;
            }
        }
        delta[0]
    });
    println!("{}", res.report());

    // incremental Δ update: Δ −= s·diff²  (the Variant::Incremental path)
    let diff = vec![0.1f64; n];
    let res = bench("delta_incremental (Δ -= s·diff²)", &cfg, || {
        for i in 0..n {
            delta[i] -= 0.5 * diff[i] * diff[i];
        }
        delta[0]
    });
    println!("{}", res.report());

    // rank-1 R update (Eq. 6): R[0..k] += s·q⊗diff
    let mut rr = vec![0.0f64; k * n];
    let q = vec![0.3f64; k];
    let res = bench("rank1_r_update (Eq. 6)", &cfg, || {
        for t in 0..k {
            let f = 0.5 * q[t];
            let row = &mut rr[t * n..(t + 1) * n];
            for (o, &dv) in row.iter_mut().zip(&diff) {
                *o += f * dv;
            }
        }
        rr[0]
    });
    println!("{}", res.report());

    // kernel column generation (the oracle cost per selection)
    let mut col = vec![0.0f64; n];
    let res = bench("kernel_column (gaussian, m=2)", &cfg, || {
        kernel_column_into(&ds, &kern, n / 2, &mut col);
        col[0]
    });
    println!("{}", res.report());

    // end-to-end per-column selection throughput, both variants
    let small = two_moons(8_000, 0.05, 5);
    let skern = Gaussian::with_sigma_fraction(&small, 0.1);
    let oracle = ImplicitOracle::new(&small, &skern);
    for (label, variant) in [
        ("oasis_select PaperR  (ℓ=128, n=8000)", Variant::PaperR),
        ("oasis_select Increm. (ℓ=128, n=8000)", Variant::Incremental),
    ] {
        let res = bench(label, &cfg, || {
            Oasis::new(128, 10, 1e-14, 7)
                .with_variant(variant)
                .sample(&oracle)
                .unwrap()
                .k()
        });
        println!("{}", res.report());
    }

    // PJRT delta artifact vs native sweep at the artifact shape
    println!("\n== runtime: PJRT delta artifact vs native sweep ==");
    match Accel::try_default() {
        None => println!("(no artifacts — run `make artifacts` to include this bench)"),
        Some(mut accel) => {
            let art = accel
                .manifest
                .best_fit("delta_scores", 4096, &[("l", 512)])
                .expect("delta artifact")
                .clone();
            accel.executor.load(&art).unwrap();
            let (np, lp) = (art.dim("n").unwrap(), art.dim("l").unwrap());
            let c32 = vec![0.5f32; np * lp];
            let r32 = vec![0.25f32; lp * np];
            let d32 = vec![1.0f32; np];
            let res = bench(&format!("pjrt_delta ({np}×{lp})"), &cfg, || {
                accel
                    .executor
                    .run_f32(
                        &art.name,
                        &[
                            (&c32, &[np as i64, lp as i64]),
                            (&r32, &[lp as i64, np as i64]),
                            (&d32, &[np as i64]),
                        ],
                    )
                    .unwrap()[0][0]
            });
            println!("{}", res.report());
            let cc = vec![0.5f64; lp * np];
            let rr2 = vec![0.25f64; lp * np];
            let dd = vec![1.0f64; np];
            let mut out = vec![0.0f64; np];
            let res = bench(&format!("native_delta ({np}×{lp})"), &cfg, || {
                for i in 0..np {
                    let mut acc = 0.0;
                    for t in 0..lp {
                        acc += cc[t * np + i] * rr2[t * np + i];
                    }
                    out[i] = dd[i] - acc;
                }
                out[0]
            });
            println!("{}", res.report());
        }
    }
}
