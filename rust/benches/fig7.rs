//! Figure 7 regeneration: (top) Nyström error vs wall-clock runtime and
//! (bottom) columns sampled vs runtime, for the adaptive methods on the
//! Gaussian kernel — the paper's "fair assessment after a set run time".
//!
//!     cargo bench --bench fig7
//!     OASIS_BENCH_SCALE=0.25 cargo bench --bench fig7

use oasis::bench_support::curves::{error_curve, k_grid, scaled, ErrorMode};
use oasis::data::generators::{abalone_like, two_moons};
use oasis::kernels::{kernel_matrix, Gaussian};
use oasis::sampling::{
    farahat::Farahat, leverage::LeverageScores, oasis::Oasis, sis::Sis,
    uniform::Uniform, ExplicitOracle, TracedSampler,
};

fn main() {
    let l = scaled(450, 40);
    let ks = k_grid(10.min(l), l, 8);
    println!("Fig. 7 — error vs selection time, and sampling rate (ℓmax = {l})\n");

    let problems: Vec<(&str, oasis::data::Dataset, f64)> = vec![
        ("Two Moons", two_moons(scaled(2_000, 200), 0.05, 1), 0.05),
        ("Abalone", abalone_like(scaled(4_177, 300), 2), 0.05),
        ("BORG", oasis::bench_support::curves::borg_scaled(scaled(450, 40), 3), 0.4),
    ];

    for (name, ds, frac) in &problems {
        let kern = Gaussian::with_sigma_fraction(ds, *frac);
        let g = kernel_matrix(ds, &kern);
        let oracle = ExplicitOracle::new(&g);
        println!("--- {name} (gaussian, n={}) ---", ds.n());
        println!("{:10} {:>6} {:>12} {:>10}", "method", "k", "error", "t_select");
        let methods: Vec<(&str, Box<dyn TracedSampler>)> = vec![
            ("oASIS", Box::new(Oasis::new(l, 10.min(l), 1e-14, 7))),
            ("Random", Box::new(Uniform::new(l, 100))),
            ("Leverage", Box::new(LeverageScores::new(l, l, 200))),
            ("Farahat", Box::new(Farahat::new(l))),
        ];
        for (mname, sampler) in methods {
            let (_, trace) = sampler.sample_traced(&oracle).expect(mname);
            let curve = error_curve(&oracle, &trace, &ks, ErrorMode::Full, 5);
            for p in &curve {
                println!(
                    "{:10} {:>6} {:>12.4e} {:>9.3}s",
                    mname, p.k, p.error, p.secs
                );
            }
            // sampling-rate panel: columns vs time comes directly from the
            // trace (cum_secs[k])
            let rate_points: Vec<String> = ks
                .iter()
                .filter(|&&k| k <= trace.cum_secs.len())
                .map(|&k| format!("({:.3}s → {k})", trace.cum_secs[k - 1]))
                .collect();
            println!("{:10} sampling rate: {}", mname, rate_points.join(" "));
        }
        // naive SIS on the smallest problem only — the ablation the
        // acceleration is measured against
        if *name == "Two Moons" && ds.n() <= 2_000 {
            let l_sis = l.min(100);
            let (_, trace) = Sis::new(l_sis, 10.min(l_sis), 1e-14, 7)
                .sample_traced(&oracle)
                .expect("sis");
            let ks_sis = k_grid(10.min(l_sis), l_sis, 5);
            let curve = error_curve(&oracle, &trace, &ks_sis, ErrorMode::Full, 5);
            for p in &curve {
                println!(
                    "{:10} {:>6} {:>12.4e} {:>9.3}s   (naive, ablation)",
                    "SIS", p.k, p.error, p.secs
                );
            }
        }
        println!();
    }
    println!(
        "paper shape check: oASIS reaches low error fastest per wall-second and\n\
         samples columns at a near-constant rate; Farahat matches its error only\n\
         after ~10× the time; Leverage pays a large up-front SVD before its\n\
         first sample; Random floors early."
    );
}
