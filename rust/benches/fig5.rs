//! Figure 5 regeneration: exact recovery on the rank-3 Gram matrix.
//!
//! Panels: (b) approximation error vs columns sampled, oASIS vs 5 uniform
//! random trials; (c) rank of G̃ vs columns sampled.
//!
//!     cargo bench --bench fig5

use oasis::data::generators::gauss_2d_plus_3d;
use oasis::kernels::{kernel_matrix, Linear};
use oasis::linalg::eig::psd_rank;
use oasis::sampling::{
    assemble_from_indices, oasis::Oasis, uniform::Uniform, ExplicitOracle,
};
use oasis::util::table::{sci, Table};

fn main() {
    let ds = gauss_2d_plus_3d(150, 150, 5);
    let g = kernel_matrix(&ds, &Linear);
    let oracle = ExplicitOracle::new(&g);
    let gnorm = g.fro_norm();
    println!("Fig. 5 — dataset: 2-D Gaussian at (0,0) + 3-D Gaussian at (0,0,1)");
    println!("rank(G) = {} (n = {})\n", psd_rank(&g, 1e-9), g.rows);

    let eval = |order: &[usize], k: usize| -> (f64, usize) {
        let approx = assemble_from_indices(&oracle, order[..k.min(order.len())].to_vec(), 0.0);
        let recon = approx.reconstruct();
        (recon.fro_dist(&g) / gnorm, psd_rank(&recon, 1e-9))
    };

    let mut table = Table::new(&["method", "k", "error", "rank(G̃)"])
        .with_title("Fig. 5(b)+(c): error and rank vs columns sampled");
    let (_, oasis_trace) = Oasis::new(8, 1, 1e-9, 1)
        .sample_traced(&oracle)
        .expect("oasis");
    for k in 1..=oasis_trace.order.len() {
        let (err, rank) = eval(&oasis_trace.order, k);
        table.row(vec!["oASIS".into(), k.to_string(), sci(err), rank.to_string()]);
    }
    for trial in 0..5u64 {
        let (_, tr) = Uniform::new(8, 100 + trial)
            .sample_traced(&oracle)
            .expect("uniform");
        for k in 1..=8usize {
            let (err, rank) = eval(&tr.order, k);
            table.row(vec![
                format!("Random trial {}", trial + 1),
                k.to_string(),
                sci(err),
                rank.to_string(),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper shape check: oASIS hits machine-precision error at k = rank = 3;\n\
         random trials select redundant columns (rank plateaus below k)."
    );
}
