//! Table II regeneration: implicit (on-the-fly) kernel matrices — MNIST,
//! Salinas hyperspectral, and Light Field patches — where G is never
//! stored. Methods: oASIS, uniform random, K-means Nyström (Leverage and
//! Farahat are intractable here, as in the paper). Error is the
//! 100,000-sampled-entry Frobenius discrepancy.
//!
//! Paper sizes are n = 50,000–85,265 with ℓ = 4,000–5,000; the default
//! scale runs n/ℓ at ~12% of that so the bench finishes in minutes — set
//! OASIS_BENCH_SCALE=1 to regenerate at paper size.
//!
//!     cargo bench --bench table2

use oasis::bench_support::curves::scaled;
use oasis::data::generators::{lightfield_like, mnist_like, salinas_like};
use oasis::data::Dataset;
use oasis::kernels::Gaussian;
use oasis::nystrom::sampled_relative_error;
use oasis::sampling::{
    kmeans::KMeansNystrom, oasis::Oasis, uniform::Uniform, ColumnSampler,
    ImplicitOracle,
};
use oasis::util::table::{sci, Table};

struct Problem {
    name: &'static str,
    ds: Dataset,
    l: usize,
    sigma: SigmaSpec,
}

enum SigmaSpec {
    Fraction(f64),
    Absolute(f64),
}

fn problems() -> Vec<Problem> {
    let s = |n: usize| scaled(n, 500);
    vec![
        Problem {
            // paper: 50,000 × 784, ℓ=4,000, σ = 50% max distance
            name: "MNIST",
            ds: mnist_like(s(50_000) / 8, 784, 1),
            l: scaled(4_000, 60) / 8,
            sigma: SigmaSpec::Fraction(0.5),
        },
        Problem {
            // paper: 54,129 × 204, ℓ=5,000, σ = 10
            name: "Salinas",
            ds: salinas_like(s(54_129) / 8, 204, 2),
            l: scaled(5_000, 60) / 8,
            sigma: SigmaSpec::Absolute(10.0),
        },
        Problem {
            // paper: 85,265 × 400, ℓ=5,000, σ = 50% max distance
            name: "Light Field",
            ds: lightfield_like(s(85_265) / 8, 3),
            l: scaled(5_000, 60) / 8,
            sigma: SigmaSpec::Fraction(0.5),
        },
    ]
}

fn main() {
    let samples = 100_000;
    let trials = 3;
    println!(
        "Table II — implicit kernel matrices (sampled-entry error over {samples} entries; scale {}×)\n",
        oasis::bench_support::curves::bench_scale()
    );
    let mut table =
        Table::new(&["Problem", "n", "ℓ", "oASIS", "Random", "K-means"]);
    for p in problems() {
        let kern = match p.sigma {
            SigmaSpec::Fraction(f) => Gaussian::with_sigma_fraction(&p.ds, f),
            SigmaSpec::Absolute(s) => Gaussian::new(s),
        };
        let oracle = ImplicitOracle::new(&p.ds, &kern);
        let l = p.l.min(p.ds.n());

        let approx = Oasis::new(l, 10.min(l), 1e-14, 7).sample(&oracle).unwrap();
        let e_oasis = sampled_relative_error(&oracle, &approx, samples, 11);
        let oasis_cell = format!("{} ({:.1})", sci(e_oasis), approx.selection_secs);

        let (mut e_sum, mut t_sum) = (0.0, 0.0);
        for t in 0..trials {
            let a = Uniform::new(l, 100 + t).sample(&oracle).unwrap();
            e_sum += sampled_relative_error(&oracle, &a, samples, 11);
            t_sum += a.selection_secs;
        }
        let rand_cell = format!(
            "{} ({:.1})",
            sci(e_sum / trials as f64),
            t_sum / trials as f64
        );

        let (mut e_sum, mut t_sum) = (0.0, 0.0);
        for t in 0..trials {
            let a = KMeansNystrom::new(&p.ds, &kern, l, 300 + t)
                .approximate()
                .unwrap();
            e_sum += sampled_relative_error(&oracle, &a, samples, 11);
            t_sum += a.selection_secs;
        }
        let km_cell = format!(
            "{} ({:.1})",
            sci(e_sum / trials as f64),
            t_sum / trials as f64
        );

        table.row(vec![
            p.name.to_string(),
            p.ds.n().to_string(),
            l.to_string(),
            oasis_cell,
            rand_cell,
            km_cell,
        ]);
    }
    table.print();
    println!(
        "\npaper shape check: oASIS beats Random by orders of magnitude on\n\
         low-rank image-like data; K-means is competitive in error but gives\n\
         no column index set and must re-run per ℓ."
    );
}
