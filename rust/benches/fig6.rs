//! Figure 6 regeneration: (top) Nyström approximation error vs number of
//! columns sampled for Two Moons / Abalone / BORG, Gaussian and diffusion
//! kernels; (bottom) column-selection runtime vs matrix size n.
//!
//!     cargo bench --bench fig6
//!     OASIS_BENCH_SCALE=0.25 cargo bench --bench fig6

use oasis::bench_support::curves::{error_curve, k_grid, scaled, ErrorMode};
use oasis::data::generators::{abalone_like, two_moons};
use oasis::kernels::{diffusion_normalize, kernel_matrix, Gaussian};
use oasis::nystrom::relative_frobenius_error;
use oasis::sampling::{
    farahat::Farahat, kmeans::KMeansNystrom, leverage::LeverageScores,
    oasis::Oasis, uniform::Uniform, ColumnSampler, ExplicitOracle,
    TracedSampler,
};
use oasis::util::timing::timed;

fn main() {
    let l = scaled(450, 40);
    let ks = k_grid(10.min(l), l, 8);
    println!("Fig. 6 (top) — error vs columns sampled (ℓmax = {l})\n");

    let problems: Vec<(&str, oasis::data::Dataset, f64)> = vec![
        ("Two Moons", two_moons(scaled(2_000, 200), 0.05, 1), 0.05),
        ("Abalone", abalone_like(scaled(4_177, 300), 2), 0.05),
        ("BORG", oasis::bench_support::curves::borg_scaled(scaled(450, 40), 3), 0.4),
    ];

    for (name, ds, frac) in &problems {
        let kern = Gaussian::with_sigma_fraction(ds, *frac);
        let g = kernel_matrix(ds, &kern);
        let mut m = g.clone();
        diffusion_normalize(&mut m);
        for (kname, target) in [("gaussian", &g), ("diffusion", &m)] {
            println!("--- {name} ({kname}, n={}) ---", ds.n());
            let oracle = ExplicitOracle::new(target);
            let methods: Vec<(&str, Box<dyn TracedSampler>)> = vec![
                ("oASIS", Box::new(Oasis::new(l, 10.min(l), 1e-14, 7))),
                ("Random", Box::new(Uniform::new(l, 100))),
                ("Leverage", Box::new(LeverageScores::new(l, l, 200))),
                ("Farahat", Box::new(Farahat::new(l))),
            ];
            for (mname, sampler) in methods {
                let (_, trace) = sampler.sample_traced(&oracle).expect(mname);
                let curve = error_curve(&oracle, &trace, &ks, ErrorMode::Full, 5);
                for p in &curve {
                    println!(
                        "{name},{kname},{mname},k={},error={:.4e}",
                        p.k, p.error
                    );
                }
            }
            // K-means has no prefix property — rerun per k (paper §V-E)
            if kname == "gaussian" {
                for &k in &ks {
                    let a = KMeansNystrom::new(ds, &kern, k, 300).approximate().unwrap();
                    let e = relative_frobenius_error(&oracle, &a);
                    println!("{name},{kname},K-means,k={k},error={e:.4e}");
                }
            }
            println!();
        }
    }

    // --- bottom panel: selection runtime vs matrix size ---
    println!("Fig. 6 (bottom) — column-selection runtime vs n (ℓ = {})", scaled(200, 20));
    let lruntime = scaled(200, 20);
    for n in [500usize, 1000, 2000, 4000, 8000] {
        let n = scaled(n, 100);
        let ds = two_moons(n, 0.05, 9);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.05);
        let g = kernel_matrix(&ds, &kern);
        let oracle = ExplicitOracle::new(&g);
        let (a_oasis, t_oasis) = timed(|| {
            Oasis::new(lruntime.min(n), 10, 1e-14, 7).sample(&oracle).unwrap()
        });
        let (_, t_rand) =
            timed(|| Uniform::new(lruntime.min(n), 3).sample(&oracle).unwrap());
        let (_, t_lev) = timed(|| {
            LeverageScores::new(lruntime.min(n), lruntime.min(n), 4)
                .sample(&oracle)
                .unwrap()
        });
        let (_, t_far) =
            timed(|| Farahat::new(lruntime.min(n)).sample(&oracle).unwrap());
        println!(
            "n={n:6}  oASIS={t_oasis:8.3}s  Random={t_rand:8.3}s  \
             Leverage={t_lev:8.3}s  Farahat={t_far:8.3}s  (oASIS k={})",
            a_oasis.k()
        );
    }
    println!(
        "\npaper shape check: oASIS runtime grows ~linearly in n; Farahat and\n\
         Leverage grow ~quadratically; Random is near-constant selection cost."
    );
}
