//! Table I regeneration: error (selection runtime) at ℓ=450 for explicit
//! Gaussian (first line) and diffusion (second line) kernel matrices over
//! Two Moons (n=2000), Abalone-like (n=4177) and BORG (n=7680), for
//! oASIS / Random / Leverage scores / K-means / Farahat.
//!
//!     cargo bench --bench table1
//!     OASIS_BENCH_SCALE=0.25 cargo bench --bench table1   (quick run)

use oasis::bench_support::curves::scaled;
use oasis::data::generators::{abalone_like, two_moons};
use oasis::data::Dataset;
use oasis::kernels::{diffusion_normalize, kernel_matrix, Gaussian};
use oasis::nystrom::relative_frobenius_error;
use oasis::sampling::{
    farahat::Farahat, kmeans::KMeansNystrom, leverage::LeverageScores,
    oasis::Oasis, uniform::Uniform, ColumnSampler, ExplicitOracle,
};
use oasis::util::table::{sci, Table};
use oasis::util::timing::timed;

struct Problem {
    name: &'static str,
    ds: Dataset,
    sigma_frac: f64,
}

fn problems() -> Vec<Problem> {
    vec![
        Problem {
            name: "Two Moons",
            ds: two_moons(scaled(2_000, 200), 0.05, 1),
            sigma_frac: 0.05,
        },
        Problem {
            name: "Abalone",
            ds: abalone_like(scaled(4_177, 300), 2),
            sigma_frac: 0.05,
        },
        Problem {
            name: "BORG",
            ds: oasis::bench_support::curves::borg_scaled(scaled(450, 40), 3),
            sigma_frac: 0.4, // tuned (§V-A); 0.125 of max-dist makes G≈I at this scale
        },
    ]
}

fn main() {
    let l = scaled(450, 40);
    let trials = 3; // paper uses 10 for the stochastic methods
    println!(
        "Table I — explicit kernel matrices, ℓ = {l} (scale {}×; error (selection secs))\n",
        oasis::bench_support::curves::bench_scale()
    );

    let mut table = Table::new(&[
        "Problem", "kernel", "n", "oASIS", "Random", "Leverage", "K-means", "Farahat",
    ]);

    for p in problems() {
        let n = p.ds.n();
        let kern = Gaussian::with_sigma_fraction(&p.ds, p.sigma_frac);
        let gaussian_g = kernel_matrix(&p.ds, &kern);
        let mut diffusion_g = gaussian_g.clone();
        diffusion_normalize(&mut diffusion_g);

        for (kname, g) in [("gaussian", &gaussian_g), ("diffusion", &diffusion_g)] {
            let oracle = ExplicitOracle::new(g);
            let mut cells = vec![p.name.to_string(), kname.to_string(), n.to_string()];

            // oASIS (deterministic — single run)
            let approx = Oasis::new(l, 10.min(l), 1e-14, 7)
                .sample(&oracle)
                .expect("oasis");
            let err = relative_frobenius_error(&oracle, &approx);
            cells.push(format!("{} ({:.2})", sci(err), approx.selection_secs));

            // Random — averaged trials
            let (mut e_sum, mut t_sum) = (0.0, 0.0);
            for t in 0..trials {
                let a = Uniform::new(l, 100 + t).sample(&oracle).unwrap();
                e_sum += relative_frobenius_error(&oracle, &a);
                t_sum += a.selection_secs;
            }
            cells.push(format!(
                "{} ({:.2})",
                sci(e_sum / trials as f64),
                t_sum / trials as f64
            ));

            // Leverage scores — averaged trials
            let (mut e_sum, mut t_sum) = (0.0, 0.0);
            for t in 0..trials {
                let a = LeverageScores::new(l, l, 200 + t).sample(&oracle).unwrap();
                e_sum += relative_frobenius_error(&oracle, &a);
                t_sum += a.selection_secs;
            }
            cells.push(format!(
                "{} ({:.2})",
                sci(e_sum / trials as f64),
                t_sum / trials as f64
            ));

            // K-means Nyström — averaged trials (kernel-space approx uses
            // the raw data; for the diffusion rows the paper remaps too —
            // we approximate the un-normalized kernel and report its error
            // against the normalized target like-for-like by re-normalizing
            // its reconstruction is out of scope, so we evaluate on the
            // gaussian target for both rows, flagged with '*' on diffusion)
            if kname == "gaussian" {
                let (mut e_sum, mut t_sum) = (0.0, 0.0);
                for t in 0..trials {
                    let a = KMeansNystrom::new(&p.ds, &kern, l, 300 + t)
                        .approximate()
                        .unwrap();
                    e_sum += relative_frobenius_error(&oracle, &a);
                    t_sum += a.selection_secs;
                }
                cells.push(format!(
                    "{} ({:.2})",
                    sci(e_sum / trials as f64),
                    t_sum / trials as f64
                ));
            } else {
                cells.push("n/a (col-space only)".to_string());
            }

            // Farahat (deterministic)
            let (a, secs) = timed(|| Farahat::new(l).sample(&oracle).unwrap());
            let err = relative_frobenius_error(&oracle, &a);
            cells.push(format!("{} ({:.2})", sci(err), secs));

            table.row(cells);
        }
    }
    table.print();
    println!(
        "\npaper shape check: oASIS ≈ Farahat-class accuracy at a fraction of its\n\
         runtime; Random is fastest to select but least accurate; Leverage sits\n\
         between; K-means leads on BORG (its ideal cluster model)."
    );
}
