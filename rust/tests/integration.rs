//! Cross-module integration tests: every sampler against every oracle
//! class on realistic (small) workloads, checking the paper's qualitative
//! claims end to end.

use oasis::data::generators::*;
use oasis::kernels::{diffusion_normalize, kernel_matrix, Gaussian, Linear};
use oasis::nystrom::{
    nystrom_eig, relative_frobenius_error, sampled_relative_error,
};
use oasis::sampling::{
    farahat::Farahat, kmeans::KMeansNystrom, leverage::LeverageScores,
    oasis::Oasis, uniform::Uniform, ColumnSampler, ExplicitOracle,
    ImplicitOracle, SparseKnnOracle,
};

/// Table I qualitative shape on a mini Two Moons: adaptive methods beat
/// uniform random at equal ℓ; oASIS is in the same accuracy class as
/// Farahat.
#[test]
fn adaptive_beats_random_on_two_moons() {
    let ds = two_moons(300, 0.05, 21);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let l = 60;

    let e_oasis = relative_frobenius_error(
        &oracle,
        &Oasis::new(l, 10, 1e-14, 3).sample(&oracle).unwrap(),
    );
    let e_far = relative_frobenius_error(
        &oracle,
        &Farahat::new(l).sample(&oracle).unwrap(),
    );
    // average several random trials like the paper
    let mut e_rand = 0.0;
    for s in 0..5 {
        e_rand += relative_frobenius_error(
            &oracle,
            &Uniform::new(l, 100 + s).sample(&oracle).unwrap(),
        );
    }
    e_rand /= 5.0;

    assert!(e_oasis < e_rand, "oASIS {e_oasis} !< random {e_rand}");
    assert!(e_far < e_rand, "farahat {e_far} !< random {e_rand}");
    // same accuracy class: within 100× of the expensive greedy method
    assert!(e_oasis < e_far * 100.0 + 1e-12, "oASIS {e_oasis} vs farahat {e_far}");
}

/// The diffusion-kernel variant of Table I (second rows) runs through the
/// same pipeline.
#[test]
fn diffusion_kernel_pipeline() {
    let ds = two_moons(200, 0.05, 8);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let mut m = kernel_matrix(&ds, &kern);
    diffusion_normalize(&mut m);
    let oracle = ExplicitOracle::new(&m);
    let approx = Oasis::new(50, 8, 1e-14, 5).sample(&oracle).unwrap();
    let err = relative_frobenius_error(&oracle, &approx);
    assert!(err < 0.05, "diffusion error {err}");
}

/// Leverage scores work on the explicit class and are competitive with
/// uniform random (Table I shape).
#[test]
fn leverage_on_explicit_matrix() {
    let ds = two_moons(250, 0.05, 4);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let g = kernel_matrix(&ds, &kern);
    let oracle = ExplicitOracle::new(&g);
    let l = 50;
    let e_lev = relative_frobenius_error(
        &oracle,
        &LeverageScores::new(l, l, 2).sample(&oracle).unwrap(),
    );
    let mut e_rand = 0.0;
    for s in 0..5 {
        e_rand += relative_frobenius_error(
            &oracle,
            &Uniform::new(l, 200 + s).sample(&oracle).unwrap(),
        );
    }
    e_rand /= 5.0;
    // leverage sampling is adaptive-random: on this workload it lands in
    // the same order of magnitude as uniform (the paper's Table I shows it
    // between random and the greedy methods, dataset-dependent)
    assert!(
        e_lev < e_rand * 3.0,
        "leverage {e_lev} not competitive with random {e_rand}"
    );
}

/// K-means Nyström is the strongest baseline on its ideal workload
/// (BORG-like spherical clusters, §V-E) — and oASIS stays within range.
#[test]
fn kmeans_wins_its_home_game() {
    let ds = borg(4, 12, 0.05, 6); // 16 vertices × 12 points
    let kern = Gaussian::with_sigma_fraction(&ds, 0.3);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let l = 24;
    let e_km = relative_frobenius_error(
        &oracle,
        &KMeansNystrom::new(&ds, &kern, l, 3).sample(&oracle).unwrap(),
    );
    let e_oasis = relative_frobenius_error(
        &oracle,
        &Oasis::new(l, 6, 1e-14, 3).sample(&oracle).unwrap(),
    );
    let e_rand = relative_frobenius_error(
        &oracle,
        &Uniform::new(l, 9).sample(&oracle).unwrap(),
    );
    assert!(e_km < e_rand, "kmeans {e_km} !< random {e_rand}");
    assert!(e_oasis < e_rand, "oasis {e_oasis} !< random {e_rand}");
}

/// Sparse k-NN kernel oracle: oASIS touches only sampled columns and the
/// approximation is still accurate (§V-E sparse discussion).
#[test]
fn sparse_knn_oracle_end_to_end() {
    let ds = two_moons(200, 0.05, 10);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = SparseKnnOracle::build(&ds, &kern, 12);
    assert!(oracle.density() < 0.2);
    let approx = Oasis::new(60, 8, 1e-14, 4).sample(&oracle).unwrap();
    let err = relative_frobenius_error(&oracle, &approx);
    assert!(err < 0.35, "sparse error {err}");
    // sampled-entry estimator agrees on order of magnitude
    let est = sampled_relative_error(&oracle, &approx, 30_000, 11);
    assert!((est - err).abs() < 0.3 * err.max(0.05), "est {est} vs {err}");
}

/// Nyström SVD on a mini MNIST-like set: the top eigenpairs from ℓ ≪ n
/// sampled columns match the dense eigendecomposition.
#[test]
fn nystrom_svd_matches_dense_on_low_rank_data() {
    let ds = mnist_like(150, 48, 12);
    let g = kernel_matrix(&ds, &Linear);
    let oracle = ExplicitOracle::new(&g);
    let approx = Oasis::new(80, 10, 1e-10, 6).sample(&oracle).unwrap();
    let (vals, u) = nystrom_eig(&approx, 1e-9);
    let dense = oasis::linalg::sym_eig(&g);
    // top-5 eigenvalues within 2%
    for t in 0..5 {
        let rel = (vals[t] - dense.vals[t]).abs() / dense.vals[t];
        assert!(rel < 0.02, "eigenvalue {t}: {} vs {}", vals[t], dense.vals[t]);
    }
    // eigenvectors align up to sign: |<u, v>| ≈ 1
    for t in 0..3 {
        let dot: f64 = (0..150).map(|i| u.at(i, t) * dense.vecs.at(i, t)).sum();
        assert!(dot.abs() > 0.98, "eigenvector {t} alignment {dot}");
    }
}

/// Implicit (on-the-fly) oracle and explicit oracle give the same oASIS
/// selections — G is never materialized for the implicit path.
#[test]
fn implicit_matches_explicit_selection() {
    let ds = abalone_like(300, 9);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.2);
    let g = kernel_matrix(&ds, &kern);
    let expo = ExplicitOracle::new(&g);
    let impo = ImplicitOracle::new(&ds, &kern);
    let (a1, t1) = Oasis::new(40, 5, 1e-12, 17).sample_traced(&expo).unwrap();
    let (a2, t2) = Oasis::new(40, 5, 1e-12, 17).sample_traced(&impo).unwrap();
    assert_eq!(t1.order, t2.order);
    assert_eq!(a1.indices, a2.indices);
}

/// Error estimators: sampled vs exact on a mid-size problem.
#[test]
fn sampled_error_estimator_consistency() {
    let ds = salinas_like(220, 60, 3);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.5);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let approx = Oasis::new(40, 6, 1e-12, 2).sample(&oracle).unwrap();
    let exact = relative_frobenius_error(&oracle, &approx);
    let est = sampled_relative_error(&oracle, &approx, 50_000, 19);
    assert!(
        (est - exact).abs() < 0.3 * exact.max(1e-4),
        "estimator {est} vs exact {exact}"
    );
}

/// The lib.rs doc quickstart path runs.
#[test]
fn quickstart_path() {
    let ds = two_moons(400, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let approx = Oasis::new(90, 10, 1e-12, 7).sample(&oracle).unwrap();
    let err = relative_frobenius_error(&oracle, &approx);
    assert!(err < 0.1, "quickstart error {err}");
}
