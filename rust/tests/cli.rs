//! CLI smoke tests: drive the `oasis` binary end to end via
//! `CARGO_BIN_EXE_oasis` (cargo builds it for integration tests).

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_oasis"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_prints_usage() {
    let (stdout, _, ok) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("approximate"));
    assert!(stdout.contains("parallel"));
}

#[test]
fn approximate_oasis_small() {
    let (stdout, stderr, ok) = run(&[
        "approximate",
        "--dataset",
        "two-moons",
        "--n",
        "300",
        "--cols",
        "40",
        "--method",
        "oasis",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("method=oasis"), "{stdout}");
    assert!(stdout.contains("error="), "{stdout}");
    // deterministic: same invocation gives the same error line
    let (stdout2, _, _) = run(&[
        "approximate",
        "--dataset",
        "two-moons",
        "--n",
        "300",
        "--cols",
        "40",
        "--method",
        "oasis",
    ]);
    let line = |s: &str| {
        s.lines()
            .find(|l| l.contains("error="))
            .unwrap()
            .split("select_time")
            .next()
            .unwrap()
            .to_string()
    };
    assert_eq!(line(&stdout), line(&stdout2));
}

#[test]
fn approximate_all_methods_run() {
    for m in ["random", "kmeans", "farahat", "leverage"] {
        let (stdout, stderr, ok) = run(&[
            "approximate",
            "--dataset",
            "abalone",
            "--n",
            "200",
            "--cols",
            "20",
            "--method",
            m,
        ]);
        assert!(ok, "method {m} failed: {stderr}");
        assert!(stdout.contains(&format!("method={m}")), "{stdout}");
    }
}

#[test]
fn approximate_json_output_parses() {
    let (stdout, stderr, ok) = run(&[
        "approximate",
        "--dataset",
        "two-moons",
        "--n",
        "300",
        "--cols",
        "40",
        "--method",
        "oasis",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let line = stdout.lines().find(|l| l.starts_with('{')).expect("json line");
    // keys promised to downstream tooling: method, k, error, secs
    for key in ["\"method\"", "\"k\"", "\"error\"", "\"secs\"", "\"stop\""] {
        assert!(line.contains(key), "missing {key} in {line}");
    }
    assert!(line.contains("\"method\":\"oasis\""), "{line}");
    assert!(line.contains("\"k\":40"), "{line}");
}

#[test]
fn approximate_target_err_stops_early() {
    let (stdout, stderr, ok) = run(&[
        "approximate",
        "--dataset",
        "two-moons",
        "--n",
        "400",
        "--cols",
        "200",
        "--method",
        "oasis",
        "--target-err",
        "0.5",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let line = stdout.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"stop\":\"error-target\""), "{line}");
    // k was parsed back out below the budget
    let k: f64 = line
        .split("\"k\":")
        .nth(1)
        .and_then(|s| s.split([',', '}']).next())
        .unwrap()
        .parse()
        .unwrap();
    assert!(k < 200.0, "expected early stop, k = {k}");
}

/// End-to-end persistence through the binary: approximate a CSV file,
/// save the artifact, then answer queries from it with `oasis query` —
/// deterministically, and without the CSV still being around.
#[test]
fn approximate_data_save_then_query_load() {
    let dir = std::env::temp_dir()
        .join("oasis-cli-store-test")
        .join(format!("run-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("pts.csv");
    let model = dir.join("model.oasis");

    // small deterministic grid dataset
    let mut text = String::new();
    for i in 0..60 {
        text.push_str(&format!("{},{}\n", (i % 10) as f64 * 0.37, (i / 10) as f64 * 0.81));
    }
    std::fs::write(&csv, text).unwrap();

    let (stdout, stderr, ok) = run(&[
        "approximate",
        "--data",
        csv.to_str().unwrap(),
        "--cols",
        "12",
        "--method",
        "oasis",
        "--save",
        model.to_str().unwrap(),
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stderr.contains("saved artifact"), "{stderr}");
    let line = stdout.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"dataset\":\"file:"), "{line}");
    assert!(line.contains("\"k\":12"), "{line}");
    assert!(model.is_file());

    // the CSV is no longer needed for queries
    std::fs::remove_file(&csv).unwrap();

    // summary mode
    let (stdout, stderr, ok) =
        run(&["query", "--load", model.to_str().unwrap()]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("n=60"), "{stdout}");
    assert!(stdout.contains("k=12"), "{stdout}");
    assert!(stdout.contains("kernel=gaussian"), "{stdout}");

    // query mode, twice: deterministic bit-identical output
    let q = |targs: &[&str]| {
        let mut argv = vec![
            "query",
            "--load",
            model.to_str().unwrap(),
            "--points",
            "0.5,0.5;1.0,2.0",
        ];
        argv.extend_from_slice(targs);
        run(&argv)
    };
    let (out1, stderr, ok) = q(&["--targets", "0,30,59", "--json"]);
    assert!(ok, "stderr: {stderr}");
    let line = out1.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"k\":12"), "{line}");
    assert!(line.contains("\"weights\":["), "{line}");
    assert!(line.contains("\"kernel\":["), "{line}");
    let (out2, _, _) = q(&["--targets", "0,30,59", "--json"]);
    assert_eq!(out1, out2, "stored queries must be deterministic");

    // human-readable mode names the targets
    let (out, stderr, ok) = q(&["--targets", "7"]);
    assert!(ok, "stderr: {stderr}");
    assert!(out.contains("g(7)="), "{out}");
    assert!(out.contains("point 1:"), "{out}");

    // a corrupted artifact is rejected with a clear error
    let mut bytes = std::fs::read(&model).unwrap();
    let at = bytes.len() - 3;
    bytes[at] ^= 0x08;
    let bad = dir.join("bad.oasis");
    std::fs::write(&bad, &bytes).unwrap();
    let (_, stderr, ok) = run(&["query", "--load", bad.to_str().unwrap()]);
    assert!(!ok);
    assert!(stderr.contains("checksum"), "{stderr}");

    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end downstream tasks through the binary: approximate a CSV
/// and save it, fit KRR from the artifact (dataset-free) with a labels
/// file, predict deterministically, persist the fitted model back into
/// the artifact, and reuse it without labels.
#[test]
fn task_krr_fit_save_and_labelfree_reuse() {
    let dir = std::env::temp_dir()
        .join("oasis-cli-task-test")
        .join(format!("run-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("pts.csv");
    let labels = dir.join("y.csv");
    let pred = dir.join("pred.csv");
    let model = dir.join("model.oasis");
    let tasked = dir.join("tasked.oasis");

    let mut text = String::new();
    let mut ytext = String::new();
    for i in 0..60 {
        text.push_str(&format!(
            "{},{}\n",
            (i % 10) as f64 * 0.37,
            (i / 10) as f64 * 0.81
        ));
        ytext.push_str(&format!("{}\n", i % 2));
    }
    std::fs::write(&csv, text).unwrap();
    std::fs::write(&labels, ytext).unwrap();
    std::fs::write(&pred, "0.5,0.5\n1.8,2.4\n").unwrap();

    let (_, stderr, ok) = run(&[
        "approximate",
        "--data",
        csv.to_str().unwrap(),
        "--cols",
        "14",
        "--method",
        "oasis",
        "--save",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");

    // the dataset is not needed for the task — only the artifact
    std::fs::remove_file(&csv).unwrap();

    let fit = |extra: &[&str]| {
        let mut argv = vec![
            "task",
            "--task",
            "krr",
            "--load",
            model.to_str().unwrap(),
            "--labels",
            labels.to_str().unwrap(),
            "--ridge",
            "0.001",
            "--predict",
            pred.to_str().unwrap(),
            "--json",
        ];
        argv.extend_from_slice(extra);
        run(&argv)
    };
    let (out1, stderr, ok) = fit(&[]);
    assert!(ok, "stderr: {stderr}");
    let line = out1.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"task\":\"krr\""), "{line}");
    assert!(line.contains("\"train_rmse\":"), "{line}");
    assert!(line.contains("\"predictions\":["), "{line}");
    // deterministic across invocations
    let (out2, _, _) = fit(&[]);
    assert_eq!(out1, out2, "task predictions must be deterministic");

    // persist the fitted model into the artifact, then reuse it with no
    // labels at all
    let (_, stderr, ok) = fit(&["--save", tasked.to_str().unwrap()]);
    assert!(ok, "stderr: {stderr}");
    assert!(stderr.contains("saved artifact with task model"), "{stderr}");
    std::fs::remove_file(&labels).unwrap();
    let (out3, stderr, ok) = run(&[
        "task",
        "--task",
        "krr",
        "--load",
        tasked.to_str().unwrap(),
        "--predict",
        pred.to_str().unwrap(),
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let stored_line =
        out3.lines().find(|l| l.starts_with('{')).expect("json line");
    let preds_of = |l: &str| {
        l.split("\"predictions\":")
            .nth(1)
            .map(str::to_string)
            .expect("predictions present")
    };
    assert_eq!(
        preds_of(line),
        preds_of(stored_line),
        "stored-model predictions diverged from the fresh fit"
    );

    // krr without labels and without a stored model is a clear error
    let (_, stderr, ok) = run(&[
        "task",
        "--task",
        "krr",
        "--load",
        model.to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(stderr.contains("--labels"), "{stderr}");

    // kpca and cluster run label-free from the artifact
    for (task, needle) in
        [("kpca", "\"eigenvalues\":"), ("cluster", "\"clusters\":")]
    {
        let (out, stderr, ok) = run(&[
            "task",
            "--task",
            task,
            "--load",
            model.to_str().unwrap(),
            "--json",
        ]);
        assert!(ok, "{task} failed: {stderr}");
        let l = out.lines().find(|l| l.starts_with('{')).expect("json line");
        assert!(l.contains(needle), "{task}: {l}");
    }

    std::fs::remove_dir_all(&dir).ok();
}

/// `--save-f32` writes a smaller artifact that still answers queries.
#[test]
fn approximate_save_f32_roundtrip() {
    let dir = std::env::temp_dir()
        .join("oasis-cli-f32-test")
        .join(format!("run-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let wide = dir.join("wide.oasis");
    let slim = dir.join("slim.oasis");
    for (flag, path) in [(false, &wide), (true, &slim)] {
        let mut argv = vec![
            "approximate",
            "--dataset",
            "two-moons",
            "--n",
            "200",
            "--cols",
            "30",
            "--method",
            "oasis",
            "--save",
            path.to_str().unwrap(),
        ];
        if flag {
            argv.push("--save-f32");
        }
        let (_, stderr, ok) = run(&argv);
        assert!(ok, "stderr: {stderr}");
    }
    let (wlen, slen) = (
        std::fs::metadata(&wide).unwrap().len(),
        std::fs::metadata(&slim).unwrap().len(),
    );
    assert!(slen < wlen, "f32 artifact not smaller: {slen} vs {wlen}");
    let (stdout, stderr, ok) = run(&[
        "query",
        "--load",
        slim.to_str().unwrap(),
        "--points",
        "0.5,0.2",
        "--targets",
        "0,100",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("g(0)="), "{stdout}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn query_without_load_errors() {
    let (_, stderr, ok) = run(&["query"]);
    assert!(!ok);
    assert!(stderr.contains("--load"), "{stderr}");
}

#[test]
fn unknown_method_errors() {
    let (_, stderr, ok) = run(&[
        "approximate",
        "--n",
        "100",
        "--method",
        "magic",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown method"));
}

#[test]
fn parallel_runs_and_reports_comm() {
    let (stdout, stderr, ok) = run(&[
        "parallel",
        "--dataset",
        "two-moons",
        "--n",
        "500",
        "--cols",
        "30",
        "--workers",
        "4",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("oASIS-P"), "{stdout}");
    assert!(stdout.contains("bcast"), "{stdout}");
}

/// `export` writes a binary matrix file that `parallel --shard-reads`
/// accepts, `--save` persists the distributed result, and `query` serves
/// it dataset-free — the single-machine slice of the multi-node story.
#[test]
fn export_then_shard_read_parallel_and_save() {
    let dir = std::env::temp_dir()
        .join("oasis-cli-export-test")
        .join(format!("run-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mat = dir.join("points.mat");
    let model = dir.join("dist.oasis");

    let (stdout, stderr, ok) = run(&[
        "export",
        "--dataset",
        "two-moons",
        "--n",
        "160",
        "--out",
        mat.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("wrote 160 points"), "{stdout}");
    assert!(mat.is_file());

    let (stdout, stderr, ok) = run(&[
        "parallel",
        "--data",
        mat.to_str().unwrap(),
        "--shard-reads",
        "--sigma",
        "0.6",
        "--workers",
        "2",
        "--cols",
        "16",
        "--save",
        model.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("error_est="), "{stdout}");
    assert!(stderr.contains("saved artifact"), "{stderr}");

    let (stdout, stderr, ok) = run(&["query", "--load", model.to_str().unwrap()]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("k=16"), "{stdout}");
    assert!(stdout.contains("method=oasis-p"), "{stdout}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn export_without_out_errors() {
    let (_, stderr, ok) = run(&["export", "--dataset", "two-moons", "--n", "50"]);
    assert!(!ok);
    assert!(stderr.contains("--out"), "{stderr}");
}

#[test]
fn worker_without_join_errors() {
    let (_, stderr, ok) = run(&["worker"]);
    assert!(!ok);
    assert!(stderr.contains("--join"), "{stderr}");
}

#[test]
fn help_mentions_new_subcommands() {
    let (stdout, _, ok) = run(&[]);
    assert!(ok);
    for needle in ["worker", "export", "--listen", "--merge-batch", "--join"] {
        assert!(stdout.contains(needle), "help lost {needle}");
    }
}

#[test]
fn seed_subcommand_runs() {
    let (stdout, stderr, ok) = run(&[
        "seed",
        "--dataset",
        "mnist",
        "--n",
        "150",
        "--dict",
        "20",
        "--sparsity",
        "4",
        "--clusters",
        "3",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("SEED:"), "{stdout}");
    assert!(stdout.contains("cluster sizes"), "{stdout}");
}

#[test]
fn info_reports_platform() {
    let (stdout, _, ok) = run(&["info"]);
    assert!(ok);
    // either artifacts are present (manifest list) or a clear message
    assert!(
        stdout.contains("artifacts") || stdout.contains("manifest"),
        "{stdout}"
    );
}
