//! CLI smoke tests: drive the `oasis` binary end to end via
//! `CARGO_BIN_EXE_oasis` (cargo builds it for integration tests).

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_oasis"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_prints_usage() {
    let (stdout, _, ok) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("approximate"));
    assert!(stdout.contains("parallel"));
}

#[test]
fn approximate_oasis_small() {
    let (stdout, stderr, ok) = run(&[
        "approximate",
        "--dataset",
        "two-moons",
        "--n",
        "300",
        "--cols",
        "40",
        "--method",
        "oasis",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("method=oasis"), "{stdout}");
    assert!(stdout.contains("error="), "{stdout}");
    // deterministic: same invocation gives the same error line
    let (stdout2, _, _) = run(&[
        "approximate",
        "--dataset",
        "two-moons",
        "--n",
        "300",
        "--cols",
        "40",
        "--method",
        "oasis",
    ]);
    let line = |s: &str| {
        s.lines()
            .find(|l| l.contains("error="))
            .unwrap()
            .split("select_time")
            .next()
            .unwrap()
            .to_string()
    };
    assert_eq!(line(&stdout), line(&stdout2));
}

#[test]
fn approximate_all_methods_run() {
    for m in ["random", "kmeans", "farahat", "leverage"] {
        let (stdout, stderr, ok) = run(&[
            "approximate",
            "--dataset",
            "abalone",
            "--n",
            "200",
            "--cols",
            "20",
            "--method",
            m,
        ]);
        assert!(ok, "method {m} failed: {stderr}");
        assert!(stdout.contains(&format!("method={m}")), "{stdout}");
    }
}

#[test]
fn approximate_json_output_parses() {
    let (stdout, stderr, ok) = run(&[
        "approximate",
        "--dataset",
        "two-moons",
        "--n",
        "300",
        "--cols",
        "40",
        "--method",
        "oasis",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let line = stdout.lines().find(|l| l.starts_with('{')).expect("json line");
    // keys promised to downstream tooling: method, k, error, secs
    for key in ["\"method\"", "\"k\"", "\"error\"", "\"secs\"", "\"stop\""] {
        assert!(line.contains(key), "missing {key} in {line}");
    }
    assert!(line.contains("\"method\":\"oasis\""), "{line}");
    assert!(line.contains("\"k\":40"), "{line}");
}

#[test]
fn approximate_target_err_stops_early() {
    let (stdout, stderr, ok) = run(&[
        "approximate",
        "--dataset",
        "two-moons",
        "--n",
        "400",
        "--cols",
        "200",
        "--method",
        "oasis",
        "--target-err",
        "0.5",
        "--json",
    ]);
    assert!(ok, "stderr: {stderr}");
    let line = stdout.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"stop\":\"error-target\""), "{line}");
    // k was parsed back out below the budget
    let k: f64 = line
        .split("\"k\":")
        .nth(1)
        .and_then(|s| s.split([',', '}']).next())
        .unwrap()
        .parse()
        .unwrap();
    assert!(k < 200.0, "expected early stop, k = {k}");
}

#[test]
fn unknown_method_errors() {
    let (_, stderr, ok) = run(&[
        "approximate",
        "--n",
        "100",
        "--method",
        "magic",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown method"));
}

#[test]
fn parallel_runs_and_reports_comm() {
    let (stdout, stderr, ok) = run(&[
        "parallel",
        "--dataset",
        "two-moons",
        "--n",
        "500",
        "--cols",
        "30",
        "--workers",
        "4",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("oASIS-P"), "{stdout}");
    assert!(stdout.contains("bcast"), "{stdout}");
}

#[test]
fn seed_subcommand_runs() {
    let (stdout, stderr, ok) = run(&[
        "seed",
        "--dataset",
        "mnist",
        "--n",
        "150",
        "--dict",
        "20",
        "--sparsity",
        "4",
        "--clusters",
        "3",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("SEED:"), "{stdout}");
    assert!(stdout.contains("cluster sizes"), "{stdout}");
}

#[test]
fn info_reports_platform() {
    let (stdout, _, ok) = run(&["info"]);
    assert!(ok);
    // either artifacts are present (manifest list) or a clear message
    assert!(
        stdout.contains("artifacts") || stdout.contains("manifest"),
        "{stdout}"
    );
}
