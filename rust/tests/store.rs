//! Integration tests for the persistence layer: artifact save→load
//! round trips through real files, clean rejection of damaged files, and
//! the file-backed dataset path — including the acceptance check that a
//! CSV-loaded dataset drives the *same* oASIS selection sequence as the
//! equivalent inline-points dataset.

use oasis::data::generators::two_moons;
use oasis::data::{loader, Dataset, LoadLimits};
use oasis::kernels::{Gaussian, Kernel};
use oasis::nystrom::{Provenance, StoredArtifact};
use oasis::sampling::{
    oasis::Oasis, run_to_completion, ImplicitOracle, SamplerSession,
    StoppingRule,
};
use std::path::PathBuf;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("oasis-store-integration").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run an oASIS session to `cols` columns and return the snapshot plus
/// its inputs.
fn run_oasis(ds: &Dataset, cols: usize) -> (oasis::nystrom::NystromApprox, Gaussian) {
    let kernel = Gaussian::with_sigma_fraction(ds, 0.05);
    let approx = {
        let oracle = ImplicitOracle::new(ds, &kernel);
        let mut session = Oasis::new(cols, 5, 1e-12, 7).session(&oracle).unwrap();
        run_to_completion(&mut session, &StoppingRule::budget(cols)).unwrap();
        session.snapshot().unwrap()
    };
    (approx, kernel)
}

/// ACCEPTANCE: a saved approximation reloads bit-identically — indices,
/// both factor matrices, and the extension weights it produces for a
/// query point — and answers queries without the original dataset.
#[test]
fn artifact_file_round_trip_is_bit_identical() {
    let dir = tmp_dir("roundtrip");
    let ds = two_moons(300, 0.05, 42);
    let (approx, kernel) = run_oasis(&ds, 40);
    let artifact = StoredArtifact::from_parts(
        approx,
        &ds,
        &kernel,
        Provenance { source: "generator:two-moons".into(), method: "oASIS".into() },
        Some(0.01),
    )
    .unwrap();

    let path = dir.join("model.oasis");
    artifact.save(&path).unwrap();
    let loaded = StoredArtifact::load(&path).unwrap();

    // indices and factors: bit-identical
    assert_eq!(loaded.approx.indices, artifact.approx.indices);
    for (a, b) in artifact.approx.c.data.iter().zip(&loaded.approx.c.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "C diverged");
    }
    for (a, b) in artifact.approx.winv.data.iter().zip(&loaded.approx.winv.data)
    {
        assert_eq!(a.to_bits(), b.to_bits(), "W⁻¹ diverged");
    }

    // extension weights from the loaded artifact (which never sees `ds`)
    // match the live-oracle path exactly
    let z = [0.35, -0.1];
    let stored_w = loaded.query_weights(&z).unwrap();
    let b: Vec<f64> = artifact
        .approx
        .indices
        .iter()
        .map(|&j| kernel.eval(&z, ds.point(j)))
        .collect();
    let live_w = artifact.approx.extension_weights(&b);
    assert_eq!(stored_w.len(), live_w.len());
    for (a, b) in stored_w.iter().zip(&live_w) {
        assert_eq!(a.to_bits(), b.to_bits(), "extension weights diverged");
    }
    let vals = loaded.extend(&stored_w, &[0, 150, 299]).unwrap();
    for (v, &t) in vals.iter().zip(&[0usize, 150, 299]) {
        assert_eq!(
            v.to_bits(),
            artifact.approx.extend_entry(&live_w, t).to_bits(),
            "ĝ(z, {t}) diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Damaged files must be rejected with a clear error, not garbage data:
/// flipped payload bytes, truncation at several byte counts, and a
/// future format version.
#[test]
fn damaged_artifact_files_rejected() {
    let dir = tmp_dir("damage");
    let ds = two_moons(80, 0.05, 3);
    let (approx, kernel) = run_oasis(&ds, 12);
    let artifact = StoredArtifact::from_parts(
        approx,
        &ds,
        &kernel,
        Provenance { source: "t".into(), method: "oASIS".into() },
        None,
    )
    .unwrap();
    let path = dir.join("good.oasis");
    artifact.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // truncations at several depths: inside magic, header, and payload
    for keep in [3usize, 20, good.len() / 2, good.len() - 1] {
        let p = dir.join(format!("trunc-{keep}.oasis"));
        std::fs::write(&p, &good[..keep]).unwrap();
        assert!(
            StoredArtifact::load(&p).is_err(),
            "truncation to {keep} bytes was accepted"
        );
    }

    // one flipped bit deep in the payload → checksum failure
    let mut corrupt = good.clone();
    let at = good.len() - good.len() / 4;
    corrupt[at] ^= 0x10;
    let p = dir.join("corrupt.oasis");
    std::fs::write(&p, &corrupt).unwrap();
    let err = StoredArtifact::load(&p).unwrap_err();
    assert!(format!("{err}").contains("checksum"), "{err}");

    // future version
    let text = String::from_utf8_lossy(&good).into_owned();
    let bumped = text.replacen("\"version\":1", "\"version\":7", 1);
    let p = dir.join("future.oasis");
    std::fs::write(&p, bumped.as_bytes()).unwrap();
    let err = StoredArtifact::load(&p).unwrap_err();
    assert!(format!("{err}").contains("version 7"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

/// ACCEPTANCE: a dataset loaded from CSV selects the *same columns in
/// the same order* as the equivalent inline-points dataset (both sides
/// parse decimal text with the same `str::parse::<f64>`, so the values
/// — and therefore the whole selection sequence — are bit-identical).
#[test]
fn csv_dataset_reproduces_inline_selection_sequence() {
    let dir = tmp_dir("csv-vs-inline");
    let ds = two_moons(200, 0.05, 9);

    // one canonical decimal rendering, consumed by both paths
    let csv_path = dir.join("points.csv");
    loader::save_csv(&csv_path, &ds).unwrap();
    let csv_text = std::fs::read_to_string(&csv_path).unwrap();

    // "inline" path: parse each field back exactly as a JSON request
    // parser would (str::parse::<f64>)
    let rows: Vec<Vec<f64>> = csv_text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.split(',').map(|f| f.trim().parse().unwrap()).collect())
        .collect();
    let inline_ds = Dataset::from_rows(rows);
    let file_ds = loader::load_dataset(&csv_path, &LoadLimits::unlimited()).unwrap();

    assert_eq!(inline_ds.n(), file_ds.n());
    assert_eq!(inline_ds.dim(), file_ds.dim());
    for (a, b) in inline_ds.flat().iter().zip(file_ds.flat()) {
        assert_eq!(a.to_bits(), b.to_bits(), "datasets diverged before sampling");
    }

    let select = |ds: &Dataset| -> Vec<usize> {
        let kernel = Gaussian::with_sigma_fraction(ds, 0.05);
        let oracle = ImplicitOracle::new(ds, &kernel);
        let mut s = Oasis::new(30, 5, 1e-12, 11).session(&oracle).unwrap();
        run_to_completion(&mut s, &StoppingRule::budget(30)).unwrap();
        s.indices().to_vec()
    };
    assert_eq!(
        select(&inline_ds),
        select(&file_ds),
        "oASIS selection diverged between inline and CSV-loaded data"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The binary matrix format round-trips through shard loads: each
/// worker's block read straight off the file equals the in-memory shard.
#[test]
fn binary_file_shards_feed_oasis_p_blocks() {
    let dir = tmp_dir("bin-shards");
    let ds = two_moons(91, 0.05, 5);
    let path = dir.join("points.mat");
    loader::save_matrix(&path, &ds).unwrap();
    let p = 3;
    let shards: Vec<_> = (0..p)
        .map(|w| loader::load_shard(&path, w, p, &LoadLimits::unlimited()).unwrap())
        .collect();
    let total: usize = shards.iter().map(|s| s.len()).sum();
    assert_eq!(total, ds.n());
    for s in &shards {
        for l in 0..s.len() {
            let want = ds.point(s.start + l);
            let got = s.points.point(l);
            for (a, b) in want.iter().zip(got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Artifact saves are atomic: re-saving renames over the existing file
/// (readers racing a save can never observe a truncated artifact), no
/// temp files are left behind, and a save into a directory that does not
/// exist is a clean error that leaves nothing on disk.
#[test]
fn artifact_save_is_atomic() {
    let dir = tmp_dir("atomic-save");
    let ds = two_moons(120, 0.05, 9);
    let (approx, kernel) = run_oasis(&ds, 20);
    let artifact = StoredArtifact::from_parts(
        approx,
        &ds,
        &kernel,
        Provenance { source: "generator:two-moons".into(), method: "oASIS".into() },
        None,
    )
    .unwrap();

    let path = dir.join("model.oasis");
    artifact.save(&path).unwrap();
    // overwrite in place: the rename replaces the old file atomically
    let bytes = artifact.save(&path).unwrap();
    assert_eq!(std::fs::metadata(&path).unwrap().len(), bytes as u64);
    let back = StoredArtifact::load(&path).unwrap();
    assert_eq!(back.approx.indices, artifact.approx.indices);

    // no temp residue in the destination directory
    let stray: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".tmp"))
        .collect();
    assert!(stray.is_empty(), "stray temp files: {stray:?}");

    // missing directory: clean error, nothing created
    let absent = dir.join("absent-dir").join("model.oasis");
    assert!(artifact.save(&absent).is_err());
    assert!(!absent.exists());
    std::fs::remove_dir_all(&dir).ok();
}
