//! Socket-level integration tests for `oasis serve`: a real
//! `TcpListener` on an ephemeral port, raw HTTP/1.1 requests over
//! `TcpStream`, and JSON assertions via the crate's own parser.
//!
//! The headline acceptance criterion lives in
//! [`concurrent_sessions_mid_run_snapshot_matches_offline_prefix`]: two
//! sessions created over the socket, stepped interleaved, and a mid-run
//! snapshot whose selected indices (and factor matrices) are
//! bit-identical to an equivalent offline `run_to_completion` prefix.

use oasis::data::generators::two_moons;
use oasis::kernels::{Gaussian, Kernel};
use oasis::sampling::{
    oasis::Oasis, run_to_completion, ImplicitOracle, SamplerSession,
    StoppingRule,
};
use oasis::server::http::client_request;
use oasis::server::Server;
use oasis::util::json::Json;
use std::net::SocketAddr;
use std::time::{Duration, Instant};

fn start_server() -> (SocketAddr, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run().expect("server run"));
    (addr, join)
}

fn stop_server(addr: SocketAddr, join: std::thread::JoinHandle<()>) {
    let (status, _) = request(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    join.join().expect("server thread");
}

/// One HTTP exchange on a fresh connection; returns (status, JSON body).
fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let (status, raw) =
        client_request(addr, method, path, body).expect("http exchange");
    let json = Json::parse(&raw)
        .unwrap_or_else(|e| panic!("bad JSON body {e}: {raw}"));
    (status, json)
}

fn usize_field(j: &Json, key: &str) -> usize {
    j.get(key)
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("missing usize '{key}' in {j}"))
}

fn indices_of(j: &Json) -> Vec<usize> {
    j.get("indices")
        .and_then(Json::as_arr)
        .expect("indices array")
        .iter()
        .map(|v| v.as_usize().expect("index"))
        .collect()
}

/// ACCEPTANCE: ≥2 concurrent sessions over a real socket, interleaved
/// steps, and a mid-run snapshot bit-identical to the equivalent offline
/// `run_to_completion` prefix.
#[test]
fn concurrent_sessions_mid_run_snapshot_matches_offline_prefix() {
    let (addr, join) = start_server();

    let create = |name: &str, sampler_seed: u64| {
        format!(
            r#"{{"name":"{name}",
                 "dataset":{{"generator":"two-moons","n":400,"seed":42,"noise":0.05}},
                 "kernel":{{"type":"gaussian","sigma_fraction":0.05}},
                 "method":"oasis","max_cols":60,"init_cols":5,
                 "tol":1e-12,"seed":{sampler_seed}}}"#
        )
    };
    let (status, j) = request(addr, "POST", "/sessions", &create("a", 7));
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 5, "seed columns at create");
    let (status, j) = request(addr, "POST", "/sessions", &create("b", 9));
    assert_eq!(status, 200, "{j}");

    // interleave stepping across the two live sessions
    for (name, steps) in [("a", 7), ("b", 5), ("a", 8), ("b", 10)] {
        let (status, j) = request(
            addr,
            "POST",
            &format!("/sessions/{name}/step"),
            &format!(r#"{{"steps":{steps}}}"#),
        );
        assert_eq!(status, 200, "{j}");
        assert_eq!(usize_field(&j, "stepped"), steps, "{j}");
    }

    // mid-run snapshot of "a" at k = 5 + 15 = 20, with factors
    let (status, snap) =
        request(addr, "GET", "/sessions/a/snapshot?factors=1", "");
    assert_eq!(status, 200, "{snap}");
    assert_eq!(usize_field(&snap, "k"), 20);

    // equivalent offline run: same dataset, kernel, and sampler params
    let ds = two_moons(400, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let mut offline = Oasis::new(60, 5, 1e-12, 7).session(&oracle).unwrap();
    run_to_completion(&mut offline, &StoppingRule::budget(20)).unwrap();
    let reference = offline.snapshot().unwrap();

    assert_eq!(
        indices_of(&snap),
        reference.indices,
        "server selection diverged from the offline run"
    );
    // factor matrices survive the JSON round-trip exactly (shortest
    // round-trip f64 formatting), so compare by value
    for (key, want) in [("c", &reference.c), ("winv", &reference.winv)] {
        let m = snap.get(key).unwrap_or_else(|| panic!("missing {key}"));
        assert_eq!(usize_field(m, "rows"), want.rows);
        assert_eq!(usize_field(m, "cols"), want.cols);
        let data = m.get("data").and_then(Json::as_arr).expect("data");
        assert_eq!(data.len(), want.data.len());
        for (i, (got, want)) in data.iter().zip(&want.data).enumerate() {
            assert_eq!(
                got.as_f64().expect("number"),
                *want,
                "{key}[{i}] diverged"
            );
        }
    }

    // the snapshot did not disturb the run: continue "a" to k = 30 and
    // compare against the continued offline session
    let (status, j) = request(addr, "POST", "/sessions/a/step", r#"{"budget":30}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 30);
    assert_eq!(j.get("stop").and_then(Json::as_str), Some("budget"));
    run_to_completion(&mut offline, &StoppingRule::budget(30)).unwrap();
    let (_, snap2) = request(addr, "GET", "/sessions/a/snapshot", "");
    assert_eq!(indices_of(&snap2), offline.indices());

    // session "b" ran concurrently and was not affected
    let (status, jb) = request(addr, "GET", "/sessions/b", "");
    assert_eq!(status, 200);
    assert_eq!(usize_field(&jb, "k"), 20);

    // finish both (one via POST …/finish, one via DELETE), registry empties
    let (status, jf) = request(addr, "POST", "/sessions/a/finish", "");
    assert_eq!(status, 200, "{jf}");
    assert_eq!(jf.get("final").and_then(Json::as_bool), Some(true));
    assert_eq!(usize_field(&jf, "k"), 30);
    let (status, _) = request(addr, "DELETE", "/sessions/b", "");
    assert_eq!(status, 200);
    let (_, jl) = request(addr, "GET", "/sessions", "");
    assert_eq!(jl.get("sessions").and_then(Json::as_arr).unwrap().len(), 0);

    stop_server(addr, join);
}

/// Stopping-rule composition over the wire: a loose error target ends the
/// batch before the steps cap; protocol errors map to clean status codes.
#[test]
fn step_rules_and_error_statuses() {
    let (addr, join) = start_server();
    let create = r#"{"name":"r",
        "dataset":{"generator":"two-moons","n":300,"seed":1},
        "method":"oasis","max_cols":200,"init_cols":5}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");

    let (status, j) = request(
        addr,
        "POST",
        "/sessions/r/step",
        r#"{"steps":150,"target_err":0.5,"deadline_ms":60000}"#,
    );
    assert_eq!(status, 200, "{j}");
    assert_eq!(j.get("stop").and_then(Json::as_str), Some("error-target"));
    assert!(j.get("error_estimate").and_then(Json::as_f64).unwrap() <= 0.5);
    assert!(usize_field(&j, "k") < 155, "{j}");

    // status codes: 404 unknown session/endpoint, 400 bad payloads,
    // 409 duplicate name
    assert_eq!(request(addr, "POST", "/sessions/nope/step", "{}").0, 404);
    assert_eq!(request(addr, "GET", "/nothing", "").0, 404);
    assert_eq!(request(addr, "POST", "/sessions", "{not json").0, 400);
    assert_eq!(
        request(addr, "POST", "/sessions", r#"{"method":"magic"}"#).0,
        400
    );
    assert_eq!(request(addr, "POST", "/sessions", r#"{"name":"r"}"#).0, 409);
    assert_eq!(
        request(addr, "POST", "/sessions/r/query", r#"{"points":[[1,2,3]]}"#).0,
        400,
        "dimension mismatch must 400"
    );

    stop_server(addr, join);
}

/// Background stepping, /metrics, and out-of-sample queries against the
/// live snapshot (checked against direct kernel evaluations).
#[test]
fn background_steps_metrics_and_queries() {
    let (addr, join) = start_server();

    // deterministic inline dataset: 12 well-separated 2-D points
    let pts: Vec<Vec<f64>> = (0..12)
        .map(|i| vec![(i % 4) as f64 * 0.9, (i / 4) as f64 * 1.1])
        .collect();
    let pts_json = format!(
        "[{}]",
        pts.iter()
            .map(|p| format!("[{},{}]", p[0], p[1]))
            .collect::<Vec<_>>()
            .join(",")
    );
    let create = format!(
        r#"{{"name":"q","dataset":{{"points":{pts_json}}},
            "kernel":{{"type":"gaussian","sigma":1.0}},
            "method":"oasis","max_cols":12,"init_cols":2,"tol":1e-14,"seed":3}}"#
    );
    let (status, j) = request(addr, "POST", "/sessions", &create);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "n"), 12);
    assert_eq!(usize_field(&j, "dim"), 2);

    // background batch: 202 now, progress visible via status polling
    let (status, j) = request(
        addr,
        "POST",
        "/sessions/q/step",
        r#"{"steps":5,"background":true}"#,
    );
    assert_eq!(status, 202, "{j}");
    assert_eq!(j.get("accepted").and_then(Json::as_bool), Some(true));
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, s) = request(addr, "GET", "/sessions/q", "");
        let done = usize_field(&s, "steps_done") >= 5
            && s.get("busy").and_then(Json::as_bool) == Some(false);
        if done {
            assert_eq!(usize_field(&s, "k"), 7); // 2 seeds + 5 background
            break;
        }
        assert!(Instant::now() < deadline, "background batch never finished");
        std::thread::sleep(Duration::from_millis(20));
    }

    // grow to full rank so the extension is exact, then query
    let (status, j) = request(addr, "POST", "/sessions/q/step", r#"{"steps":20}"#);
    assert_eq!(status, 200, "{j}");
    let (status, snap) = request(addr, "GET", "/sessions/q/snapshot", "");
    assert_eq!(status, 200);
    let k = usize_field(&snap, "k");
    assert!(k >= 11, "expected near-full rank, k = {k} ({snap})");

    let z = &pts[3];
    let query = format!(
        r#"{{"points":[[{},{}]],"targets":[0,5,11],"refresh":true}}"#,
        z[0], z[1]
    );
    let (status, jq) = request(addr, "POST", "/sessions/q/query", &query);
    assert_eq!(status, 200, "{jq}");
    assert_eq!(usize_field(&jq, "snapshot_k"), k);
    let results = jq.get("results").and_then(Json::as_arr).expect("results");
    assert_eq!(results.len(), 1);
    let weights = results[0].get("weights").and_then(Json::as_arr).unwrap();
    assert_eq!(weights.len(), k);
    let kernel_vals = results[0].get("kernel").and_then(Json::as_arr).unwrap();
    let g = Gaussian::new(1.0);
    for (t, &target) in [0usize, 5, 11].iter().enumerate() {
        let got = kernel_vals[t].as_f64().unwrap();
        let want = g.eval(&pts[target], z);
        assert!(
            (got - want).abs() < 1e-6,
            "ĝ(z, {target}) = {got}, want {want}"
        );
    }

    // /metrics reports the session with its step latencies and counters
    let (status, m) = request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert!(m.get("uptime_secs").and_then(Json::as_f64).unwrap() >= 0.0);
    let server = m.get("server").expect("server counters");
    assert!(usize_field(server, "sessions_created") >= 1);
    assert!(usize_field(server, "queries_total") >= 1);
    assert!(usize_field(server, "requests") >= 5);
    let sessions = m.get("sessions").and_then(Json::as_arr).unwrap();
    let q = sessions
        .iter()
        .find(|s| s.get("name").and_then(Json::as_str) == Some("q"))
        .expect("session q listed");
    assert!(usize_field(q, "steps_done") >= 5);
    let lat = q.get("step_latency").expect("latency stats");
    assert!(usize_field(lat, "count") >= 5);
    assert!(lat.get("mean_ms").and_then(Json::as_f64).unwrap() >= 0.0);

    // health endpoint and eviction
    assert_eq!(request(addr, "GET", "/healthz", "").0, 200);
    assert_eq!(request(addr, "DELETE", "/sessions/q", "").0, 200);
    assert_eq!(request(addr, "GET", "/sessions/q", "").0, 404);

    stop_server(addr, join);
}

/// The distributed oASIS-P method is hostable too, including its (new)
/// non-terminal snapshot gather.
#[test]
fn oasis_p_session_over_socket() {
    let (addr, join) = start_server();
    let create = r#"{"name":"p",
        "dataset":{"generator":"two-moons","n":200,"seed":5},
        "method":"oasis-p","max_cols":24,"init_cols":4,"workers":3,"seed":11}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");
    assert_eq!(j.get("method").and_then(Json::as_str), Some("oASIS-P"));

    let (status, j) = request(addr, "POST", "/sessions/p/step", r#"{"steps":8}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 12);

    let (status, snap) = request(addr, "GET", "/sessions/p/snapshot", "");
    assert_eq!(status, 200, "{snap}");
    assert_eq!(usize_field(&snap, "k"), 12);
    assert_eq!(indices_of(&snap).len(), 12);

    // keeps running after the snapshot, then finishes cleanly
    let (status, j) = request(addr, "POST", "/sessions/p/step", r#"{"budget":24}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 24);
    let (status, jf) = request(addr, "POST", "/sessions/p/finish", "");
    assert_eq!(status, 200, "{jf}");
    assert_eq!(usize_field(&jf, "k"), 24);

    stop_server(addr, join);
}
