//! Socket-level integration tests for `oasis serve`: a real
//! `TcpListener` on an ephemeral port, raw HTTP/1.1 requests over
//! `TcpStream`, and JSON assertions via the crate's own parser.
//!
//! The headline acceptance criterion lives in
//! [`concurrent_sessions_mid_run_snapshot_matches_offline_prefix`]: two
//! sessions created over the socket, stepped interleaved, and a mid-run
//! snapshot whose selected indices (and factor matrices) are
//! bit-identical to an equivalent offline `run_to_completion` prefix.

use oasis::data::generators::two_moons;
use oasis::data::loader;
use oasis::engine::{
    self, DatasetSpec, KernelSpec, Method, MethodSpec, RunSpec, SessionBuilder,
};
use oasis::kernels::{Gaussian, Kernel};
use oasis::sampling::{
    oasis::Oasis, run_to_completion, ImplicitOracle, SamplerSession,
    StoppingRule,
};
use oasis::server::http::{client_request, ClientConn};
use oasis::server::{Server, ServerConfig};
use oasis::util::json::Json;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn start_server() -> (SocketAddr, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run().expect("server run"));
    (addr, join)
}

/// Server whose client paths resolve under a private temp directory.
fn start_server_rooted(
    root: PathBuf,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let server = Server::bind_with(
        "127.0.0.1:0",
        ServerConfig { fs_root: root, ..Default::default() },
    )
    .expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run().expect("server run"));
    (addr, join)
}

fn stop_server(addr: SocketAddr, join: std::thread::JoinHandle<()>) {
    let (status, _) = request(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    join.join().expect("server thread");
}

/// One HTTP exchange on a fresh connection; returns (status, JSON body).
fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let (status, raw) =
        client_request(addr, method, path, body).expect("http exchange");
    let json = Json::parse(&raw)
        .unwrap_or_else(|e| panic!("bad JSON body {e}: {raw}"));
    (status, json)
}

fn usize_field(j: &Json, key: &str) -> usize {
    j.get(key)
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("missing usize '{key}' in {j}"))
}

fn indices_of(j: &Json) -> Vec<usize> {
    j.get("indices")
        .and_then(Json::as_arr)
        .expect("indices array")
        .iter()
        .map(|v| v.as_usize().expect("index"))
        .collect()
}

/// ACCEPTANCE: ≥2 concurrent sessions over a real socket, interleaved
/// steps, and a mid-run snapshot bit-identical to the equivalent offline
/// `run_to_completion` prefix.
#[test]
fn concurrent_sessions_mid_run_snapshot_matches_offline_prefix() {
    let (addr, join) = start_server();

    let create = |name: &str, sampler_seed: u64| {
        format!(
            r#"{{"name":"{name}",
                 "dataset":{{"generator":"two-moons","n":400,"seed":42,"noise":0.05}},
                 "kernel":{{"type":"gaussian","sigma_fraction":0.05}},
                 "method":"oasis","max_cols":60,"init_cols":5,
                 "tol":1e-12,"seed":{sampler_seed}}}"#
        )
    };
    let (status, j) = request(addr, "POST", "/sessions", &create("a", 7));
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 5, "seed columns at create");
    let (status, j) = request(addr, "POST", "/sessions", &create("b", 9));
    assert_eq!(status, 200, "{j}");

    // interleave stepping across the two live sessions
    for (name, steps) in [("a", 7), ("b", 5), ("a", 8), ("b", 10)] {
        let (status, j) = request(
            addr,
            "POST",
            &format!("/sessions/{name}/step"),
            &format!(r#"{{"steps":{steps}}}"#),
        );
        assert_eq!(status, 200, "{j}");
        assert_eq!(usize_field(&j, "stepped"), steps, "{j}");
    }

    // mid-run snapshot of "a" at k = 5 + 15 = 20, with factors
    let (status, snap) =
        request(addr, "GET", "/sessions/a/snapshot?factors=1", "");
    assert_eq!(status, 200, "{snap}");
    assert_eq!(usize_field(&snap, "k"), 20);

    // equivalent offline run: same dataset, kernel, and sampler params
    let ds = two_moons(400, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let mut offline = Oasis::new(60, 5, 1e-12, 7).session(&oracle).unwrap();
    run_to_completion(&mut offline, &StoppingRule::budget(20)).unwrap();
    let reference = offline.snapshot().unwrap();

    assert_eq!(
        indices_of(&snap),
        reference.indices,
        "server selection diverged from the offline run"
    );
    // factor matrices survive the JSON round-trip exactly (shortest
    // round-trip f64 formatting), so compare by value
    for (key, want) in [("c", &reference.c), ("winv", &reference.winv)] {
        let m = snap.get(key).unwrap_or_else(|| panic!("missing {key}"));
        assert_eq!(usize_field(m, "rows"), want.rows);
        assert_eq!(usize_field(m, "cols"), want.cols);
        let data = m.get("data").and_then(Json::as_arr).expect("data");
        assert_eq!(data.len(), want.data.len());
        for (i, (got, want)) in data.iter().zip(&want.data).enumerate() {
            assert_eq!(
                got.as_f64().expect("number"),
                *want,
                "{key}[{i}] diverged"
            );
        }
    }

    // the snapshot did not disturb the run: continue "a" to k = 30 and
    // compare against the continued offline session
    let (status, j) = request(addr, "POST", "/sessions/a/step", r#"{"budget":30}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 30);
    assert_eq!(j.get("stop").and_then(Json::as_str), Some("budget"));
    run_to_completion(&mut offline, &StoppingRule::budget(30)).unwrap();
    let (_, snap2) = request(addr, "GET", "/sessions/a/snapshot", "");
    assert_eq!(indices_of(&snap2), offline.indices());

    // session "b" ran concurrently and was not affected
    let (status, jb) = request(addr, "GET", "/sessions/b", "");
    assert_eq!(status, 200);
    assert_eq!(usize_field(&jb, "k"), 20);

    // finish both (one via POST …/finish, one via DELETE), registry empties
    let (status, jf) = request(addr, "POST", "/sessions/a/finish", "");
    assert_eq!(status, 200, "{jf}");
    assert_eq!(jf.get("final").and_then(Json::as_bool), Some(true));
    assert_eq!(usize_field(&jf, "k"), 30);
    let (status, _) = request(addr, "DELETE", "/sessions/b", "");
    assert_eq!(status, 200);
    let (_, jl) = request(addr, "GET", "/sessions", "");
    assert_eq!(jl.get("sessions").and_then(Json::as_arr).unwrap().len(), 0);

    stop_server(addr, join);
}

/// Stopping-rule composition over the wire: a loose error target ends the
/// batch before the steps cap; protocol errors map to clean status codes.
#[test]
fn step_rules_and_error_statuses() {
    let (addr, join) = start_server();
    let create = r#"{"name":"r",
        "dataset":{"generator":"two-moons","n":300,"seed":1},
        "method":"oasis","max_cols":200,"init_cols":5}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");

    let (status, j) = request(
        addr,
        "POST",
        "/sessions/r/step",
        r#"{"steps":150,"target_err":0.5,"deadline_ms":60000}"#,
    );
    assert_eq!(status, 200, "{j}");
    assert_eq!(j.get("stop").and_then(Json::as_str), Some("error-target"));
    assert!(j.get("error_estimate").and_then(Json::as_f64).unwrap() <= 0.5);
    assert!(usize_field(&j, "k") < 155, "{j}");

    // status codes: 404 unknown session/endpoint, 400 bad payloads,
    // 409 duplicate name
    assert_eq!(request(addr, "POST", "/sessions/nope/step", "{}").0, 404);
    assert_eq!(request(addr, "GET", "/nothing", "").0, 404);
    assert_eq!(request(addr, "POST", "/sessions", "{not json").0, 400);
    assert_eq!(
        request(addr, "POST", "/sessions", r#"{"method":"magic"}"#).0,
        400
    );
    assert_eq!(request(addr, "POST", "/sessions", r#"{"name":"r"}"#).0, 409);
    assert_eq!(
        request(addr, "POST", "/sessions/r/query", r#"{"points":[[1,2,3]]}"#).0,
        400,
        "dimension mismatch must 400"
    );

    stop_server(addr, join);
}

/// Background stepping, /metrics, and out-of-sample queries against the
/// live snapshot (checked against direct kernel evaluations).
#[test]
fn background_steps_metrics_and_queries() {
    let (addr, join) = start_server();

    // deterministic inline dataset: 12 well-separated 2-D points
    let pts: Vec<Vec<f64>> = (0..12)
        .map(|i| vec![(i % 4) as f64 * 0.9, (i / 4) as f64 * 1.1])
        .collect();
    let pts_json = format!(
        "[{}]",
        pts.iter()
            .map(|p| format!("[{},{}]", p[0], p[1]))
            .collect::<Vec<_>>()
            .join(",")
    );
    let create = format!(
        r#"{{"name":"q","dataset":{{"points":{pts_json}}},
            "kernel":{{"type":"gaussian","sigma":1.0}},
            "method":"oasis","max_cols":12,"init_cols":2,"tol":1e-14,"seed":3}}"#
    );
    let (status, j) = request(addr, "POST", "/sessions", &create);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "n"), 12);
    assert_eq!(usize_field(&j, "dim"), 2);

    // background batch: 202 now, progress visible via status polling
    let (status, j) = request(
        addr,
        "POST",
        "/sessions/q/step",
        r#"{"steps":5,"background":true}"#,
    );
    assert_eq!(status, 202, "{j}");
    assert_eq!(j.get("accepted").and_then(Json::as_bool), Some(true));
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, s) = request(addr, "GET", "/sessions/q", "");
        let done = usize_field(&s, "steps_done") >= 5
            && s.get("busy").and_then(Json::as_bool) == Some(false);
        if done {
            assert_eq!(usize_field(&s, "k"), 7); // 2 seeds + 5 background
            break;
        }
        assert!(Instant::now() < deadline, "background batch never finished");
        std::thread::sleep(Duration::from_millis(20));
    }

    // grow to full rank so the extension is exact, then query
    let (status, j) = request(addr, "POST", "/sessions/q/step", r#"{"steps":20}"#);
    assert_eq!(status, 200, "{j}");
    let (status, snap) = request(addr, "GET", "/sessions/q/snapshot", "");
    assert_eq!(status, 200);
    let k = usize_field(&snap, "k");
    assert!(k >= 11, "expected near-full rank, k = {k} ({snap})");

    let z = &pts[3];
    let query = format!(
        r#"{{"points":[[{},{}]],"targets":[0,5,11],"refresh":true}}"#,
        z[0], z[1]
    );
    let (status, jq) = request(addr, "POST", "/sessions/q/query", &query);
    assert_eq!(status, 200, "{jq}");
    assert_eq!(usize_field(&jq, "snapshot_k"), k);
    let results = jq.get("results").and_then(Json::as_arr).expect("results");
    assert_eq!(results.len(), 1);
    let weights = results[0].get("weights").and_then(Json::as_arr).unwrap();
    assert_eq!(weights.len(), k);
    let kernel_vals = results[0].get("kernel").and_then(Json::as_arr).unwrap();
    let g = Gaussian::new(1.0);
    for (t, &target) in [0usize, 5, 11].iter().enumerate() {
        let got = kernel_vals[t].as_f64().unwrap();
        let want = g.eval(&pts[target], z);
        assert!(
            (got - want).abs() < 1e-6,
            "ĝ(z, {target}) = {got}, want {want}"
        );
    }

    // /metrics reports the session with its step latencies and counters
    let (status, m) = request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert!(m.get("uptime_secs").and_then(Json::as_f64).unwrap() >= 0.0);
    let server = m.get("server").expect("server counters");
    assert!(usize_field(server, "sessions_created") >= 1);
    assert!(usize_field(server, "queries_total") >= 1);
    assert!(usize_field(server, "requests") >= 5);
    let sessions = m.get("sessions").and_then(Json::as_arr).unwrap();
    let q = sessions
        .iter()
        .find(|s| s.get("name").and_then(Json::as_str) == Some("q"))
        .expect("session q listed");
    assert!(usize_field(q, "steps_done") >= 5);
    let lat = q.get("step_latency").expect("latency stats");
    assert!(usize_field(lat, "count") >= 5);
    assert!(lat.get("mean_ms").and_then(Json::as_f64).unwrap() >= 0.0);

    // health endpoint and eviction
    assert_eq!(request(addr, "GET", "/healthz", "").0, 200);
    assert_eq!(request(addr, "DELETE", "/sessions/q", "").0, 200);
    assert_eq!(request(addr, "GET", "/sessions/q", "").0, 404);

    stop_server(addr, join);
}

/// ACCEPTANCE: full store-and-serve lifecycle over the socket — create a
/// session from a CSV *file*, grow it, persist it with
/// `POST /sessions/{name}/save`, host the saved artifact with
/// `POST /artifacts/load`, and get bit-identical answers from
/// `POST /artifacts/{name}/query` without the original dataset — plus
/// path-traversal rejection and artifact listing in `/metrics`.
#[test]
fn save_load_and_query_artifact_over_socket() {
    let root = std::env::temp_dir()
        .join("oasis-server-store-test")
        .join(format!("run-{}", std::process::id()));
    std::fs::create_dir_all(&root).unwrap();
    let (addr, join) = start_server_rooted(root.clone());

    // a CSV dataset inside the server's fs-root
    let ds = two_moons(150, 0.05, 21);
    loader::save_csv(&root.join("train.csv"), &ds).unwrap();

    let create = r#"{"name":"fs",
        "dataset":{"file":"train.csv"},
        "kernel":{"type":"gaussian","sigma":0.7},
        "method":"oasis","max_cols":30,"init_cols":4,"seed":13}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "n"), 150);
    assert_eq!(usize_field(&j, "dim"), 2);

    let (status, j) = request(addr, "POST", "/sessions/fs/step", r#"{"steps":16}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 20);

    // escaping the fs-root must 400 for both datasets and artifacts
    let (status, j) = request(
        addr,
        "POST",
        "/sessions",
        r#"{"dataset":{"file":"../outside.csv"}}"#,
    );
    assert_eq!(status, 400, "{j}");
    let (status, _) = request(
        addr,
        "POST",
        "/sessions/fs/save",
        r#"{"path":"/tmp/abs.oasis"}"#,
    );
    assert_eq!(status, 400, "absolute save path must be rejected");

    // persist the live session
    let (status, j) = request(
        addr,
        "POST",
        "/sessions/fs/save",
        r#"{"path":"models/fs.oasis"}"#,
    );
    // models/ does not exist: the server must not invent directories
    assert_eq!(status, 500, "{j}");
    let (status, j) =
        request(addr, "POST", "/sessions/fs/save", r#"{"path":"fs.oasis"}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 20);
    assert!(usize_field(&j, "bytes") > 0, "{j}");
    assert!(root.join("fs.oasis").is_file());

    // query the live session for reference answers
    let qbody = r#"{"points":[[0.4,0.1]],"targets":[0,75,149]}"#;
    let (status, live) = request(addr, "POST", "/sessions/fs/query", qbody);
    assert_eq!(status, 200, "{live}");

    // host the stored artifact and query it — the artifact never touches
    // the session, its dataset, or its oracle
    let (status, j) = request(
        addr,
        "POST",
        "/artifacts/load",
        r#"{"path":"fs.oasis","name":"fs-replica"}"#,
    );
    assert_eq!(status, 200, "{j}");
    assert_eq!(j.get("name").and_then(Json::as_str), Some("fs-replica"));
    assert_eq!(usize_field(&j, "k"), 20);
    assert_eq!(j.get("method").and_then(Json::as_str), Some("oASIS"));
    assert!(
        j.get("source").and_then(Json::as_str).unwrap().contains("train.csv"),
        "{j}"
    );
    // duplicate name → 409; corrupt file → 400
    assert_eq!(
        request(
            addr,
            "POST",
            "/artifacts/load",
            r#"{"path":"fs.oasis","name":"fs-replica"}"#
        )
        .0,
        409
    );
    std::fs::write(root.join("junk.oasis"), b"not an artifact").unwrap();
    assert_eq!(
        request(addr, "POST", "/artifacts/load", r#"{"path":"junk.oasis"}"#).0,
        400
    );

    let (status, stored) =
        request(addr, "POST", "/artifacts/fs-replica/query", qbody);
    assert_eq!(status, 200, "{stored}");
    assert_eq!(usize_field(&stored, "k"), 20);

    // bit-identical answers: weights and kernel values match the live
    // session query exactly (both travel as shortest-round-trip JSON)
    let result_of = |j: &Json| -> (Vec<f64>, Vec<f64>) {
        let r = &j.get("results").and_then(Json::as_arr).expect("results")[0];
        let nums = |key: &str| -> Vec<f64> {
            r.get(key)
                .and_then(Json::as_arr)
                .unwrap_or_else(|| panic!("missing {key} in {j}"))
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect()
        };
        (nums("weights"), nums("kernel"))
    };
    let (lw, lk) = result_of(&live);
    let (sw, sk) = result_of(&stored);
    assert_eq!(lw.len(), sw.len());
    for (a, b) in lw.iter().zip(&sw) {
        assert_eq!(a.to_bits(), b.to_bits(), "weights diverged");
    }
    for (a, b) in lk.iter().zip(&sk) {
        assert_eq!(a.to_bits(), b.to_bits(), "kernel values diverged");
    }

    // bad artifact queries map to clean statuses
    assert_eq!(
        request(addr, "POST", "/artifacts/fs-replica/query", r#"{"points":[[1]]}"#).0,
        400,
        "dimension mismatch"
    );
    assert_eq!(
        request(
            addr,
            "POST",
            "/artifacts/fs-replica/query",
            r#"{"points":[[0,0]],"targets":[150]}"#
        )
        .0,
        400,
        "target out of range"
    );
    assert_eq!(request(addr, "POST", "/artifacts/nope/query", qbody).0, 404);

    // listings: GET /artifacts, GET /artifacts/{name}, /metrics
    let (_, jl) = request(addr, "GET", "/artifacts", "");
    let arts = jl.get("artifacts").and_then(Json::as_arr).unwrap();
    assert_eq!(arts.len(), 1);
    // exactly one artifact query succeeded so far (the malformed ones
    // 400 before the counters are touched)
    let (_, js) = request(addr, "GET", "/artifacts/fs-replica", "");
    assert_eq!(usize_field(&js, "queries"), 1, "{js}");
    let (_, m) = request(addr, "GET", "/metrics", "");
    let marts = m.get("artifacts").and_then(Json::as_arr).unwrap();
    assert_eq!(marts.len(), 1);
    let server_counters = m.get("server").expect("server counters");
    assert!(usize_field(server_counters, "artifacts_saved") >= 1);
    assert!(usize_field(server_counters, "artifacts_loaded") >= 1);
    assert_eq!(usize_field(server_counters, "artifact_queries"), 1);

    // the artifact outlives its session: evict the session, query again
    assert_eq!(request(addr, "DELETE", "/sessions/fs", "").0, 200);
    let (status, again) =
        request(addr, "POST", "/artifacts/fs-replica/query", qbody);
    assert_eq!(status, 200, "{again}");
    let (aw, _) = result_of(&again);
    for (a, b) in lw.iter().zip(&aw) {
        assert_eq!(a.to_bits(), b.to_bits(), "post-eviction answers diverged");
    }

    // unload
    assert_eq!(request(addr, "DELETE", "/artifacts/fs-replica", "").0, 200);
    assert_eq!(request(addr, "GET", "/artifacts/fs-replica", "").0, 404);

    stop_server(addr, join);
    std::fs::remove_dir_all(&root).ok();
}

/// FRONT-END PARITY: the same `RunSpec`, once resolved through the
/// engine in-process (the CLI's path) and once shipped as a `POST
/// /sessions` payload (the server's path), yields bit-identical
/// selection sequences and factor matrices.
#[test]
fn engine_runspec_parity_cli_vs_server() {
    let (addr, join) = start_server();
    let (status, j) = request(
        addr,
        "POST",
        "/sessions",
        r#"{"name":"par",
            "dataset":{"generator":"two-moons","n":350,"seed":4},
            "kernel":{"type":"gaussian","sigma_fraction":0.05},
            "method":"oasis","max_cols":50,"init_cols":5,"tol":1e-12,"seed":11}"#,
    );
    assert_eq!(status, 200, "{j}");
    let (status, j) = request(addr, "POST", "/sessions/par/step", r#"{"budget":50}"#);
    assert_eq!(status, 200, "{j}");
    let (status, snap) =
        request(addr, "GET", "/sessions/par/snapshot?factors=1", "");
    assert_eq!(status, 200, "{snap}");

    // the identical spec, resolved in-process through the engine
    let spec = RunSpec {
        dataset: DatasetSpec::Generator {
            name: "two-moons".into(),
            n: 350,
            seed: 4,
            noise: 0.05,
            dim: 0,
        },
        kernel: KernelSpec::Gaussian { sigma: None, sigma_fraction: 0.05 },
        method: MethodSpec {
            method: Method::Oasis,
            max_cols: 50,
            init_cols: 5,
            tol: 1e-12,
            seed: 11,
            batch: 10,
            workers: 1,
            merge_batch: 1,
            listen: None,
        },
        stopping: engine::stopping_rule(50, None, None),
        shard_reads: false,
        warm_start: None,
    };
    let run = SessionBuilder::new().resolve(spec).unwrap();
    let slot = run.oracle_slot();
    let mut s = run.open_session(&slot).unwrap();
    run_to_completion(s.as_mut(), &run.stopping).unwrap();
    let reference = s.snapshot().unwrap();

    assert_eq!(indices_of(&snap), reference.indices, "selection diverged");
    for (key, want) in [("c", &reference.c), ("winv", &reference.winv)] {
        let m = snap.get(key).unwrap_or_else(|| panic!("missing {key}"));
        let data = m.get("data").and_then(Json::as_arr).expect("data");
        assert_eq!(data.len(), want.data.len());
        for (i, (got, want)) in data.iter().zip(&want.data).enumerate() {
            assert_eq!(got.as_f64().expect("number"), *want, "{key}[{i}]");
        }
    }
    stop_server(addr, join);
}

/// Warm start over the wire: a session saved mid-run seeds a fresh
/// session through the create option `{"warm_start": …}`; the warm
/// session resumes at the stored k, answers queries bit-identically to
/// the original, and continues selecting in lockstep with it.
#[test]
fn warm_start_create_resumes_from_artifact() {
    let root = std::env::temp_dir()
        .join("oasis-server-warm-test")
        .join(format!("run-{}", std::process::id()));
    std::fs::create_dir_all(&root).unwrap();
    let (addr, join) = start_server_rooted(root.clone());

    let ds = two_moons(120, 0.05, 33);
    loader::save_csv(&root.join("train.csv"), &ds).unwrap();

    let create = |name: &str, extra: &str| {
        format!(
            r#"{{"name":"{name}",
                "dataset":{{"file":"train.csv"}},
                "kernel":{{"type":"gaussian","sigma":0.7}},
                "method":"oasis","max_cols":30,"init_cols":4,"seed":13{extra}}}"#
        )
    };
    let (status, j) = request(addr, "POST", "/sessions", &create("w0", ""));
    assert_eq!(status, 200, "{j}");
    let (status, j) = request(addr, "POST", "/sessions/w0/step", r#"{"steps":14}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 18);
    let (status, j) =
        request(addr, "POST", "/sessions/w0/save", r#"{"path":"w.oasis"}"#);
    assert_eq!(status, 200, "{j}");

    // warm create resumes at the artifact's k…
    let (status, j) = request(
        addr,
        "POST",
        "/sessions",
        &create("w1", r#","warm_start":"w.oasis""#),
    );
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 18, "warm session starts at stored k");

    // …answers queries bit-identically to the session that saved it…
    let q = r#"{"points":[[0.3,-0.1]],"targets":[0,60,119],"refresh":true}"#;
    let result_of = |j: &Json| -> (Vec<f64>, Vec<f64>) {
        let r = &j.get("results").and_then(Json::as_arr).expect("results")[0];
        let nums = |key: &str| -> Vec<f64> {
            r.get(key)
                .and_then(Json::as_arr)
                .unwrap_or_else(|| panic!("missing {key} in {j}"))
                .iter()
                .map(|v| v.as_f64().unwrap())
                .collect()
        };
        (nums("weights"), nums("kernel"))
    };
    let (status, q0) = request(addr, "POST", "/sessions/w0/query", q);
    assert_eq!(status, 200, "{q0}");
    let (status, q1) = request(addr, "POST", "/sessions/w1/query", q);
    assert_eq!(status, 200, "{q1}");
    let ((w0w, w0k), (w1w, w1k)) = (result_of(&q0), result_of(&q1));
    for (a, b) in w0w.iter().zip(&w1w) {
        assert_eq!(a.to_bits(), b.to_bits(), "warm weights diverged");
    }
    for (a, b) in w0k.iter().zip(&w1k) {
        assert_eq!(a.to_bits(), b.to_bits(), "warm kernel values diverged");
    }

    // …and keeps selecting in lockstep with the original session
    for name in ["w0", "w1"] {
        let (status, j) = request(
            addr,
            "POST",
            &format!("/sessions/{name}/step"),
            r#"{"budget":30}"#,
        );
        assert_eq!(status, 200, "{j}");
        assert_eq!(usize_field(&j, "k"), 30);
    }
    let (_, s0) = request(addr, "GET", "/sessions/w0/snapshot", "");
    let (_, s1) = request(addr, "GET", "/sessions/w1/snapshot", "");
    assert_eq!(indices_of(&s0), indices_of(&s1), "continued selection diverged");

    // mismatched warm starts are clean 400s
    let (status, j) = request(
        addr,
        "POST",
        "/sessions",
        &create("w2", r#","warm_start":"missing.oasis""#),
    );
    assert_eq!(status, 400, "{j}");
    let bad_kernel = r#"{"name":"w3",
        "dataset":{"file":"train.csv"},
        "kernel":{"type":"gaussian","sigma":2.5},
        "method":"oasis","max_cols":30,"warm_start":"w.oasis"}"#;
    let (status, j) = request(addr, "POST", "/sessions", bad_kernel);
    assert_eq!(status, 400, "{j}");

    stop_server(addr, join);
    std::fs::remove_dir_all(&root).ok();
}

/// ACCEPTANCE: the downstream-task layer answers identically through
/// every front end — KRR predictions from the CLI's dataset-free
/// library path (`oasis task --load`), from the live session's
/// `POST /sessions/{name}/task`, and from the loaded artifact's
/// `POST /artifacts/{name}/task` are bit-identical for the same
/// approximation; repeated requests hit the fitted-model cache, and the
/// kpca/cluster tasks serve label-free.
#[test]
fn krr_task_parity_cli_live_artifact_over_socket() {
    let root = std::env::temp_dir()
        .join("oasis-server-task-test")
        .join(format!("run-{}", std::process::id()));
    std::fs::create_dir_all(&root).unwrap();
    let (addr, join) = start_server_rooted(root.clone());

    let n = 120;
    let ds = two_moons(n, 0.05, 27);
    loader::save_csv(&root.join("train.csv"), &ds).unwrap();
    let labels: Vec<Vec<f64>> = (0..n).map(|i| vec![(i % 2) as f64]).collect();
    loader::save_csv(
        &root.join("labels.csv"),
        &oasis::data::Dataset::from_rows(labels),
    )
    .unwrap();

    let create = r#"{"name":"t0",
        "dataset":{"file":"train.csv"},
        "kernel":{"type":"gaussian","sigma":0.7},
        "method":"oasis","max_cols":24,"init_cols":4,"seed":3}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");
    let (status, j) = request(addr, "POST", "/sessions/t0/step", r#"{"budget":24}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 24);

    // live-session task fit + predictions
    let task_body = r#"{"task":"krr","ridge":0.001,
        "labels_file":"labels.csv",
        "predict":[[0.3,0.1],[-0.5,0.4],[1.2,-0.3]]}"#;
    let (status, live) = request(addr, "POST", "/sessions/t0/task", task_body);
    assert_eq!(status, 200, "{live}");
    assert_eq!(live.get("task").and_then(Json::as_str), Some("krr"));
    assert_eq!(live.get("model").and_then(Json::as_str), Some("fitted"));
    assert_eq!(usize_field(&live, "k"), 24);
    assert!(live.get("train_rmse").and_then(Json::as_f64).is_some());
    let preds_of = |j: &Json| -> Vec<f64> {
        j.get("predictions")
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("missing predictions in {j}"))
            .iter()
            .map(|v| v.as_f64().expect("numeric prediction"))
            .collect()
    };
    let live_preds = preds_of(&live);
    assert_eq!(live_preds.len(), 3);

    // identical repeat → fitted-model cache
    let (status, again) = request(addr, "POST", "/sessions/t0/task", task_body);
    assert_eq!(status, 200, "{again}");
    assert_eq!(again.get("model").and_then(Json::as_str), Some("cached"));
    for (a, b) in live_preds.iter().zip(&preds_of(&again)) {
        assert_eq!(a.to_bits(), b.to_bits(), "cached predictions diverged");
    }
    // predict-only traffic: a krr request with no labels at all reuses
    // the fitted model (fit once, predict many)
    let (status, lf) = request(
        addr,
        "POST",
        "/sessions/t0/task",
        r#"{"task":"krr","predict":[[0.3,0.1]]}"#,
    );
    assert_eq!(status, 200, "{lf}");
    assert_eq!(lf.get("model").and_then(Json::as_str), Some("cached"));
    assert_eq!(
        preds_of(&lf)[0].to_bits(),
        live_preds[0].to_bits(),
        "label-free predict diverged from the fitted model"
    );

    // persist, host as an artifact, and ask the artifact endpoint
    let (status, j) =
        request(addr, "POST", "/sessions/t0/save", r#"{"path":"t.oasis"}"#);
    assert_eq!(status, 200, "{j}");
    let (status, j) = request(
        addr,
        "POST",
        "/artifacts/load",
        r#"{"path":"t.oasis","name":"t-rep"}"#,
    );
    assert_eq!(status, 200, "{j}");
    let (status, stored) = request(addr, "POST", "/artifacts/t-rep/task", task_body);
    assert_eq!(status, 200, "{stored}");
    assert_eq!(stored.get("model").and_then(Json::as_str), Some("fitted"));
    let stored_preds = preds_of(&stored);
    for (a, b) in live_preds.iter().zip(&stored_preds) {
        assert_eq!(a.to_bits(), b.to_bits(), "artifact predictions diverged");
    }
    // the artifact's second identical request is cached too
    let (_, j) = request(addr, "POST", "/artifacts/t-rep/task", task_body);
    assert_eq!(j.get("model").and_then(Json::as_str), Some("cached"));

    // the CLI's dataset-free library path: load the artifact file, fit
    // through the engine with the same labels file, predict the same
    // points — bit-identical to both endpoints
    let artifact =
        oasis::nystrom::StoredArtifact::load(&root.join("t.oasis")).unwrap();
    let mut spec = oasis::engine::TaskSpec::new(oasis::tasks::TaskKind::Krr);
    spec.ridge = 0.001;
    spec.labels = Some(oasis::engine::LabelsSpec {
        label: "labels.csv".into(),
        path: root.join("labels.csv"),
        cols: vec![0],
    });
    let cfg = SessionBuilder::new().resolve_task(&spec).unwrap();
    let fit = oasis::tasks::FittedTask::fit(&artifact.approx, &cfg).unwrap();
    let kernel = artifact.kernel.build();
    let cli_preds = match fit
        .model
        .predict(
            &*kernel,
            &artifact.selected_points,
            &[vec![0.3, 0.1], vec![-0.5, 0.4], vec![1.2, -0.3]],
        )
        .unwrap()
    {
        oasis::tasks::TaskPrediction::Values(v) => v,
        other => panic!("unexpected prediction {other:?}"),
    };
    for (a, b) in live_preds.iter().zip(&cli_preds) {
        assert_eq!(a.to_bits(), b.to_bits(), "CLI-path predictions diverged");
    }

    // label-free tasks serve over both endpoints
    let (status, jk) = request(
        addr,
        "POST",
        "/artifacts/t-rep/task",
        r#"{"task":"kpca","components":2,"predict":[[0.3,0.1]]}"#,
    );
    assert_eq!(status, 200, "{jk}");
    assert!(jk.get("eigenvalues").and_then(Json::as_arr).is_some());
    let (status, jc) = request(
        addr,
        "POST",
        "/sessions/t0/task",
        r#"{"task":"cluster","clusters":2,"predict":[[0.3,0.1]]}"#,
    );
    assert_eq!(status, 200, "{jc}");
    assert_eq!(usize_field(&jc, "clusters"), 2);

    // krr without labels on an artifact without a stored model → 400;
    // dimension mismatches → 400
    assert_eq!(
        request(addr, "POST", "/artifacts/t-rep/task", r#"{"task":"krr"}"#).0,
        400
    );
    assert_eq!(
        request(
            addr,
            "POST",
            "/sessions/t0/task",
            r#"{"task":"kpca","predict":[[1]]}"#
        )
        .0,
        400
    );

    // counters: fits, cache hits, and predictions all moved
    let (_, m) = request(addr, "GET", "/metrics", "");
    let server = m.get("server").expect("server counters");
    assert!(usize_field(server, "tasks_fitted") >= 4, "{m}");
    assert!(usize_field(server, "task_cache_hits") >= 2, "{m}");
    assert!(usize_field(server, "task_predictions") >= 8, "{m}");

    stop_server(addr, join);
    std::fs::remove_dir_all(&root).ok();
}

/// The distributed oASIS-P method is hostable too, including its (new)
/// non-terminal snapshot gather.
#[test]
fn oasis_p_session_over_socket() {
    let (addr, join) = start_server();
    let create = r#"{"name":"p",
        "dataset":{"generator":"two-moons","n":200,"seed":5},
        "method":"oasis-p","max_cols":24,"init_cols":4,"workers":3,"seed":11}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");
    assert_eq!(j.get("method").and_then(Json::as_str), Some("oASIS-P"));

    let (status, j) = request(addr, "POST", "/sessions/p/step", r#"{"steps":8}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 12);

    let (status, snap) = request(addr, "GET", "/sessions/p/snapshot", "");
    assert_eq!(status, 200, "{snap}");
    assert_eq!(usize_field(&snap, "k"), 12);
    assert_eq!(indices_of(&snap).len(), 12);

    // keeps running after the snapshot, then finishes cleanly
    let (status, j) = request(addr, "POST", "/sessions/p/step", r#"{"budget":24}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(usize_field(&j, "k"), 24);
    let (status, jf) = request(addr, "POST", "/sessions/p/finish", "");
    assert_eq!(status, 200, "{jf}");
    assert_eq!(usize_field(&jf, "k"), 24);

    stop_server(addr, join);
}

/// Observability surface over the socket: `/healthz` reports uptime and
/// build info, and the Prometheus rendering of `/metrics` passes the
/// exposition checker while carrying the per-endpoint request histograms
/// and per-session step histograms produced by real traffic.
#[test]
fn prometheus_exposition_and_healthz_over_socket() {
    let (addr, join) = start_server();

    let (status, h) = request(addr, "GET", "/healthz", "");
    assert_eq!(status, 200, "{h}");
    assert!(h.get("uptime_secs").and_then(Json::as_f64).unwrap() >= 0.0);
    assert!(h.get("start_time_unix_secs").and_then(Json::as_f64).is_some());
    assert_eq!(
        h.get("version").and_then(Json::as_str),
        Some(env!("CARGO_PKG_VERSION"))
    );

    // traffic across several endpoints so the histograms have samples
    let create = r#"{"name":"pm",
        "dataset":{"generator":"two-moons","n":200,"seed":2},
        "method":"oasis","max_cols":20,"init_cols":4,"seed":5}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");
    let (status, j) = request(addr, "POST", "/sessions/pm/step", r#"{"steps":6}"#);
    assert_eq!(status, 200, "{j}");

    // the default rendering stays JSON
    let (status, m) = request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200, "{m}");
    assert!(m.get("server").is_some(), "{m}");

    // ?format=prometheus: valid exposition text, not JSON
    let (status, page) =
        client_request(addr, "GET", "/metrics?format=prometheus", "")
            .expect("prometheus scrape");
    assert_eq!(status, 200, "{page}");
    oasis::obs::prom::validate(&page)
        .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{page}"));

    for family in [
        "# TYPE oasis_build_info gauge",
        "# TYPE oasis_uptime_seconds gauge",
        "# TYPE oasis_requests_total counter",
        "# TYPE oasis_http_request_duration_seconds histogram",
        "# TYPE oasis_session_step_duration_seconds histogram",
        "# TYPE oasis_session_steps_total counter",
    ] {
        assert!(page.contains(family), "missing {family:?} in:\n{page}");
    }
    // per-endpoint request series, with templated session names
    for series in [
        r#"oasis_http_request_duration_seconds_bucket{endpoint="POST /sessions""#,
        r#"oasis_http_request_duration_seconds_count{endpoint="POST /sessions/{name}/step"}"#,
        r#"oasis_http_request_duration_seconds_sum{endpoint="GET /healthz"}"#,
    ] {
        assert!(page.contains(series), "missing {series} in:\n{page}");
    }
    // per-session series reflect the traffic above
    assert!(
        page.contains(r#"oasis_session_steps_total{session="pm"} 6"#),
        "{page}"
    );
    assert!(
        page.contains(r#"oasis_session_step_duration_seconds_count{session="pm"} 6"#),
        "{page}"
    );

    // Accept-header negotiation selects the same rendering
    let (status, via_accept) = client_request_accept(
        addr,
        "/metrics",
        "text/plain; version=0.0.4",
    );
    assert_eq!(status, 200);
    assert!(
        via_accept.contains("# TYPE oasis_requests_total counter"),
        "Accept negotiation returned:\n{via_accept}"
    );

    stop_server(addr, join);
}

/// Server with a custom config on an ephemeral port.
fn start_server_with(
    config: ServerConfig,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let server =
        Server::bind_with("127.0.0.1:0", config).expect("bind ephemeral port");
    let addr = server.local_addr().unwrap();
    let join = std::thread::spawn(move || server.run().expect("server run"));
    (addr, join)
}

/// ACCEPTANCE (production serving): a multi-output KRR model fit once
/// over the wire, then a B-point batched predict on a single kept-alive
/// connection — bit-identical to the same points sent one per request —
/// plus the f32 serving mode and the predict histograms in `/metrics`.
#[test]
fn batched_multi_output_predict_over_one_keep_alive_connection() {
    let (addr, join) = start_server();

    // every exchange in this test reuses ONE connection: if the server
    // dropped it between requests, the next request() would error
    let mut conn = ClientConn::connect(addr).expect("connect");
    let mut exchange = |method: &str, path: &str, body: &str| -> (u16, Json) {
        let (status, raw) =
            conn.request(method, path, body).expect("keep-alive exchange");
        let json = Json::parse(&raw)
            .unwrap_or_else(|e| panic!("bad JSON body {e}: {raw}"));
        (status, json)
    };

    let n = 140;
    let create = format!(
        r#"{{"name":"bp",
            "dataset":{{"generator":"two-moons","n":{n},"seed":17}},
            "kernel":{{"type":"gaussian","sigma":0.7}},
            "method":"oasis","max_cols":24,"init_cols":4,"seed":5}}"#
    );
    let (status, j) = exchange("POST", "/sessions", &create);
    assert_eq!(status, 200, "{j}");
    let (status, j) = exchange("POST", "/sessions/bp/step", r#"{"budget":24}"#);
    assert_eq!(status, 200, "{j}");

    // multi-output fit: per-point [class, drift] label rows
    let rows: Vec<String> = (0..n)
        .map(|i| format!("[{},{}]", (i % 2) as f64, i as f64 * 0.01))
        .collect();
    let queries = [[0.3, 0.1], [-0.5, 0.4], [1.2, -0.3], [0.0, 0.8]];
    let pts: Vec<String> =
        queries.iter().map(|q| format!("[{},{}]", q[0], q[1])).collect();
    let fit_and_predict = format!(
        r#"{{"task":"krr","ridge":0.001,"labels":[{}],"predict":[{}]}}"#,
        rows.join(","),
        pts.join(",")
    );
    let (status, batched) = exchange("POST", "/sessions/bp/task", &fit_and_predict);
    assert_eq!(status, 200, "{batched}");
    assert_eq!(usize_field(&batched, "outputs"), 2, "{batched}");
    let rows_of = |j: &Json| -> Vec<Vec<f64>> {
        j.get("predictions")
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("missing predictions in {j}"))
            .iter()
            .map(|r| {
                r.as_arr()
                    .expect("per-point output row")
                    .iter()
                    .map(|v| v.as_f64().unwrap())
                    .collect()
            })
            .collect()
    };
    let batch_rows = rows_of(&batched);
    assert_eq!(batch_rows.len(), queries.len());

    // the same points one per request (label-free → cached model) are
    // bit-identical: the B×k block changes how many rows are evaluated
    // at once, never the accumulation order within an element
    for (i, q) in queries.iter().enumerate() {
        let one = format!(r#"{{"task":"krr","predict":[[{},{}]]}}"#, q[0], q[1]);
        let (status, single) = exchange("POST", "/sessions/bp/task", &one);
        assert_eq!(status, 200, "{single}");
        assert_eq!(
            single.get("model").and_then(Json::as_str),
            Some("cached"),
            "{single}"
        );
        let srow = &rows_of(&single)[0];
        assert_eq!(srow.len(), 2);
        for (o, (a, b)) in batch_rows[i].iter().zip(srow).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "batched point {i} output {o} diverged from single-point"
            );
        }
    }

    // the f32 serving mode answers close to f64 on the same connection
    let f32_body = format!(
        r#"{{"task":"krr","predict":[{}],"f32":true}}"#,
        pts.join(",")
    );
    let (status, jf) = exchange("POST", "/sessions/bp/task", &f32_body);
    assert_eq!(status, 200, "{jf}");
    for (r64, r32) in batch_rows.iter().zip(&rows_of(&jf)) {
        for (a, b) in r64.iter().zip(r32) {
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + a.abs()),
                "f32 serving drifted: {a} vs {b}"
            );
        }
    }

    // predict telemetry: batch sizes and per-model latencies surface in
    // the JSON report and as Prometheus histogram families
    let (status, m) = exchange("GET", "/metrics", "");
    assert_eq!(status, 200);
    let predict = m.get("predict").expect("predict metrics section");
    let batch_hist = predict.get("batch_size").expect("batch-size histogram");
    // 1 batched call of 4 + 4 singles + 1 f32 batch of 4 = 6 calls
    assert_eq!(usize_field(batch_hist, "count"), 6, "{m}");
    assert_eq!(batch_hist.get("max").and_then(Json::as_f64), Some(4.0));
    assert!(
        predict
            .get("models")
            .and_then(|ms| ms.get("session:bp"))
            .and_then(|h| h.get("count"))
            .and_then(Json::as_usize)
            .unwrap_or(0)
            >= 6,
        "{m}"
    );
    let (status, page) =
        client_request(addr, "GET", "/metrics?format=prometheus", "")
            .expect("prometheus scrape");
    assert_eq!(status, 200);
    oasis::obs::prom::validate(&page)
        .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{page}"));
    assert!(
        page.contains("# TYPE oasis_predict_duration_seconds histogram"),
        "{page}"
    );
    assert!(
        page.contains(r#"oasis_predict_duration_seconds_count{model="session:bp"}"#),
        "{page}"
    );
    assert!(
        page.contains("# TYPE oasis_predict_batch_size histogram"),
        "{page}"
    );

    stop_server(addr, join);
}

/// Request rate caps answer 429 without closing the connection, count
/// into the `rate_limited` counter, and exempt `/healthz` and
/// `/shutdown` so probes and operators are never locked out.
#[test]
fn rate_limits_return_429_and_exempt_health_and_shutdown() {
    let (addr, join) = start_server_with(ServerConfig {
        max_rps: 2,
        ..Default::default()
    });

    let mut conn = ClientConn::connect(addr).expect("connect");
    let mut saw_429 = 0;
    let mut saw_200 = 0;
    // 30 instant requests against a 2/s cap: the first window admits 2
    for _ in 0..30 {
        let (status, raw) =
            conn.request("GET", "/sessions", "").expect("keep-alive exchange");
        match status {
            200 => saw_200 += 1,
            429 => {
                saw_429 += 1;
                let j = Json::parse(&raw).expect("429 body is JSON");
                assert!(
                    j.get("error").and_then(Json::as_str).unwrap().contains("rate"),
                    "{j}"
                );
            }
            other => panic!("unexpected status {other}: {raw}"),
        }
    }
    assert!(saw_200 >= 1, "the first request of a window must be admitted");
    assert!(saw_429 >= 20, "a 2/s cap must reject most of a 30-shot burst");

    // exempt endpoints keep answering inside the same exhausted window,
    // on the same (still-open) connection
    for _ in 0..5 {
        let (status, _) =
            conn.request("GET", "/healthz", "").expect("health exchange");
        assert_eq!(status, 200, "/healthz must never be rate limited");
    }

    // /metrics is not exempt, so it may itself be 429 inside the
    // exhausted window; only assert on the counter when it got through
    let (status, m) = conn.request("GET", "/metrics", "").expect("metrics");
    if status == 200 {
        let j = Json::parse(&m).expect("metrics JSON");
        let server = j.get("server").expect("server counters");
        assert!(usize_field(server, "rate_limited") >= 20, "{j}");
    }

    // /shutdown is exempt too: stop_server succeeds immediately
    stop_server(addr, join);
}

/// Graceful drain: a request in flight when `/shutdown` lands still
/// completes with a full response before the server exits.
#[test]
fn shutdown_drains_in_flight_requests() {
    let (addr, join) = start_server_with(ServerConfig {
        drain: Duration::from_secs(10),
        ..Default::default()
    });
    let create = r#"{"name":"d",
        "dataset":{"generator":"two-moons","n":600,"seed":3},
        "method":"oasis","max_cols":120,"init_cols":5,"seed":1}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");

    // a deliberately long synchronous step batch…
    let slow = std::thread::spawn(move || {
        request(addr, "POST", "/sessions/d/step", r#"{"steps":110}"#)
    });
    // …interrupted by a shutdown while it is (very likely) in flight
    std::thread::sleep(Duration::from_millis(30));
    let (status, _) = request(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);

    let (status, j) = slow.join().expect("in-flight request thread");
    assert_eq!(status, 200, "drained request must complete: {j}");
    assert_eq!(usize_field(&j, "stepped"), 110, "{j}");
    join.join().expect("server thread exits after the drain");
}

/// Request correlation: a client-supplied `X-Request-Id` is echoed on
/// the response and lands in the structured request log, and ids the
/// server generates are unique across keep-alive requests on one
/// connection.
#[test]
fn request_ids_echo_and_stay_unique_across_keep_alive() {
    let (addr, join) = start_server();
    let mut conn = ClientConn::connect(addr).expect("connect");

    // client-supplied id: echoed verbatim, and correlated into the
    // structured request log line
    oasis::obs::log::capture_start();
    let (status, headers, _body) = conn
        .request_with_headers(
            "GET",
            "/healthz",
            &[("X-Request-Id", "test-rid-42")],
            "",
        )
        .expect("exchange");
    let captured = oasis::obs::log::capture_take();
    assert_eq!(status, 200);
    assert_eq!(
        headers.get("x-request-id").map(String::as_str),
        Some("test-rid-42"),
        "{headers:?}"
    );
    assert!(
        captured
            .iter()
            .any(|l| l.contains("test-rid-42") && l.contains("/healthz")),
        "request id missing from structured log: {captured:?}"
    );

    // no client id: the server generates one per request, unique across
    // the whole keep-alive connection
    let mut ids = std::collections::BTreeSet::new();
    for _ in 0..3 {
        let (status, headers, _body) = conn
            .request_with_headers("GET", "/healthz", &[], "")
            .expect("exchange");
        assert_eq!(status, 200);
        let rid = headers.get("x-request-id").expect("generated id").clone();
        assert!(!rid.is_empty());
        ids.insert(rid);
    }
    assert_eq!(ids.len(), 3, "generated ids must be unique: {ids:?}");

    // an unprintable client id is replaced, not echoed
    let (status, headers, _body) = conn
        .request_with_headers(
            "GET",
            "/healthz",
            &[("X-Request-Id", "bad id with spaces")],
            "",
        )
        .expect("exchange");
    assert_eq!(status, 200);
    assert_ne!(
        headers.get("x-request-id").map(String::as_str),
        Some("bad id with spaces"),
        "non-graphic client ids must not be echoed"
    );

    stop_server(addr, join);
}

/// Convergence telemetry and live tracing over the socket: the per-step
/// trajectory ring, its `/metrics` summary and Prometheus gauges, and
/// the `/debug/trace` enable → drain round trip.
#[test]
fn trajectory_and_debug_trace_over_socket() {
    let (addr, join) = start_server();
    let create = r#"{"name":"tj",
        "dataset":{"generator":"two-moons","n":200,"seed":6},
        "method":"oasis","max_cols":30,"init_cols":4,"seed":9}"#;
    let (status, j) = request(addr, "POST", "/sessions", create);
    assert_eq!(status, 200, "{j}");
    let (status, j) = request(addr, "POST", "/sessions/tj/step", r#"{"steps":10}"#);
    assert_eq!(status, 200, "{j}");
    let batch_err = j.get("error_estimate").and_then(Json::as_f64);

    // the trajectory replays the batch step by step: k grows by one per
    // point and the error estimate decreases monotonically in k (the
    // Schur residual-trace ratio shrinks as columns are adopted)
    let (status, tj) = request(addr, "GET", "/sessions/tj/trajectory", "");
    assert_eq!(status, 200, "{tj}");
    assert_eq!(usize_field(&tj, "count"), 10, "{tj}");
    let points = tj.get("points").and_then(Json::as_arr).expect("points");
    assert_eq!(points.len(), 10);
    let mut prev_k = 4;
    let mut prev_err = f64::INFINITY;
    for p in points {
        let k = usize_field(p, "k");
        assert_eq!(k, prev_k + 1, "k must grow by one per point: {tj}");
        prev_k = k;
        let err = p
            .get("error_estimate")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing error_estimate in {p}"));
        assert!(
            err <= prev_err * (1.0 + 1e-9),
            "error estimate rose in k: {err} after {prev_err} ({tj})"
        );
        prev_err = err;
    }
    // the final point agrees with the step batch's own summary
    assert_eq!(
        points.last().and_then(|p| p.get("error_estimate")).and_then(Json::as_f64),
        batch_err,
        "{tj}"
    );

    // session status carries the latest score; /metrics summarizes the ring
    let (_, s) = request(addr, "GET", "/sessions/tj", "");
    assert!(s.get("best_score").and_then(Json::as_f64).is_some(), "{s}");
    let (_, m) = request(addr, "GET", "/metrics", "");
    let tsec = m.get("trajectory").expect("trajectory section");
    let ttj = tsec.get("tj").expect("session tj summary");
    assert_eq!(usize_field(ttj, "count"), 10, "{m}");
    assert!(ttj.get("last").and_then(|l| l.get("k")).is_some(), "{m}");

    // the new session gauges render in the Prometheus exposition
    let (status, page) =
        client_request(addr, "GET", "/metrics?format=prometheus", "")
            .expect("prometheus scrape");
    assert_eq!(status, 200);
    oasis::obs::prom::validate(&page)
        .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{page}"));
    assert!(
        page.contains("# TYPE oasis_session_best_score gauge"),
        "{page}"
    );
    assert!(page.contains(r#"oasis_session_best_score{session="tj"}"#), "{page}");
    assert!(
        page.contains(r#"oasis_session_error_estimate{session="tj"}"#),
        "{page}"
    );

    // live tracing: enable over the wire, generate traffic, drain as
    // Chrome trace JSON with the request spans on the server track
    let (status, j) = request(
        addr,
        "POST",
        "/debug/trace",
        r#"{"enable":true,"capacity":4096}"#,
    );
    assert_eq!(status, 200, "{j}");
    assert_eq!(j.get("enabled").and_then(Json::as_bool), Some(true), "{j}");
    let (status, j) = request(addr, "POST", "/sessions/tj/step", r#"{"steps":3}"#);
    assert_eq!(status, 200, "{j}");
    let (status, tr) = request(addr, "GET", "/debug/trace", "");
    assert_eq!(status, 200, "{tr}");
    let events = tr
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents");
    assert!(
        events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("http_request")
                && e.get("ph").and_then(Json::as_str) == Some("X")
        }),
        "no http_request span in drained trace: {tr}"
    );
    // …and the drain emptied the ring: disable and confirm
    let (status, j) = request(addr, "POST", "/debug/trace", r#"{"enable":false}"#);
    assert_eq!(status, 200, "{j}");
    assert_eq!(j.get("enabled").and_then(Json::as_bool), Some(false), "{j}");

    stop_server(addr, join);
}

/// GET with an explicit Accept header over a raw TcpStream (the shared
/// `client_request` helper doesn't set one).
fn client_request_accept(
    addr: SocketAddr,
    path: &str,
    accept: &str,
) -> (u16, String) {
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    write!(
        stream,
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nAccept: {accept}\r\nConnection: close\r\n\r\n"
    )
    .expect("send request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status line");
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}
