//! Engine-layer acceptance tests: the spec-driven run pipeline shared by
//! the CLI, the HTTP server, and the oASIS-P coordinator. Front-end
//! parity proper lives in `tests/session.rs` (engine vs hand-built) and
//! `tests/server.rs` (engine vs socket); this file exercises the
//! resolution rules themselves — clamping, one-shot methods, shard-read
//! validation and equivalence, and warm-start validation.

use oasis::data::generators::two_moons;
use oasis::data::{loader, LoadLimits};
use oasis::engine::{
    self, DatasetSpec, KernelSpec, Method, MethodSpec, RunSpec, SessionBuilder,
    WarmStartSpec,
};
use oasis::sampling::run_to_completion;
use std::path::PathBuf;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("oasis-engine-test")
        .join(format!("{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spec(method: Method, dataset: DatasetSpec, kernel: KernelSpec, cols: usize) -> RunSpec {
    RunSpec {
        dataset,
        kernel,
        method: MethodSpec {
            method,
            max_cols: cols,
            init_cols: 4,
            tol: 1e-12,
            seed: 17,
            batch: 10,
            workers: 3,
            merge_batch: 1,
            listen: None,
        },
        stopping: engine::stopping_rule(cols, None, None),
        shard_reads: false,
        warm_start: None,
    }
}

fn moons(n: usize) -> DatasetSpec {
    DatasetSpec::Generator {
        name: "two-moons".into(),
        n,
        seed: 5,
        noise: 0.05,
        dim: 0,
    }
}

fn gaussian_frac() -> KernelSpec {
    KernelSpec::Gaussian { sigma: None, sigma_fraction: 0.1 }
}

/// Budgets and sampler parameters clamp to the resolved dataset size —
/// the clamp every front end used to hand-roll.
#[test]
fn resolve_clamps_budgets_and_method_to_n() {
    let run = SessionBuilder::new()
        .resolve(spec(Method::Oasis, moons(25), gaussian_frac(), 500))
        .unwrap();
    assert_eq!(run.n(), 25);
    assert_eq!(run.method.max_cols, 25);
    let slot = run.oracle_slot();
    let mut s = run.open_session(&slot).unwrap();
    // the clamped budget reports BudgetReached (not Exhausted) at n
    let reason = run_to_completion(s.as_mut(), &run.stopping).unwrap();
    assert!(
        matches!(
            reason,
            oasis::sampling::StopReason::BudgetReached
                | oasis::sampling::StopReason::ScoreBelowTol
        ),
        "{reason:?}"
    );
}

/// File datasets resolve through the loader under the builder's limits.
#[test]
fn file_dataset_resolves_with_limits() {
    let dir = tmp_dir("file-limits");
    let ds = two_moons(60, 0.05, 2);
    let path = dir.join("train.csv");
    loader::save_csv(&path, &ds).unwrap();
    let file_spec = || DatasetSpec::File {
        label: "train.csv".into(),
        path: path.clone(),
    };
    let run = SessionBuilder::new()
        .resolve(spec(Method::Oasis, file_spec(), gaussian_frac(), 10))
        .unwrap();
    assert_eq!((run.n(), run.dim()), (60, 2));
    assert_eq!(run.source, "file:train.csv");
    // a limits-bounded builder refuses the same file while parsing
    let tight = LoadLimits { max_n: 10, max_dim: 8, max_elems: u128::MAX };
    let err = SessionBuilder::with_limits(tight)
        .resolve(spec(Method::Oasis, file_spec(), gaussian_frac(), 10))
        .unwrap_err();
    assert!(format!("{err}").contains("rows"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Shard-read resolution: oASIS-P + a binary file + a data-free kernel.
/// Every other combination is a clean error, and the accepted one runs
/// bit-identically to the whole-file path.
#[test]
fn shard_reads_resolve_and_match_full_run() {
    let dir = tmp_dir("shard-reads");
    let ds = two_moons(140, 0.05, 21);
    let bin = dir.join("points.mat");
    loader::save_matrix(&bin, &ds).unwrap();
    let csv = dir.join("points.csv");
    loader::save_csv(&csv, &ds).unwrap();
    let file_spec = |p: &PathBuf| DatasetSpec::File {
        label: "points".into(),
        path: p.clone(),
    };
    let sigma = KernelSpec::Gaussian { sigma: Some(0.5), sigma_fraction: 0.1 };

    // CSV cannot be byte-range sharded
    let mut s = spec(Method::OasisP, file_spec(&csv), sigma.clone(), 20);
    s.shard_reads = true;
    let err = SessionBuilder::new().resolve(s).unwrap_err();
    assert!(format!("{err}").contains("binary"), "{err}");
    // a σ-fraction kernel needs the dataset the leader never loads
    let mut s = spec(Method::OasisP, file_spec(&bin), gaussian_frac(), 20);
    s.shard_reads = true;
    let err = SessionBuilder::new().resolve(s).unwrap_err();
    assert!(format!("{err}").contains("sigma"), "{err}");

    // the valid combination: equal to the whole-file run, bit for bit
    let mut sharded_spec = spec(Method::OasisP, file_spec(&bin), sigma.clone(), 20);
    sharded_spec.shard_reads = true;
    let sharded_run = SessionBuilder::new().resolve(sharded_spec).unwrap();
    assert!(sharded_run.dataset().is_err(), "no dataset is materialized");
    let mut session = sharded_run.open_oasis_p().unwrap();
    run_to_completion(&mut session, &sharded_run.stopping).unwrap();
    let (sharded, report) = session.finish_run().unwrap();
    assert_eq!(report.workers, 3);

    let full_run = SessionBuilder::new()
        .resolve(spec(Method::OasisP, file_spec(&bin), sigma, 20))
        .unwrap();
    let mut session = full_run.open_oasis_p().unwrap();
    run_to_completion(&mut session, &full_run.stopping).unwrap();
    let (full, _) = session.finish_run().unwrap();

    assert_eq!(sharded.indices, full.indices);
    assert_eq!(sharded.c.data, full.c.data);
    assert_eq!(sharded.winv.data, full.winv.data);
    std::fs::remove_dir_all(&dir).ok();
}

/// Warm-start validation happens at resolve time with specific errors.
#[test]
fn warm_start_resolution_errors_are_specific() {
    let warm = |label: &str| {
        Some(WarmStartSpec {
            label: label.into(),
            path: PathBuf::from(format!("/nonexistent/{label}")),
        })
    };
    // only the Schur-complement selectors (oasis, sis) can warm start
    let mut s = spec(Method::Farahat, moons(40), gaussian_frac(), 10);
    s.warm_start = warm("a.oasis");
    let err = SessionBuilder::new().resolve(s).unwrap_err();
    assert!(format!("{err}").contains("'oasis' and 'sis'"), "{err}");
    // sis *is* warmable now: with a missing artifact it fails on the
    // file, not on the method
    let mut s = spec(Method::Sis, moons(40), gaussian_frac(), 10);
    s.warm_start = warm("a.oasis");
    let err = SessionBuilder::new().resolve(s).unwrap_err();
    assert!(!format!("{err}").contains("methods only"), "{err}");
    // a missing artifact file names the problem
    let mut s = spec(Method::Oasis, moons(40), gaussian_frac(), 10);
    s.warm_start = warm("b.oasis");
    let err = SessionBuilder::new().resolve(s).unwrap_err();
    assert!(format!("{err}").contains("warm_start"), "{err}");
}

/// The one-shot methods resolve and sample through the same engine spec.
#[test]
fn one_shot_methods_run_through_the_engine() {
    for m in [Method::Uniform, Method::Leverage, Method::Kmeans] {
        let run = SessionBuilder::new()
            .resolve(spec(m, moons(50), gaussian_frac(), 8))
            .unwrap();
        let slot = run.oracle_slot();
        let approx = run.one_shot(&slot).unwrap();
        assert_eq!(approx.n(), 50, "{m:?}");
        assert!(approx.k() >= 1, "{m:?}");
        // and the stepwise entry refuses them with a pointer at one_shot
        let err = run.open_session(&slot).unwrap_err();
        assert!(format!("{err}").contains("one_shot"), "{err}");
    }
}
