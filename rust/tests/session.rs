//! Session-API acceptance tests: the stepwise `SamplerSession` path must
//! reproduce the legacy one-shot `ColumnSampler::sample` path bit for bit,
//! stopping criteria must fire deterministically and in rule order, and
//! finished sessions must resume (extend, not restart).

use oasis::data::generators::two_moons;
use oasis::engine::{
    self, DatasetSpec, KernelSpec, Method, MethodSpec, RunSpec, SessionBuilder,
    WarmStartSpec,
};
use oasis::kernels::Gaussian;
use oasis::nystrom::{Provenance, StoredArtifact};
use oasis::sampling::{
    oasis::{Oasis, Variant},
    run_to_completion, ImplicitOracle, SamplerSession, StepOutcome, StopReason,
    StoppingCriterion, StoppingRule,
};
use std::time::Duration;

/// The headline acceptance criterion: oASIS driven one `step()` at a time
/// selects the bit-identical column sequence — and assembles the
/// bit-identical `NystromApprox` (C and W⁻¹ data) — as the legacy
/// `ColumnSampler::sample` path on two-moons with n = 2000, ℓ = 450.
#[test]
fn stepped_session_bit_identical_to_sample_two_moons_2000() {
    let ds = two_moons(2_000, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let sampler = Oasis::new(450, 10, 1e-12, 7);

    let (reference, ref_trace) = sampler.sample_traced(&oracle).unwrap();

    let mut session = sampler.session(&oracle).unwrap();
    let mut stepped_order: Vec<usize> = session.indices().to_vec();
    while stepped_order.len() < 450 {
        match session.step().unwrap() {
            StepOutcome::Selected { index, .. } => stepped_order.push(index),
            StepOutcome::Exhausted(_) => break,
        }
    }
    assert_eq!(stepped_order, ref_trace.order, "selection order diverged");

    let approx = Box::new(session).finish().unwrap();
    assert_eq!(approx.indices, reference.indices);
    assert_eq!(approx.c.data, reference.c.data, "C diverged");
    assert_eq!(approx.winv.data, reference.winv.data, "W⁻¹ diverged");
    assert_eq!(approx.k(), 450);
}

/// Both scoring variants agree between their session and one-shot paths
/// (smaller instance; the PaperR variant maintains extra state worth
/// exercising through the stepwise path).
#[test]
fn both_variants_step_identically_to_sample() {
    let ds = two_moons(300, 0.05, 11);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    for variant in [Variant::PaperR, Variant::Incremental] {
        let sampler = Oasis::new(60, 5, 1e-12, 3).with_variant(variant);
        let (reference, _) = sampler.sample_traced(&oracle).unwrap();
        let mut s = sampler.session(&oracle).unwrap();
        while s.k() < 60 {
            if let StepOutcome::Exhausted(_) = s.step().unwrap() {
                break;
            }
        }
        let approx = s.snapshot().unwrap();
        assert_eq!(approx.indices, reference.indices, "{variant:?}");
        assert_eq!(approx.c.data, reference.c.data, "{variant:?}");
        assert_eq!(approx.winv.data, reference.winv.data, "{variant:?}");
    }
}

/// A loose error target stops the run with k < ℓ and reports
/// `ErrorTargetMet` (second acceptance criterion).
#[test]
fn loose_error_target_stops_before_budget() {
    let ds = two_moons(2_000, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let mut session = Oasis::new(450, 10, 1e-12, 7).session(&oracle).unwrap();
    let rule = StoppingRule::new()
        .with(StoppingCriterion::ErrorBelow(0.5))
        .with(StoppingCriterion::ColumnBudget(450));
    let reason = run_to_completion(&mut session, &rule).unwrap();
    assert_eq!(reason, StopReason::ErrorTargetMet);
    assert!(
        session.k() < 450,
        "loose target should stop early, got k = {}",
        session.k()
    );
    assert!(session.error_estimate().unwrap() <= 0.5);
}

/// Criteria are evaluated in rule order: when the budget and the error
/// target hold simultaneously, the first-listed criterion names the stop.
#[test]
fn criteria_report_in_rule_order() {
    let ds = two_moons(400, 0.05, 5);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kernel);

    // run once to learn where a 0.5 estimate is reached
    let mut probe = Oasis::new(120, 5, 1e-12, 9).session(&oracle).unwrap();
    run_to_completion(
        &mut probe,
        &StoppingRule::new().with(StoppingCriterion::ErrorBelow(0.5)),
    )
    .unwrap();
    let k_at_target = probe.k();

    // both criteria hold at k_at_target: listed order decides the reason
    for (rule, expect) in [
        (
            StoppingRule::new()
                .with(StoppingCriterion::ColumnBudget(k_at_target))
                .with(StoppingCriterion::ErrorBelow(0.5)),
            StopReason::BudgetReached,
        ),
        (
            StoppingRule::new()
                .with(StoppingCriterion::ErrorBelow(0.5))
                .with(StoppingCriterion::ColumnBudget(k_at_target)),
            StopReason::ErrorTargetMet,
        ),
    ] {
        let mut s = Oasis::new(120, 5, 1e-12, 9).session(&oracle).unwrap();
        let reason = run_to_completion(&mut s, &rule).unwrap();
        assert_eq!(reason, expect, "rule {:?}", rule.criteria());
        assert_eq!(s.k(), k_at_target);
    }
}

/// Resuming a finished session with a larger budget extends the index set
/// (never restarts): the extended run equals a fresh run at the larger
/// budget, bitwise — which also exercises the state growth path, since the
/// session was allocated for only 20 columns.
#[test]
fn resumed_session_extends_index_set() {
    let ds = two_moons(500, 0.05, 13);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kernel);

    let (fresh_60, _) = Oasis::new(60, 5, 1e-12, 21)
        .sample_traced(&oracle)
        .unwrap();

    // allocate for 20, run to 20, then resume twice (growing past cap)
    let mut s = Oasis::new(20, 5, 1e-12, 21).session(&oracle).unwrap();
    let r1 = run_to_completion(&mut s, &StoppingRule::budget(20)).unwrap();
    assert_eq!(r1, StopReason::BudgetReached);
    assert_eq!(s.k(), 20);
    let at_20: Vec<usize> = s.indices().to_vec();
    let snap_20 = s.snapshot().unwrap();

    let r2 = run_to_completion(&mut s, &StoppingRule::budget(45)).unwrap();
    assert_eq!(r2, StopReason::BudgetReached);
    assert_eq!(s.k(), 45);
    assert_eq!(&s.indices()[..20], &at_20[..], "resume restarted the run");

    run_to_completion(&mut s, &StoppingRule::budget(60)).unwrap();
    let extended = s.snapshot().unwrap();
    assert_eq!(extended.indices, fresh_60.indices);
    assert_eq!(extended.c.data, fresh_60.c.data);
    assert_eq!(extended.winv.data, fresh_60.winv.data);
    // the mid-run snapshot was a faithful 20-column prefix
    assert_eq!(snap_20.indices, &fresh_60.indices[..20]);
    for i in 0..500 {
        for t in 0..20 {
            assert_eq!(snap_20.c.at(i, t), fresh_60.c.at(i, t));
        }
    }
}

/// An immediate deadline stops before any adaptive selection; re-driving
/// the same session afterwards picks up where it left off with a fresh
/// deadline.
#[test]
fn deadline_stops_and_resumes() {
    let ds = two_moons(300, 0.05, 2);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let mut s = Oasis::new(40, 4, 1e-12, 1).session(&oracle).unwrap();
    let rule = StoppingRule::new()
        .with(StoppingCriterion::Deadline(Duration::ZERO))
        .with(StoppingCriterion::ColumnBudget(40));
    let reason = run_to_completion(&mut s, &rule).unwrap();
    assert_eq!(reason, StopReason::DeadlineExpired);
    assert_eq!(s.k(), 4, "only the seed columns should be selected");
    // resume without the dead deadline
    let reason = run_to_completion(&mut s, &StoppingRule::budget(40)).unwrap();
    assert_eq!(reason, StopReason::BudgetReached);
    assert_eq!(s.k(), 40);
}

/// Deadline and error-target composed in one rule, driven *stepwise* (the
/// serving pattern: evaluate-before-step exactly like `run_to_completion`,
/// but with the loop in caller hands): whichever criterion holds first
/// names the stop, and a deadline-stopped session resumes to the error
/// target afterwards.
#[test]
fn composed_deadline_and_error_target_under_stepped_execution() {
    let ds = two_moons(400, 0.05, 17);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kernel);

    // (a) generous deadline + loose error target → the error target fires
    let mut s = Oasis::new(200, 5, 1e-12, 3).session(&oracle).unwrap();
    let rule = StoppingRule::new()
        .with(StoppingCriterion::ErrorBelow(0.5))
        .with(StoppingCriterion::Deadline(Duration::from_secs(3600)))
        .with(StoppingCriterion::ColumnBudget(200));
    let started = std::time::Instant::now();
    let reason = loop {
        if let Some(r) = rule.evaluate(&s, started.elapsed()) {
            break r;
        }
        match s.step().unwrap() {
            StepOutcome::Selected { .. } => {}
            StepOutcome::Exhausted(r) => break r,
        }
    };
    assert_eq!(reason, StopReason::ErrorTargetMet);
    assert!(s.k() < 200, "k = {}", s.k());
    assert!(s.error_estimate().unwrap() <= 0.5);

    // (b) zero deadline + unreachable error target → the deadline fires
    // before any adaptive selection…
    let mut s2 = Oasis::new(200, 5, 1e-12, 3).session(&oracle).unwrap();
    let rule2 = StoppingRule::new()
        .with(StoppingCriterion::ErrorBelow(1e-12))
        .with(StoppingCriterion::Deadline(Duration::ZERO))
        .with(StoppingCriterion::ColumnBudget(200));
    let reason2 = run_to_completion(&mut s2, &rule2).unwrap();
    assert_eq!(reason2, StopReason::DeadlineExpired);
    assert_eq!(s2.k(), 5, "only the seed columns");

    // …and resuming the same session with a reachable target (fresh
    // deadline) extends it to exactly where session (a) stopped — stepped
    // and rule-driven execution agree bit for bit
    let resume = StoppingRule::new()
        .with(StoppingCriterion::ErrorBelow(0.5))
        .with(StoppingCriterion::ColumnBudget(200));
    let reason3 = run_to_completion(&mut s2, &resume).unwrap();
    assert_eq!(reason3, StopReason::ErrorTargetMet);
    assert_eq!(s2.k(), s.k());
    assert_eq!(s2.indices(), s.indices());
}

/// The engine spec for a plain oASIS run over a generator dataset.
fn oasis_spec(n: usize, cols: usize, warm: Option<WarmStartSpec>) -> RunSpec {
    RunSpec {
        dataset: DatasetSpec::Generator {
            name: "two-moons".into(),
            n,
            seed: 42,
            noise: 0.05,
            dim: 0,
        },
        kernel: KernelSpec::Gaussian { sigma: None, sigma_fraction: 0.05 },
        method: MethodSpec {
            method: Method::Oasis,
            max_cols: cols,
            init_cols: 5,
            tol: 1e-12,
            seed: 7,
            batch: 10,
            workers: 1,
            merge_batch: 1,
            listen: None,
        },
        stopping: engine::stopping_rule(cols, None, None),
        shard_reads: false,
        warm_start: warm,
    }
}

/// FRONT-END PARITY: the same `RunSpec` resolved through the engine (the
/// CLI's path) selects the bit-identical sequence — and assembles the
/// bit-identical factors — as a hand-wired dataset → kernel → oracle →
/// session pipeline with the same parameters.
#[test]
fn engine_resolved_spec_matches_hand_built_session() {
    let run = SessionBuilder::new().resolve(oasis_spec(400, 60, None)).unwrap();
    let slot = run.oracle_slot();
    let mut s = run.open_session(&slot).unwrap();
    run_to_completion(s.as_mut(), &run.stopping).unwrap();
    let via_engine = s.snapshot().unwrap();

    let ds = two_moons(400, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let mut hand = Oasis::new(60, 5, 1e-12, 7).session(&oracle).unwrap();
    run_to_completion(&mut hand, &StoppingRule::budget(60)).unwrap();
    let reference = hand.snapshot().unwrap();

    assert_eq!(via_engine.indices, reference.indices, "selection diverged");
    assert_eq!(via_engine.c.data, reference.c.data, "C diverged");
    assert_eq!(via_engine.winv.data, reference.winv.data, "W⁻¹ diverged");
}

/// WARM START ≡ PREFIX RESUME: saving a 20-column prefix as an artifact
/// and warm-starting a fresh spec from it continues bit-identically to
/// the uninterrupted 40-column run — the engine's warm replay exactly
/// reconstructs the recording session's state.
#[test]
fn warm_start_from_artifact_equals_prefix_resume() {
    let dir = std::env::temp_dir()
        .join("oasis-engine-warm-test")
        .join(format!("r{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // uninterrupted reference to 40
    let run = SessionBuilder::new().resolve(oasis_spec(300, 40, None)).unwrap();
    let slot = run.oracle_slot();
    let mut s = run.open_session(&slot).unwrap();
    run_to_completion(s.as_mut(), &run.stopping).unwrap();
    let reference = s.snapshot().unwrap();

    // prefix run to 20, saved as an artifact
    let run2 = SessionBuilder::new().resolve(oasis_spec(300, 20, None)).unwrap();
    let slot2 = run2.oracle_slot();
    let mut s2 = run2.open_session(&slot2).unwrap();
    run_to_completion(s2.as_mut(), &run2.stopping).unwrap();
    let artifact = StoredArtifact::from_parts(
        s2.snapshot().unwrap(),
        run2.dataset().unwrap(),
        &*run2.kernel,
        Provenance { source: run2.source.clone(), method: "oASIS".into() },
        None,
    )
    .unwrap();
    let path = dir.join("prefix.oasis");
    artifact.save(&path).unwrap();

    // warm-start a third run from the artifact and continue to 40
    let warm = Some(WarmStartSpec {
        label: "prefix.oasis".into(),
        path: path.clone(),
    });
    let run3 = SessionBuilder::new().resolve(oasis_spec(300, 40, warm)).unwrap();
    let slot3 = run3.oracle_slot();
    let mut s3 = run3.open_session(&slot3).unwrap();
    assert_eq!(s3.k(), 20, "warm session resumes at the stored k");
    assert_eq!(s3.indices(), &reference.indices[..20]);
    run_to_completion(s3.as_mut(), &run3.stopping).unwrap();
    let warmed = s3.snapshot().unwrap();
    assert_eq!(warmed.indices, reference.indices, "selection diverged");
    assert_eq!(warmed.c.data, reference.c.data, "C diverged");
    assert_eq!(warmed.winv.data, reference.winv.data, "W⁻¹ diverged");

    // a mismatched kernel is refused at resolve time — resuming under a
    // different kernel would make every replayed Δ meaningless
    let mut bad = oasis_spec(
        300,
        40,
        Some(WarmStartSpec { label: "prefix.oasis".into(), path: path.clone() }),
    );
    bad.kernel = KernelSpec::Gaussian { sigma: Some(0.9), sigma_fraction: 0.05 };
    let err = SessionBuilder::new().resolve(bad).unwrap_err();
    assert!(format!("{err}").contains("mismatch"), "{err}");
    // …and so is a mismatched dataset size
    let mut bad_n = oasis_spec(
        280,
        40,
        Some(WarmStartSpec { label: "prefix.oasis".into(), path }),
    );
    bad_n.kernel = KernelSpec::Gaussian { sigma: Some(0.9), sigma_fraction: 0.05 };
    let err = SessionBuilder::new().resolve(bad_n).unwrap_err();
    assert!(format!("{err}").contains("n = "), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

/// WARM START for `sis` through the engine: same contract as the oasis
/// test above — a saved 12-column sis prefix resumes bit-identically to
/// the uninterrupted 24-column run (sis is the naive correctness
/// oracle, so this also cross-checks the oasis replay arithmetic).
#[test]
fn sis_warm_start_through_engine_equals_prefix_resume() {
    let dir = std::env::temp_dir()
        .join("oasis-engine-sis-warm-test")
        .join(format!("r{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let sis_spec = |cols: usize, warm: Option<WarmStartSpec>| {
        let mut s = oasis_spec(200, cols, warm);
        s.method.method = Method::Sis;
        s.stopping = engine::stopping_rule(cols, None, None);
        s
    };

    let run = SessionBuilder::new().resolve(sis_spec(24, None)).unwrap();
    let slot = run.oracle_slot();
    let mut s = run.open_session(&slot).unwrap();
    run_to_completion(s.as_mut(), &run.stopping).unwrap();
    let reference = s.snapshot().unwrap();

    let run2 = SessionBuilder::new().resolve(sis_spec(12, None)).unwrap();
    let slot2 = run2.oracle_slot();
    let mut s2 = run2.open_session(&slot2).unwrap();
    run_to_completion(s2.as_mut(), &run2.stopping).unwrap();
    let artifact = StoredArtifact::from_parts(
        s2.snapshot().unwrap(),
        run2.dataset().unwrap(),
        &*run2.kernel,
        Provenance { source: run2.source.clone(), method: "sis".into() },
        None,
    )
    .unwrap();
    let path = dir.join("sis-prefix.oasis");
    artifact.save(&path).unwrap();

    let warm = Some(WarmStartSpec { label: "sis-prefix.oasis".into(), path });
    let run3 = SessionBuilder::new().resolve(sis_spec(24, warm)).unwrap();
    let slot3 = run3.oracle_slot();
    let mut s3 = run3.open_session(&slot3).unwrap();
    assert_eq!(s3.k(), 12, "warm sis session resumes at the stored k");
    run_to_completion(s3.as_mut(), &run3.stopping).unwrap();
    let warmed = s3.snapshot().unwrap();
    assert_eq!(warmed.indices, reference.indices, "selection diverged");
    assert_eq!(warmed.c.data, reference.c.data, "C diverged");
    assert_eq!(warmed.winv.data, reference.winv.data, "W⁻¹ diverged");

    std::fs::remove_dir_all(&dir).ok();
}

/// `ScoreBelow` as an external criterion stops a run that the internal
/// numerical floor would have let continue.
#[test]
fn score_below_criterion_stops_externally() {
    let ds = two_moons(400, 0.05, 7);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let mut s = Oasis::new(200, 5, 1e-14, 3).session(&oracle).unwrap();
    let rule = StoppingRule::new()
        .with(StoppingCriterion::ScoreBelow(1e-2))
        .with(StoppingCriterion::ColumnBudget(200));
    let reason = run_to_completion(&mut s, &rule).unwrap();
    assert_eq!(reason, StopReason::ScoreBelowTol);
    assert!(s.k() < 200, "k = {}", s.k());
    // the last recorded score is indeed below the threshold, and the one
    // before it was not
    let deltas = &s.trace().deltas;
    assert!(deltas.last().unwrap() < &1e-2);
    assert!(deltas[deltas.len() - 2] >= 1e-2);
}

/// The blocked-kernel pass must not move a single bit: a fully naive
/// in-test reimplementation of the pre-blocking Incremental arithmetic —
/// per-entry kernel evaluation (no `eval_rows` batching), serial i-outer
/// sweeps, the unfused two-pass Δ update — reproduces the shipped
/// sampler's selection order, C, and W⁻¹ exactly. If a future kernel
/// edit reorders any accumulation, this test names the first divergent
/// element.
#[test]
fn oasis_selection_bit_identical_to_naive_reference() {
    use oasis::kernels::Kernel;
    use oasis::linalg::{inverse, matrix::dot, Mat};
    use oasis::util::rng::Pcg64;

    let n = 400;
    let l = 60;
    let k0 = 6;
    let seed = 5u64;
    let user_tol = 1e-12;
    let ds = two_moons(n, 0.05, 21);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);

    // --- shipped path (blocked kernels, fused step, batched columns) ---
    let oracle = ImplicitOracle::new(&ds, &kern);
    let (approx, trace) =
        Oasis::new(l, k0, user_tol, seed).sample_traced(&oracle).unwrap();

    // --- naive reference ---
    let col_of = |j: usize| -> Vec<f64> {
        (0..n).map(|i| kern.eval(ds.point(i), ds.point(j))).collect()
    };
    let d: Vec<f64> = (0..n).map(|i| kern.diag_value(ds.point(i))).collect();
    let dmax = d.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
    let tol = user_tol.max(1e-12 * dmax.max(1e-300));

    // seed draw, with the same singular-W₀ redraw loop as the sampler
    let mut rng = Pcg64::new(seed);
    let mut c: Vec<f64> = Vec::new(); // column-major: column t at [t*n..]
    let cap = l; // W⁻¹ stride (never affects the arithmetic)
    let mut winv = vec![0.0; cap * cap];
    let mut order: Vec<usize>;
    loop {
        let cand = rng.sample_without_replacement(n, k0);
        c.clear();
        for &j in &cand {
            c.extend_from_slice(&col_of(j));
        }
        let mut w = Mat::zeros(k0, k0);
        for (ti, &i) in cand.iter().enumerate() {
            for tj in 0..k0 {
                *w.at_mut(ti, tj) = c[tj * n + i];
            }
        }
        if let Some(inv) = inverse(&w) {
            let cond_proxy = inv.max_abs() * w.max_abs();
            if cond_proxy.is_finite() && cond_proxy <= 1e12 {
                for i in 0..k0 {
                    for j in 0..k0 {
                        winv[i * cap + j] = inv.at(i, j);
                    }
                }
                order = cand;
                break;
            }
        }
    }
    let mut selected = vec![false; n];
    for &j in &order {
        selected[j] = true;
    }

    // seed Δ: Δᵢ = dᵢ − bᵢᵀ W⁻¹ bᵢ (same `dot` as the shipped sweep)
    let mut k = k0;
    let mut delta = vec![0.0; n];
    let mut b = vec![0.0; k0];
    for i in 0..n {
        for (t, bt) in b.iter_mut().enumerate() {
            *bt = c[t * n + i];
        }
        let mut quad = 0.0;
        for t in 0..k {
            quad += b[t] * dot(&winv[t * cap..t * cap + k], &b);
        }
        delta[i] = d[i] - quad;
    }

    // greedy steps: serial argmax, per-entry column, unfused Δ update
    while k < l {
        let mut best = usize::MAX;
        let mut best_abs = -1.0;
        for (i, &dv) in delta.iter().enumerate() {
            if selected[i] {
                continue;
            }
            if dv.abs() > best_abs {
                best_abs = dv.abs();
                best = i;
            }
        }
        if best == usize::MAX || best_abs < tol {
            break;
        }
        let s = 1.0 / delta[best];
        let col = col_of(best);
        let mut bq = vec![0.0; k];
        for (t, bt) in bq.iter_mut().enumerate() {
            *bt = c[t * n + best];
        }
        let q: Vec<f64> =
            (0..k).map(|t| dot(&winv[t * cap..t * cap + k], &bq)).collect();
        // unfused pair: diff sweep (t-ascending per element), then Δ pass
        let mut diff = vec![0.0; n];
        for (i, df) in diff.iter_mut().enumerate() {
            let mut acc = -col[i];
            for (t, &qt) in q.iter().enumerate() {
                if qt == 0.0 {
                    continue;
                }
                acc += qt * c[t * n + i];
            }
            *df = acc;
        }
        for (dl, &dv) in delta.iter_mut().zip(&diff) {
            *dl -= s * dv * dv;
        }
        // Eq. 5 block-inverse update
        for i in 0..k {
            let qi = q[i];
            for j in 0..k {
                winv[i * cap + j] += s * qi * q[j];
            }
            winv[i * cap + k] = -s * qi;
            winv[k * cap + i] = -s * qi;
        }
        winv[k * cap + k] = s;
        c.extend_from_slice(&col);
        selected[best] = true;
        order.push(best);
        k += 1;
    }

    // --- identical to the last bit ---
    assert_eq!(trace.order, order, "selection order diverged from naive");
    assert_eq!(approx.k(), k);
    for t in 0..k {
        for i in 0..n {
            assert_eq!(
                approx.c.data[i * k + t].to_bits(),
                c[t * n + i].to_bits(),
                "C({i},{t}) diverged"
            );
        }
    }
    for i in 0..k {
        for j in 0..k {
            assert_eq!(
                approx.winv.data[i * k + j].to_bits(),
                winv[i * cap + j].to_bits(),
                "W⁻¹({i},{j}) diverged"
            );
        }
    }
}
