//! Distributed-coordinator tests: oASIS-P ≡ sequential oASIS for every
//! worker count (DESIGN.md invariant 4), communication accounting, fault
//! injection, and end-to-end accuracy.

use oasis::coordinator::{
    run_oasis_p, FailureSpec, OasisPConfig, OasisPSession, ShardPlan,
};
use oasis::data::generators::{abalone_like, two_moons};
use oasis::data::{loader, LoadLimits};
use oasis::kernels::{Gaussian, Kernel};
use oasis::nystrom::{relative_frobenius_error, sampled_relative_error};
use oasis::sampling::{
    oasis::Oasis, oasis::Variant, run_to_completion, ColumnSampler,
    ImplicitOracle, SamplerSession, StoppingRule,
};
use std::sync::Arc;

fn gaussian(ds: &oasis::data::Dataset, frac: f64) -> Arc<dyn Kernel + Send + Sync> {
    Arc::new(Gaussian::with_sigma_fraction(ds, frac))
}

/// Invariant 4: identical selection sequence to the sequential sampler for
/// p ∈ {1, 2, 3, 5, 8}.
#[test]
fn matches_sequential_for_all_worker_counts() {
    let ds = two_moons(240, 0.05, 31);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let (l, k0, seed) = (30usize, 5usize, 17u64);
    let (_, seq_trace) = Oasis::new(l, k0, 1e-12, seed)
        .with_variant(Variant::PaperR)
        .sample_traced(&oracle)
        .unwrap();
    for p in [1usize, 2, 3, 5, 8] {
        let cfg = OasisPConfig::new(l, k0, p).with_seed(seed);
        let (approx, report) =
            run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg).unwrap();
        assert_eq!(
            report.trace.order, seq_trace.order,
            "worker count {p} diverged from sequential"
        );
        assert_eq!(approx.indices, seq_trace.order);
    }
}

/// The distributed result is a valid Nyström approximation: W·W⁻¹ ≈ I and
/// the error matches the sequential sampler's.
#[test]
fn distributed_approximation_is_correct() {
    let ds = abalone_like(400, 3);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.2);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let cfg = OasisPConfig::new(40, 6, 4).with_seed(23);
    let (approx, _) = run_oasis_p(&ds, gaussian(&ds, 0.2), &cfg).unwrap();
    let w = approx.c.select_rows(&approx.indices);
    let prod = w.matmul(&approx.winv);
    let dist = prod.fro_dist(&oasis::linalg::Mat::eye(approx.k()));
    assert!(dist < 1e-6, "‖WW⁻¹−I‖ = {dist}");

    let e_dist = relative_frobenius_error(&oracle, &approx);
    let seq = Oasis::new(40, 6, 1e-12, 23)
        .sample(&oracle)
        .unwrap();
    let e_seq = relative_frobenius_error(&oracle, &seq);
    assert!(
        (e_dist - e_seq).abs() < 1e-9 + 0.01 * e_seq,
        "dist {e_dist} vs seq {e_seq}"
    );
}

/// Communication scales with points-broadcast, not with n — the paper's
/// core scalability claim for oASIS-P.
#[test]
fn communication_independent_of_n() {
    let cfg = |l| OasisPConfig::new(l, 4, 4).with_seed(7);
    let small = two_moons(200, 0.05, 1);
    let large = two_moons(2_000, 0.05, 1);
    let (_, rep_small) = run_oasis_p(&small, gaussian(&small, 0.1), &cfg(20)).unwrap();
    let (_, rep_large) = run_oasis_p(&large, gaussian(&large, 0.1), &cfg(20)).unwrap();
    let bs = rep_small.metrics.broadcast_bytes();
    let bl = rep_large.metrics.broadcast_bytes();
    // same ℓ and dim ⇒ broadcast volume within 2× despite 10× data
    assert!(
        bl < bs * 2,
        "broadcast grew with n: {bs} → {bl} (should be ~constant)"
    );
}

/// Fault injection: a worker dying mid-run surfaces as a clean error, not
/// a deadlock (leader timeout) or a wrong result.
#[test]
fn worker_failure_is_detected() {
    let ds = two_moons(150, 0.05, 5);
    let mut cfg = OasisPConfig::new(20, 4, 3).with_seed(9);
    cfg.failure = Some(FailureSpec { worker: 1, at_iteration: 3 });
    cfg.timeout = std::time::Duration::from_secs(5);
    let res = run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg);
    let err = res.err().expect("expected failure to propagate");
    let msg = format!("{err}");
    assert!(
        msg.contains("worker") || msg.contains("recv"),
        "unexpected error text: {msg}"
    );
}

/// Tolerance-based early stop works distributed (rank-limited data).
#[test]
fn distributed_early_stop_on_exact_recovery() {
    let ds = oasis::data::generators::gauss_2d_plus_3d(100, 100, 2);
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(oasis::kernels::Linear);
    let cfg = OasisPConfig::new(30, 1, 4).with_seed(3).with_tol(1e-6);
    let (approx, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
    assert!(
        approx.k() <= 5,
        "should stop near rank 3, got k = {}",
        approx.k()
    );
    assert!(report.trace.order.len() == approx.k());
    // exactness via sampled estimator
    let lin = oasis::kernels::Linear;
    let oracle = ImplicitOracle::new(&ds, &lin);
    let err = sampled_relative_error(&oracle, &approx, 20_000, 5);
    assert!(err < 1e-5, "err {err}");
}

/// SHARD READS ≡ WHOLE FILE: a run whose workers each read only their
/// own byte range of the binary dataset file produces bit-identical
/// results to the in-memory run over the whole dataset — indices, C, and
/// W⁻¹ — and still supports the mid-run snapshot gather. (Explicit σ:
/// the shard-read leader has no dataset to resolve a σ fraction from.)
#[test]
fn shard_file_reads_match_whole_file_run() {
    let dir = std::env::temp_dir()
        .join("oasis-dist-shard-test")
        .join(format!("r{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ds = two_moons(220, 0.05, 12);
    let path = dir.join("points.mat");
    loader::save_matrix(&path, &ds).unwrap();
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
    let cfg = OasisPConfig::new(26, 4, 3).with_seed(19);

    // reference: the leader materializes the dataset and splits in memory
    let (reference, _) = run_oasis_p(&ds, kernel.clone(), &cfg).unwrap();

    // sharded: workers read their own byte ranges; the leader only knows
    // (n, dim) from the header
    let (n, dim) = loader::peek_matrix_dims(&path).unwrap();
    assert_eq!((n, dim), (220, 2));
    let mut session = OasisPSession::start_with_plan(
        ShardPlan::File {
            path: path.clone(),
            n,
            limits: LoadLimits::unlimited(),
        },
        kernel,
        cfg,
    )
    .unwrap();
    for _ in 0..8 {
        session.step().unwrap();
    }
    // mid-run snapshot still works without any leader-side dataset, and
    // the leader's selected-points mirror tracks Λ
    let snap = session.snapshot().unwrap();
    assert_eq!(snap.indices, &reference.indices[..snap.k()]);
    let pts = session.selected_points(0).expect("leader mirrors Λ's points");
    assert_eq!(pts.len(), session.k());
    // the incremental tail view agrees with the full mirror
    assert_eq!(session.selected_points(10).unwrap()[..], pts[10..]);
    for (t, &g) in session.indices().iter().enumerate() {
        for (a, b) in pts[t].iter().zip(ds.point(g)) {
            assert_eq!(a.to_bits(), b.to_bits(), "mirrored point diverged");
        }
    }
    run_to_completion(&mut session, &StoppingRule::budget(26)).unwrap();
    let (sharded, report) = session.finish_run().unwrap();
    assert_eq!(report.workers, 3);
    assert_eq!(sharded.indices, reference.indices);
    assert_eq!(sharded.c.data, reference.c.data);
    assert_eq!(sharded.winv.data, reference.winv.data);

    // a worker that cannot read its shard surfaces as a clean error, not
    // a hang: point the plan at a missing file
    let missing = OasisPSession::start_with_plan(
        ShardPlan::File {
            path: dir.join("absent.mat"),
            n: 220,
            limits: LoadLimits::unlimited(),
        },
        Arc::new(Gaussian::new(0.6)),
        OasisPConfig::new(10, 2, 2).with_seed(1),
    );
    assert!(missing.is_err(), "missing shard file must fail to start");
    std::fs::remove_dir_all(&dir).ok();
}

/// Scratch dir + binary dataset file for the TCP fleet tests (workers
/// shard-read the file themselves, so it must exist on disk).
fn shard_fixture(
    tag: &str,
    n: usize,
    seed: u64,
) -> (std::path::PathBuf, std::path::PathBuf, oasis::data::Dataset) {
    let dir = std::env::temp_dir()
        .join(format!("oasis-dist-{tag}"))
        .join(format!("r{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ds = two_moons(n, 0.05, seed);
    let path = dir.join("points.mat");
    loader::save_matrix(&path, &ds).unwrap();
    (dir, path, ds)
}

fn file_plan(path: &std::path::Path, n: usize) -> ShardPlan {
    ShardPlan::File {
        path: path.to_path_buf(),
        n,
        limits: LoadLimits::unlimited(),
    }
}

/// TCP TRANSPORT ≡ IN-PROCESS CHANNELS: the same run driven over real
/// localhost sockets — `run_worker` in threads standing in for worker
/// processes — selects bit-identical indices and factors. This is the
/// tentpole invariant: the wire protocol (f64s as raw bits, one merge
/// candidate per round at the default width) adds no drift.
#[test]
fn tcp_workers_match_in_process_run() {
    let (dir, path, _ds) = shard_fixture("tcp-parity", 200, 21);
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
    let cfg = || {
        let mut c = OasisPConfig::new(22, 4, 3).with_seed(19);
        c.timeout = std::time::Duration::from_secs(20);
        c
    };

    let mut reference =
        OasisPSession::start_with_plan(file_plan(&path, 200), kernel.clone(), cfg())
            .unwrap();
    run_to_completion(&mut reference, &StoppingRule::budget(22)).unwrap();
    let (reference, _) = reference.finish_run().unwrap();

    let transport = oasis::coordinator::TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..3)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                oasis::coordinator::run_worker(
                    &addr,
                    oasis::coordinator::WorkerRunOpts::default(),
                )
                .unwrap()
            })
        })
        .collect();
    let mut session = OasisPSession::start_with_transport(
        Box::new(transport),
        file_plan(&path, 200),
        kernel,
        cfg(),
    )
    .unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(22)).unwrap();
    // per-worker wire counters surface through the session trait
    let stats = session.worker_stats().expect("distributed session has stats");
    let rendered = format!("{stats}");
    assert!(rendered.contains("wire_bytes"), "stats: {rendered}");
    let (tcp, report) = session.finish_run().unwrap();
    for w in workers {
        w.join().unwrap();
    }

    assert_eq!(report.workers, 3);
    assert_eq!(tcp.indices, reference.indices);
    assert_eq!(tcp.c.data, reference.c.data);
    assert_eq!(tcp.winv.data, reference.winv.data);
    std::fs::remove_dir_all(&dir).ok();
}

/// Same invariant with real `oasis worker` PROCESSES over localhost —
/// the full deployment story: separate address spaces, each process
/// shard-reading its own byte range of the dataset file.
#[test]
fn tcp_worker_processes_match_in_process_run() {
    let (dir, path, _ds) = shard_fixture("tcp-proc", 180, 33);
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
    let cfg = || {
        let mut c = OasisPConfig::new(18, 3, 2).with_seed(5);
        c.timeout = std::time::Duration::from_secs(30);
        c
    };

    let mut reference =
        OasisPSession::start_with_plan(file_plan(&path, 180), kernel.clone(), cfg())
            .unwrap();
    run_to_completion(&mut reference, &StoppingRule::budget(18)).unwrap();
    let (reference, _) = reference.finish_run().unwrap();

    let transport = oasis::coordinator::TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.local_addr().unwrap().to_string();
    let mut children: Vec<_> = (0..2)
        .map(|_| {
            std::process::Command::new(env!("CARGO_BIN_EXE_oasis"))
                .args(["worker", "--join", &addr])
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::null())
                .spawn()
                .expect("worker process spawns")
        })
        .collect();
    let mut session = OasisPSession::start_with_transport(
        Box::new(transport),
        file_plan(&path, 180),
        kernel,
        cfg(),
    )
    .unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(18)).unwrap();
    let (tcp, _) = session.finish_run().unwrap();
    // Finish was broadcast — workers exit on their own
    for c in &mut children {
        assert!(wait_with_deadline(c, std::time::Duration::from_secs(20)));
    }

    assert_eq!(tcp.indices, reference.indices);
    assert_eq!(tcp.c.data, reference.c.data);
    assert_eq!(tcp.winv.data, reference.winv.data);
    std::fs::remove_dir_all(&dir).ok();
}

/// A worker process killed mid-run does not sink the fleet: the leader
/// detects the dead connection, re-shards its rows onto the survivors,
/// and finishes with a full-budget, numerically valid approximation.
/// (Selection after the death is not bit-identical to the undisturbed
/// run — the invariant is completion and correctness, not the order.)
#[test]
fn tcp_worker_death_reshards_onto_survivors() {
    let (dir, path, _ds) = shard_fixture("tcp-kill", 210, 44);
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
    let mut cfg = OasisPConfig::new(24, 4, 3).with_seed(11);
    cfg.timeout = std::time::Duration::from_secs(30);

    let transport = oasis::coordinator::TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.local_addr().unwrap().to_string();
    let mut children: Vec<_> = (0..3)
        .map(|_| {
            std::process::Command::new(env!("CARGO_BIN_EXE_oasis"))
                .args(["worker", "--join", &addr, "--throttle-ms", "5"])
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::null())
                .spawn()
                .expect("worker process spawns")
        })
        .collect();
    let mut session = OasisPSession::start_with_transport(
        Box::new(transport),
        file_plan(&path, 210),
        kernel,
        cfg,
    )
    .unwrap();
    for _ in 0..6 {
        session.step().unwrap();
    }
    // murder one worker between rounds; the reader thread's EOF turns
    // into a Gone signal and the next argmax round re-shards
    children[1].kill().unwrap();
    children[1].wait().unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(24)).unwrap();
    let (approx, report) = session.finish_run().unwrap();
    for c in &mut children {
        c.kill().ok();
        c.wait().ok();
    }

    assert_eq!(approx.k(), 24, "run must reach the full budget");
    assert!(
        report.metrics.reshards() >= 1,
        "death must be recovered via a reshard: {}",
        report.metrics.summary()
    );
    let w = approx.c.select_rows(&approx.indices);
    let dist = w.matmul(&approx.winv).fro_dist(&oasis::linalg::Mat::eye(24));
    assert!(dist < 1e-6, "post-reshard factors invalid: ‖WW⁻¹−I‖ = {dist}");
    std::fs::remove_dir_all(&dir).ok();
}

/// `try_wait` poll loop (std has no wait_timeout); kills on expiry so a
/// hung worker cannot wedge the suite.
fn wait_with_deadline(
    child: &mut std::process::Child,
    limit: std::time::Duration,
) -> bool {
    let start = std::time::Instant::now();
    while start.elapsed() < limit {
        if child.try_wait().unwrap().is_some() {
            return true;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    child.kill().ok();
    child.wait().ok();
    false
}

/// The same recovery path exercised hermetically: FailureSpec injects a
/// mid-run death into an in-process fleet. On a file plan (survivors can
/// re-read the dead worker's rows) the run completes; the Memory-plan
/// equivalent is `worker_failure_is_detected` above, which must bail.
#[test]
fn file_plan_failure_injection_recovers() {
    let (dir, path, _ds) = shard_fixture("inject", 160, 9);
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
    let mut cfg = OasisPConfig::new(20, 4, 3).with_seed(9);
    cfg.failure = Some(FailureSpec { worker: 1, at_iteration: 3 });
    cfg.timeout = std::time::Duration::from_secs(10);
    let mut session =
        OasisPSession::start_with_plan(file_plan(&path, 160), kernel, cfg)
            .unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(20)).unwrap();
    let (approx, report) = session.finish_run().unwrap();
    assert_eq!(approx.k(), 20);
    assert!(report.metrics.reshards() >= 1, "{}", report.metrics.summary());
    let w = approx.c.select_rows(&approx.indices);
    let dist = w.matmul(&approx.winv).fro_dist(&oasis::linalg::Mat::eye(20));
    assert!(dist < 1e-6, "‖WW⁻¹−I‖ = {dist}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Report metrics are self-consistent.
#[test]
fn metrics_consistency() {
    let ds = two_moons(120, 0.05, 6);
    let p = 3;
    let cfg = OasisPConfig::new(15, 3, p).with_seed(11);
    let (_, report) = run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg).unwrap();
    let m = &report.metrics;
    assert_eq!(report.workers, p);
    // 12 adaptive rounds + 1 final gather round
    assert!(m.iterations() >= 12, "iterations {}", m.iterations());
    assert!(m.broadcast_msgs() > 0 && m.gather_msgs() > 0);
    assert!(m.worker_compute_secs() >= 0.0);
    assert!(report.wall_secs > 0.0);
}
