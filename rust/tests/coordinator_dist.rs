//! Distributed-coordinator tests: oASIS-P ≡ sequential oASIS for every
//! worker count (DESIGN.md invariant 4), communication accounting, fault
//! injection, and end-to-end accuracy.

use oasis::coordinator::{
    run_oasis_p, FailureSpec, OasisPConfig, OasisPSession, ShardPlan,
};
use oasis::data::generators::{abalone_like, two_moons};
use oasis::data::{loader, LoadLimits};
use oasis::kernels::{Gaussian, Kernel};
use oasis::nystrom::{relative_frobenius_error, sampled_relative_error};
use oasis::sampling::{
    oasis::Oasis, oasis::Variant, run_to_completion, ColumnSampler,
    ImplicitOracle, SamplerSession, StoppingRule,
};
use std::sync::Arc;

fn gaussian(ds: &oasis::data::Dataset, frac: f64) -> Arc<dyn Kernel + Send + Sync> {
    Arc::new(Gaussian::with_sigma_fraction(ds, frac))
}

/// Invariant 4: identical selection sequence to the sequential sampler for
/// p ∈ {1, 2, 3, 5, 8}.
#[test]
fn matches_sequential_for_all_worker_counts() {
    let ds = two_moons(240, 0.05, 31);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let (l, k0, seed) = (30usize, 5usize, 17u64);
    let (_, seq_trace) = Oasis::new(l, k0, 1e-12, seed)
        .with_variant(Variant::PaperR)
        .sample_traced(&oracle)
        .unwrap();
    for p in [1usize, 2, 3, 5, 8] {
        let cfg = OasisPConfig::new(l, k0, p).with_seed(seed);
        let (approx, report) =
            run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg).unwrap();
        assert_eq!(
            report.trace.order, seq_trace.order,
            "worker count {p} diverged from sequential"
        );
        assert_eq!(approx.indices, seq_trace.order);
    }
}

/// The distributed result is a valid Nyström approximation: W·W⁻¹ ≈ I and
/// the error matches the sequential sampler's.
#[test]
fn distributed_approximation_is_correct() {
    let ds = abalone_like(400, 3);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.2);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let cfg = OasisPConfig::new(40, 6, 4).with_seed(23);
    let (approx, _) = run_oasis_p(&ds, gaussian(&ds, 0.2), &cfg).unwrap();
    let w = approx.c.select_rows(&approx.indices);
    let prod = w.matmul(&approx.winv);
    let dist = prod.fro_dist(&oasis::linalg::Mat::eye(approx.k()));
    assert!(dist < 1e-6, "‖WW⁻¹−I‖ = {dist}");

    let e_dist = relative_frobenius_error(&oracle, &approx);
    let seq = Oasis::new(40, 6, 1e-12, 23)
        .sample(&oracle)
        .unwrap();
    let e_seq = relative_frobenius_error(&oracle, &seq);
    assert!(
        (e_dist - e_seq).abs() < 1e-9 + 0.01 * e_seq,
        "dist {e_dist} vs seq {e_seq}"
    );
}

/// Communication scales with points-broadcast, not with n — the paper's
/// core scalability claim for oASIS-P.
#[test]
fn communication_independent_of_n() {
    let cfg = |l| OasisPConfig::new(l, 4, 4).with_seed(7);
    let small = two_moons(200, 0.05, 1);
    let large = two_moons(2_000, 0.05, 1);
    let (_, rep_small) = run_oasis_p(&small, gaussian(&small, 0.1), &cfg(20)).unwrap();
    let (_, rep_large) = run_oasis_p(&large, gaussian(&large, 0.1), &cfg(20)).unwrap();
    let bs = rep_small.metrics.broadcast_bytes();
    let bl = rep_large.metrics.broadcast_bytes();
    // same ℓ and dim ⇒ broadcast volume within 2× despite 10× data
    assert!(
        bl < bs * 2,
        "broadcast grew with n: {bs} → {bl} (should be ~constant)"
    );
}

/// Fault injection: a worker dying mid-run surfaces as a clean error, not
/// a deadlock (leader timeout) or a wrong result.
#[test]
fn worker_failure_is_detected() {
    let ds = two_moons(150, 0.05, 5);
    let mut cfg = OasisPConfig::new(20, 4, 3).with_seed(9);
    cfg.failure = Some(FailureSpec { worker: 1, at_iteration: 3 });
    cfg.timeout = std::time::Duration::from_secs(5);
    let res = run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg);
    let err = res.err().expect("expected failure to propagate");
    let msg = format!("{err}");
    assert!(
        msg.contains("worker") || msg.contains("recv"),
        "unexpected error text: {msg}"
    );
}

/// Tolerance-based early stop works distributed (rank-limited data).
#[test]
fn distributed_early_stop_on_exact_recovery() {
    let ds = oasis::data::generators::gauss_2d_plus_3d(100, 100, 2);
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(oasis::kernels::Linear);
    let cfg = OasisPConfig::new(30, 1, 4).with_seed(3).with_tol(1e-6);
    let (approx, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
    assert!(
        approx.k() <= 5,
        "should stop near rank 3, got k = {}",
        approx.k()
    );
    assert!(report.trace.order.len() == approx.k());
    // exactness via sampled estimator
    let lin = oasis::kernels::Linear;
    let oracle = ImplicitOracle::new(&ds, &lin);
    let err = sampled_relative_error(&oracle, &approx, 20_000, 5);
    assert!(err < 1e-5, "err {err}");
}

/// SHARD READS ≡ WHOLE FILE: a run whose workers each read only their
/// own byte range of the binary dataset file produces bit-identical
/// results to the in-memory run over the whole dataset — indices, C, and
/// W⁻¹ — and still supports the mid-run snapshot gather. (Explicit σ:
/// the shard-read leader has no dataset to resolve a σ fraction from.)
#[test]
fn shard_file_reads_match_whole_file_run() {
    let dir = std::env::temp_dir()
        .join("oasis-dist-shard-test")
        .join(format!("r{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ds = two_moons(220, 0.05, 12);
    let path = dir.join("points.mat");
    loader::save_matrix(&path, &ds).unwrap();
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
    let cfg = OasisPConfig::new(26, 4, 3).with_seed(19);

    // reference: the leader materializes the dataset and splits in memory
    let (reference, _) = run_oasis_p(&ds, kernel.clone(), &cfg).unwrap();

    // sharded: workers read their own byte ranges; the leader only knows
    // (n, dim) from the header
    let (n, dim) = loader::peek_matrix_dims(&path).unwrap();
    assert_eq!((n, dim), (220, 2));
    let mut session = OasisPSession::start_with_plan(
        ShardPlan::File {
            path: path.clone(),
            n,
            limits: LoadLimits::unlimited(),
        },
        kernel,
        cfg,
    )
    .unwrap();
    for _ in 0..8 {
        session.step().unwrap();
    }
    // mid-run snapshot still works without any leader-side dataset, and
    // the leader's selected-points mirror tracks Λ
    let snap = session.snapshot().unwrap();
    assert_eq!(snap.indices, &reference.indices[..snap.k()]);
    let pts = session.selected_points(0).expect("leader mirrors Λ's points");
    assert_eq!(pts.len(), session.k());
    // the incremental tail view agrees with the full mirror
    assert_eq!(session.selected_points(10).unwrap()[..], pts[10..]);
    for (t, &g) in session.indices().iter().enumerate() {
        for (a, b) in pts[t].iter().zip(ds.point(g)) {
            assert_eq!(a.to_bits(), b.to_bits(), "mirrored point diverged");
        }
    }
    run_to_completion(&mut session, &StoppingRule::budget(26)).unwrap();
    let (sharded, report) = session.finish_run().unwrap();
    assert_eq!(report.workers, 3);
    assert_eq!(sharded.indices, reference.indices);
    assert_eq!(sharded.c.data, reference.c.data);
    assert_eq!(sharded.winv.data, reference.winv.data);

    // a worker that cannot read its shard surfaces as a clean error, not
    // a hang: point the plan at a missing file
    let missing = OasisPSession::start_with_plan(
        ShardPlan::File {
            path: dir.join("absent.mat"),
            n: 220,
            limits: LoadLimits::unlimited(),
        },
        Arc::new(Gaussian::new(0.6)),
        OasisPConfig::new(10, 2, 2).with_seed(1),
    );
    assert!(missing.is_err(), "missing shard file must fail to start");
    std::fs::remove_dir_all(&dir).ok();
}

/// Report metrics are self-consistent.
#[test]
fn metrics_consistency() {
    let ds = two_moons(120, 0.05, 6);
    let p = 3;
    let cfg = OasisPConfig::new(15, 3, p).with_seed(11);
    let (_, report) = run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg).unwrap();
    let m = &report.metrics;
    assert_eq!(report.workers, p);
    // 12 adaptive rounds + 1 final gather round
    assert!(m.iterations() >= 12, "iterations {}", m.iterations());
    assert!(m.broadcast_msgs() > 0 && m.gather_msgs() > 0);
    assert!(m.worker_compute_secs() >= 0.0);
    assert!(report.wall_secs > 0.0);
}
