//! Distributed-coordinator tests: oASIS-P ≡ sequential oASIS for every
//! worker count (DESIGN.md invariant 4), communication accounting, fault
//! injection, and end-to-end accuracy.

use oasis::coordinator::{run_oasis_p, FailureSpec, OasisPConfig};
use oasis::data::generators::{abalone_like, two_moons};
use oasis::kernels::{Gaussian, Kernel};
use oasis::nystrom::{relative_frobenius_error, sampled_relative_error};
use oasis::sampling::{oasis::Oasis, oasis::Variant, ColumnSampler, ImplicitOracle};
use std::sync::Arc;

fn gaussian(ds: &oasis::data::Dataset, frac: f64) -> Arc<dyn Kernel + Send + Sync> {
    Arc::new(Gaussian::with_sigma_fraction(ds, frac))
}

/// Invariant 4: identical selection sequence to the sequential sampler for
/// p ∈ {1, 2, 3, 5, 8}.
#[test]
fn matches_sequential_for_all_worker_counts() {
    let ds = two_moons(240, 0.05, 31);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let (l, k0, seed) = (30usize, 5usize, 17u64);
    let (_, seq_trace) = Oasis::new(l, k0, 1e-12, seed)
        .with_variant(Variant::PaperR)
        .sample_traced(&oracle)
        .unwrap();
    for p in [1usize, 2, 3, 5, 8] {
        let cfg = OasisPConfig::new(l, k0, p).with_seed(seed);
        let (approx, report) =
            run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg).unwrap();
        assert_eq!(
            report.trace.order, seq_trace.order,
            "worker count {p} diverged from sequential"
        );
        assert_eq!(approx.indices, seq_trace.order);
    }
}

/// The distributed result is a valid Nyström approximation: W·W⁻¹ ≈ I and
/// the error matches the sequential sampler's.
#[test]
fn distributed_approximation_is_correct() {
    let ds = abalone_like(400, 3);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.2);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let cfg = OasisPConfig::new(40, 6, 4).with_seed(23);
    let (approx, _) = run_oasis_p(&ds, gaussian(&ds, 0.2), &cfg).unwrap();
    let w = approx.c.select_rows(&approx.indices);
    let prod = w.matmul(&approx.winv);
    let dist = prod.fro_dist(&oasis::linalg::Mat::eye(approx.k()));
    assert!(dist < 1e-6, "‖WW⁻¹−I‖ = {dist}");

    let e_dist = relative_frobenius_error(&oracle, &approx);
    let seq = Oasis::new(40, 6, 1e-12, 23)
        .sample(&oracle)
        .unwrap();
    let e_seq = relative_frobenius_error(&oracle, &seq);
    assert!(
        (e_dist - e_seq).abs() < 1e-9 + 0.01 * e_seq,
        "dist {e_dist} vs seq {e_seq}"
    );
}

/// Communication scales with points-broadcast, not with n — the paper's
/// core scalability claim for oASIS-P.
#[test]
fn communication_independent_of_n() {
    let cfg = |l| OasisPConfig::new(l, 4, 4).with_seed(7);
    let small = two_moons(200, 0.05, 1);
    let large = two_moons(2_000, 0.05, 1);
    let (_, rep_small) = run_oasis_p(&small, gaussian(&small, 0.1), &cfg(20)).unwrap();
    let (_, rep_large) = run_oasis_p(&large, gaussian(&large, 0.1), &cfg(20)).unwrap();
    let bs = rep_small.metrics.broadcast_bytes();
    let bl = rep_large.metrics.broadcast_bytes();
    // same ℓ and dim ⇒ broadcast volume within 2× despite 10× data
    assert!(
        bl < bs * 2,
        "broadcast grew with n: {bs} → {bl} (should be ~constant)"
    );
}

/// Fault injection: a worker dying mid-run surfaces as a clean error, not
/// a deadlock (leader timeout) or a wrong result.
#[test]
fn worker_failure_is_detected() {
    let ds = two_moons(150, 0.05, 5);
    let mut cfg = OasisPConfig::new(20, 4, 3).with_seed(9);
    cfg.failure = Some(FailureSpec { worker: 1, at_iteration: 3 });
    cfg.timeout = std::time::Duration::from_secs(5);
    let res = run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg);
    let err = res.err().expect("expected failure to propagate");
    let msg = format!("{err}");
    assert!(
        msg.contains("worker") || msg.contains("recv"),
        "unexpected error text: {msg}"
    );
}

/// Tolerance-based early stop works distributed (rank-limited data).
#[test]
fn distributed_early_stop_on_exact_recovery() {
    let ds = oasis::data::generators::gauss_2d_plus_3d(100, 100, 2);
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(oasis::kernels::Linear);
    let cfg = OasisPConfig::new(30, 1, 4).with_seed(3).with_tol(1e-6);
    let (approx, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
    assert!(
        approx.k() <= 5,
        "should stop near rank 3, got k = {}",
        approx.k()
    );
    assert!(report.trace.order.len() == approx.k());
    // exactness via sampled estimator
    let lin = oasis::kernels::Linear;
    let oracle = ImplicitOracle::new(&ds, &lin);
    let err = sampled_relative_error(&oracle, &approx, 20_000, 5);
    assert!(err < 1e-5, "err {err}");
}

/// Report metrics are self-consistent.
#[test]
fn metrics_consistency() {
    let ds = two_moons(120, 0.05, 6);
    let p = 3;
    let cfg = OasisPConfig::new(15, 3, p).with_seed(11);
    let (_, report) = run_oasis_p(&ds, gaussian(&ds, 0.1), &cfg).unwrap();
    let m = &report.metrics;
    assert_eq!(report.workers, p);
    // 12 adaptive rounds + 1 final gather round
    assert!(m.iterations() >= 12, "iterations {}", m.iterations());
    assert!(m.broadcast_msgs() > 0 && m.gather_msgs() > 0);
    assert!(m.worker_compute_secs() >= 0.0);
    assert!(report.wall_secs > 0.0);
}
