//! PJRT runtime tests: artifacts load, execute, and agree with the native
//! Rust path (DESIGN.md invariant 7). These tests require `make artifacts`
//! to have been run (skipped gracefully otherwise).

use oasis::data::generators::two_moons;
use oasis::kernels::{Gaussian, Kernel};
use oasis::nystrom::relative_frobenius_error;
use oasis::runtime::{accel::PjrtOasis, Accel, Manifest};
use oasis::sampling::{oasis::Oasis, ColumnSampler, ImplicitOracle};

fn accel_or_skip() -> Option<Accel> {
    match Accel::try_default() {
        Some(a) => Some(a),
        None => {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_ops() {
    let dir = Manifest::default_dir();
    let m = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: no manifest");
            return;
        }
    };
    assert!(!m.for_op("delta_scores").is_empty());
    assert!(!m.for_op("gaussian_columns").is_empty());
    assert!(!m.for_op("update_r").is_empty());
    for a in &m.artifacts {
        assert!(a.path.exists(), "missing artifact file {}", a.path.display());
    }
}

#[test]
fn executor_loads_and_runs_delta_artifact() {
    let mut accel = match accel_or_skip() {
        Some(a) => a,
        None => return,
    };
    let art = accel
        .manifest
        .best_fit("delta_scores", 1000, &[("l", 16)])
        .expect("delta artifact")
        .clone();
    let n_pad = art.dim("n").unwrap();
    let l_pad = art.dim("l").unwrap();
    accel.executor.load(&art).unwrap();
    // Δ = d − colsum(C∘R): craft C, R with known result on a small live
    // block, zero padding elsewhere.
    let (n, k) = (100usize, 8usize);
    let mut c = vec![0.0f32; n_pad * l_pad];
    let mut r = vec![0.0f32; l_pad * n_pad];
    let mut d = vec![0.0f32; n_pad];
    let mut expected = vec![0.0f64; n];
    for i in 0..n {
        d[i] = (i as f32) * 0.01;
        let mut acc = 0.0f64;
        for t in 0..k {
            let cv = ((i * 7 + t * 3) % 5) as f32 * 0.1 - 0.2;
            let rv = ((i * 11 + t * 5) % 7) as f32 * 0.05 - 0.15;
            c[i * l_pad + t] = cv;
            r[t * n_pad + i] = rv;
            acc += (cv as f64) * (rv as f64);
        }
        expected[i] = d[i] as f64 - acc;
    }
    let outs = accel
        .executor
        .run_f32(
            &art.name,
            &[
                (&c, &[n_pad as i64, l_pad as i64]),
                (&r, &[l_pad as i64, n_pad as i64]),
                (&d, &[n_pad as i64]),
            ],
        )
        .unwrap();
    let delta = &outs[0];
    assert_eq!(delta.len(), n_pad);
    for i in 0..n {
        assert!(
            (delta[i] as f64 - expected[i]).abs() < 1e-5,
            "Δ[{i}] = {} vs {}",
            delta[i],
            expected[i]
        );
    }
    // padded region: Δ = d = 0
    for i in n..n_pad {
        assert_eq!(delta[i], 0.0);
    }
}

#[test]
fn gaussian_columns_artifact_matches_native_kernel() {
    let mut accel = match accel_or_skip() {
        Some(a) => a,
        None => return,
    };
    let ds = two_moons(200, 0.05, 3);
    let kern = Gaussian::new(0.8);
    // artifact path: z (200×2 → padded), z_sel = 5 points
    let sel: Vec<usize> = vec![0, 40, 80, 120, 160];
    let z_blk: Vec<f64> = (0..200).flat_map(|i| ds.point(i).to_vec()).collect();
    let z_sel: Vec<f64> = sel.iter().flat_map(|&i| ds.point(i).to_vec()).collect();
    let out = accel
        .gaussian_columns(&z_blk, 200, &z_sel, 5, 2, kern.inv_sigma_sq)
        .unwrap();
    for (si, &j) in sel.iter().enumerate() {
        for i in 0..200 {
            let native = kern.eval(ds.point(i), ds.point(j));
            let accel_v = out[i * 5 + si];
            assert!(
                (native - accel_v).abs() < 1e-5,
                "col {j} row {i}: native {native} vs accel {accel_v}"
            );
        }
    }
}

/// DESIGN.md invariant 7: the PJRT-scored oASIS reaches approximation
/// quality equivalent to the native sampler. Note the selection *sequence*
/// is allowed to differ: with a narrow Gaussian kernel most candidates
/// have Δ ≈ diag value, and f32 scoring rounds those near-ties to exact
/// ties, so argmax tie-breaking diverges — both runs still pick
/// incoherent columns and the resulting W stays invertible.
#[test]
fn pjrt_oasis_matches_native() {
    let mut accel = match accel_or_skip() {
        Some(a) => a,
        None => return,
    };
    let ds = two_moons(600, 0.05, 9);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let l = 40;
    let (native, tn) = Oasis::new(l, 5, 1e-12, 13).sample_traced(&oracle).unwrap();
    let pjrt = PjrtOasis::new(l, 5, 1e-12, 13);
    let (accel_approx, ta) = pjrt.sample_with(&mut accel, &oracle).unwrap();

    // seeds identical by construction
    assert_eq!(&tn.order[..5], &ta.order[..5]);
    assert_eq!(ta.order.len(), l);
    // equivalent approximation quality — the invariant that matters
    let e_native = relative_frobenius_error(&oracle, &native);
    let e_accel = relative_frobenius_error(&oracle, &accel_approx);
    assert!(
        e_accel < e_native * 2.0 + 1e-9,
        "accel error {e_accel} vs native {e_native}"
    );
    // the accelerated run's W⁻¹ is still a true inverse (its own columns
    // are linearly independent — Lemma 1 held under f32 scoring)
    let w = accel_approx.c.select_rows(&accel_approx.indices);
    let prod = w.matmul(&accel_approx.winv);
    let dist = prod.fro_dist(&oasis::linalg::Mat::eye(l));
    // f32 tie-breaking can pick slightly-worse-conditioned columns, so
    // this tolerance is looser than the native sampler's 1e-6
    assert!(dist < 1e-4, "accel ‖WW⁻¹−I‖ = {dist}");
}

/// On well-separated Δ values (clustered data, moderate kernel width) the
/// f32-scored sequence matches the native one exactly for many steps.
#[test]
fn pjrt_sequence_matches_on_separated_scores() {
    let mut accel = match accel_or_skip() {
        Some(a) => a,
        None => return,
    };
    let ds = oasis::data::generators::gaussian_clusters(500, 4, 8, 0.4, 3);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.4);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let l = 16;
    let (_, tn) = Oasis::new(l, 4, 1e-12, 21).sample_traced(&oracle).unwrap();
    let (_, ta) = PjrtOasis::new(l, 4, 1e-12, 21)
        .sample_with(&mut accel, &oracle)
        .unwrap();
    let common = tn
        .order
        .iter()
        .zip(&ta.order)
        .take_while(|(a, b)| a == b)
        .count();
    assert!(common >= 12, "only {common}/{l} selections agree");
}

#[test]
fn update_r_artifact_matches_native_eq6() {
    let mut accel = match accel_or_skip() {
        Some(a) => a,
        None => return,
    };
    let art = accel
        .manifest
        .best_fit("update_r", 4096, &[("l", 8)])
        .expect("update_r artifact")
        .clone();
    let (np, lp) = (art.dim("n").unwrap(), art.dim("l").unwrap());
    accel.executor.load(&art).unwrap();
    // live block k=6 in an lp-padded R; random-ish deterministic data
    let (n, k) = (300usize, 6usize);
    let mut r = vec![0.0f32; lp * np];
    let mut q = vec![0.0f32; lp];
    let mut c_row = vec![0.0f32; np];
    let mut c_new = vec![0.0f32; np];
    for t in 0..k {
        q[t] = (t as f32 * 0.37).sin();
        for i in 0..n {
            r[t * np + i] = ((t * 31 + i * 7) % 13) as f32 * 0.05 - 0.3;
        }
    }
    for i in 0..n {
        c_row[i] = (i as f32 * 0.011).cos();
        c_new[i] = (i as f32 * 0.017).sin();
    }
    let s = [0.8f32];
    let outs = accel
        .executor
        .run_f32(
            &art.name,
            &[
                (&r, &[lp as i64, np as i64]),
                (&q, &[lp as i64]),
                (&c_row, &[np as i64]),
                (&c_new, &[np as i64]),
                (&s, &[]),
            ],
        )
        .unwrap();
    let (r_top, r_new) = (&outs[0], &outs[1]);
    for t in 0..k {
        for i in 0..n {
            let diff = c_row[i] - c_new[i];
            let want = r[t * np + i] + 0.8 * q[t] * diff;
            let got = r_top[t * np + i];
            assert!(
                (want - got).abs() < 1e-5,
                "r_top[{t},{i}]: {got} vs {want}"
            );
        }
    }
    for i in 0..n {
        let want = -0.8 * (c_row[i] - c_new[i]);
        assert!((r_new[i] - want).abs() < 1e-5, "r_new[{i}]");
    }
    // padded rows (q = 0 there) must be untouched
    for t in k..lp {
        for i in 0..n {
            assert_eq!(r_top[t * np + i], r[t * np + i]);
        }
    }
}

#[test]
fn fused_iteration_artifact_selects_and_forms_column() {
    let mut accel = match accel_or_skip() {
        Some(a) => a,
        None => return,
    };
    let art = accel
        .manifest
        .best_fit("oasis_iteration", 4096, &[("l", 8), ("m", 2)])
        .expect("iteration artifact")
        .clone();
    let (np, lp, mp) = (
        art.dim("n").unwrap(),
        art.dim("l").unwrap(),
        art.dim("m").unwrap(),
    );
    accel.executor.load(&art).unwrap();
    let ds = two_moons(500, 0.05, 21);
    let kern = Gaussian::new(0.7);
    let n = ds.n();
    // state: k=0 live columns (C, R zero) ⇒ Δ = d = 1, argmax = first
    // unmasked index; mask out the first 3 so idx must be 3.
    let c = vec![0.0f32; np * lp];
    let r = vec![0.0f32; lp * np];
    let mut d = vec![0.0f32; np];
    let mut mask = vec![0.0f32; np];
    let mut z = vec![0.0f32; np * mp];
    for i in 0..n {
        d[i] = 1.0;
        mask[i] = if i < 3 { 0.0 } else { 1.0 };
        for t in 0..2 {
            z[i * mp + t] = ds.point(i)[t] as f32;
        }
    }
    let gamma = [kern.inv_sigma_sq as f32];
    let outs = accel
        .executor
        .run_f32(
            &art.name,
            &[
                (&c, &[np as i64, lp as i64]),
                (&r, &[lp as i64, np as i64]),
                (&d, &[np as i64]),
                (&mask, &[np as i64]),
                (&z, &[np as i64, mp as i64]),
                (&gamma, &[]),
            ],
        )
        .unwrap();
    let (delta, idx, col) = (&outs[0], &outs[1], &outs[2]);
    assert_eq!(idx[0] as usize, 3, "masked argmax should pick index 3");
    assert!((delta[10] - 1.0).abs() < 1e-6);
    // the returned column is the Gaussian kernel column of point 3
    for i in 0..n {
        let want = kern.eval(ds.point(i), ds.point(3));
        assert!(
            (col[i] as f64 - want).abs() < 1e-5,
            "col[{i}]: {} vs {want}",
            col[i]
        );
    }
}

#[test]
fn accel_errors_cleanly_on_oversize_problem() {
    let mut accel = match accel_or_skip() {
        Some(a) => a,
        None => return,
    };
    let ds = two_moons(100, 0.05, 2);
    let kern = Gaussian::new(0.5);
    let oracle = ImplicitOracle::new(&ds, &kern);
    // l beyond every artifact bucket (l_pad = 512) must be a clean error,
    // which the CLI uses to fall back to the native path.
    let pjrt = PjrtOasis::new(100, 5, 1e-12, 1);
    // n=100 fits, but max_cols=100 ≤ 512 — craft an n too large instead:
    let big = two_moons(20_000, 0.05, 2);
    let big_oracle = ImplicitOracle::new(&big, &kern);
    let err = pjrt.sample_with(&mut accel, &big_oracle);
    assert!(err.is_err(), "expected no-artifact error for n=20000");
    // and the in-range case still works afterwards
    let ok = pjrt.sample_with(&mut accel, &oracle);
    assert!(ok.is_ok());
}
