//! Property-based tests over the DESIGN.md §7 invariants, using the
//! `util::propcheck` harness (proptest substitute — see DESIGN.md §6).

use oasis::linalg::Mat;
use oasis::nystrom::relative_frobenius_error;
use oasis::sampling::{
    oasis::{Oasis, Variant},
    sis::Sis,
    ColumnSampler, ExplicitOracle,
};
use oasis::util::propcheck::{check, close, Config, Gen};

fn psd_oracle_case(g: &mut Gen<'_>) -> (Mat, usize) {
    let n = g.usize_in(8, 8 + g.size.min(56));
    let r = g.usize_in(2, n.min(12));
    let m = Mat::from_vec(n, n, g.psd_matrix(n, r));
    (m, r)
}

/// Invariant 2 (Theorem 1): oASIS recovers a rank-r PSD matrix to machine
/// precision within r selected columns.
#[test]
fn prop_exact_recovery_in_rank_steps() {
    check(
        Config { cases: 24, max_size: 48, ..Default::default() },
        |g| {
            let (m, r) = psd_oracle_case(g);
            let oracle = ExplicitOracle::new(&m);
            let approx = Oasis::new(r + 2, 1, 1e-9 * m.max_abs().max(1.0), 7)
                .sample(&oracle)
                .map_err(|e| e.to_string())?;
            if approx.k() > r + 2 {
                return Err(format!("selected {} columns for rank {r}", approx.k()));
            }
            let err = relative_frobenius_error(&oracle, &approx);
            if err > 1e-5 {
                return Err(format!("error {err} after rank-budget selection"));
            }
            Ok(())
        },
    );
}

/// Invariant 1 (Lemma 1): the iterated Eq. 5 inverse stays a true inverse
/// of W = G(Λ,Λ) at termination.
#[test]
fn prop_winv_is_inverse() {
    check(
        Config { cases: 20, max_size: 40, ..Default::default() },
        |g| {
            let (m, r) = psd_oracle_case(g);
            let oracle = ExplicitOracle::new(&m);
            let l = r.min(6);
            let approx = Oasis::new(l, 1, 1e-10 * m.max_abs().max(1.0), 3)
                .sample(&oracle)
                .map_err(|e| e.to_string())?;
            let w = approx.c.select_rows(&approx.indices);
            let prod = w.matmul(&approx.winv);
            let dist = prod.fro_dist(&Mat::eye(approx.k()));
            if dist > 1e-5 {
                return Err(format!("‖WW⁻¹−I‖ = {dist} at k={}", approx.k()));
            }
            Ok(())
        },
    );
}

/// Invariant 3: accelerated oASIS (both variants) equals naive SIS.
#[test]
fn prop_oasis_equals_sis() {
    check(
        Config { cases: 12, max_size: 32, ..Default::default() },
        |g| {
            let (m, _r) = psd_oracle_case(g);
            let oracle = ExplicitOracle::new(&m);
            let n = m.rows;
            let l = g.usize_in(3, n.min(10));
            let seed = g.usize_in(0, 1000) as u64;
            let (_, ts) = Sis::new(l, 2.min(l), 1e-10, seed)
                .sample_traced(&oracle)
                .map_err(|e| e.to_string())?;
            for v in [Variant::PaperR, Variant::Incremental] {
                let (_, to) = Oasis::new(l, 2.min(l), 1e-10, seed)
                    .with_variant(v)
                    .sample_traced(&oracle)
                    .map_err(|e| e.to_string())?;
                if ts.order != to.order {
                    return Err(format!(
                        "{v:?} diverged: sis {:?} vs oasis {:?}",
                        ts.order, to.order
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Invariant 5: Frobenius error is non-increasing in the column budget.
#[test]
fn prop_error_monotone_in_columns() {
    check(
        Config { cases: 12, max_size: 40, ..Default::default() },
        |g| {
            let (m, _) = psd_oracle_case(g);
            let oracle = ExplicitOracle::new(&m);
            let n = m.rows;
            let seed = g.usize_in(0, 100) as u64;
            let mut prev = f64::INFINITY;
            for l in [2usize, 4, 8].iter().filter(|&&l| l <= n) {
                let approx = Oasis::new(*l, 1, 0.0, seed)
                    .sample(&oracle)
                    .map_err(|e| e.to_string())?;
                let err = relative_frobenius_error(&oracle, &approx);
                if err > prev + 1e-7 {
                    return Err(format!("error rose {prev} → {err} at ℓ={l}"));
                }
                prev = err;
            }
            Ok(())
        },
    );
}

/// Invariant 6: G̃ agrees with G exactly on the sampled columns (·, Λ).
#[test]
fn prop_nystrom_exact_on_sampled_columns() {
    check(
        Config { cases: 16, max_size: 36, ..Default::default() },
        |g| {
            let (m, r) = psd_oracle_case(g);
            let oracle = ExplicitOracle::new(&m);
            let approx = Oasis::new(r.min(5), 1, 1e-10 * m.max_abs().max(1.0), 11)
                .sample(&oracle)
                .map_err(|e| e.to_string())?;
            let recon = approx.reconstruct();
            let scale = m.max_abs().max(1.0);
            for &j in &approx.indices {
                for i in 0..m.rows {
                    close(
                        recon.at(i, j) / scale,
                        m.at(i, j) / scale,
                        1e-6,
                        &format!("G̃({i},{j})"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// Invariant 4: oASIS-P selects the same sequence as sequential oASIS for
/// random shard counts and dataset shapes.
#[test]
fn prop_oasis_p_equals_sequential() {
    use oasis::coordinator::{run_oasis_p, OasisPConfig};
    use oasis::kernels::{Gaussian, Kernel};
    use oasis::sampling::{oasis::Variant, ImplicitOracle};
    use std::sync::Arc;
    check(
        Config { cases: 10, max_size: 40, ..Default::default() },
        |g| {
            let n = g.usize_in(20, 120);
            let dim = g.usize_in(1, 6);
            let noise = g.f64_in(0.01, 0.2);
            let ds = oasis::data::generators::gaussian_clusters(
                n,
                dim,
                g.usize_in(1, 4),
                noise,
                g.usize_in(0, 1000) as u64,
            );
            let l = g.usize_in(3, n.min(15));
            let k0 = g.usize_in(1, l.min(4));
            let p = g.usize_in(1, 7);
            let seed = g.usize_in(0, 500) as u64;
            let sigma = 1.0 + g.f64_in(0.0, 3.0);
            let kern = Gaussian::new(sigma);
            let oracle = ImplicitOracle::new(&ds, &kern);
            let (_, ts) = Oasis::new(l, k0, 1e-10, seed)
                .with_variant(Variant::PaperR)
                .sample_traced(&oracle)
                .map_err(|e| e.to_string())?;
            let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(sigma));
            let cfg = OasisPConfig::new(l, k0, p).with_seed(seed).with_tol(1e-10);
            let (_, rep) =
                run_oasis_p(&ds, kernel, &cfg).map_err(|e| e.to_string())?;
            if ts.order != rep.trace.order {
                return Err(format!(
                    "p={p} diverged: seq {:?} vs dist {:?}",
                    ts.order, rep.trace.order
                ));
            }
            Ok(())
        },
    );
}

/// Gaussian kernel matrices are PSD for any data (Mercer kernel), so the
/// whole pipeline's PSD assumption holds on generated inputs.
#[test]
fn prop_gaussian_kernel_matrix_is_psd() {
    use oasis::kernels::{kernel_matrix, Gaussian};
    check(
        Config { cases: 16, max_size: 30, ..Default::default() },
        |g| {
            let n = g.usize_in(2, 40);
            let dim = g.usize_in(1, 8);
            let pts: Vec<Vec<f64>> =
                (0..n).map(|_| g.normal_vec(dim)).collect();
            let ds = oasis::data::Dataset::from_rows(pts);
            let sigma = g.f64_in(0.1, 5.0);
            let gm = kernel_matrix(&ds, &Gaussian::new(sigma));
            let eig = oasis::linalg::sym_eig(&gm);
            let lmin = eig.vals.last().copied().unwrap_or(0.0);
            if lmin < -1e-8 * eig.vals[0].max(1.0) {
                return Err(format!("negative eigenvalue {lmin}"));
            }
            Ok(())
        },
    );
}

/// JSON writer/parser round-trip over randomly generated documents.
#[test]
fn prop_json_roundtrip() {
    use oasis::util::json::Json;
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.usize_in(0, 1) == 1),
            2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..g.usize_in(0, 12))
                    .map(|_| {
                        let c = g.usize_in(32, 126) as u8 as char;
                        c
                    })
                    .collect(),
            ),
            4 => Json::Arr(
                (0..g.usize_in(0, 4)).map(|_| gen_json(g, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(
        Config { cases: 120, max_size: 32, ..Default::default() },
        |g| {
            let doc = gen_json(g, 3);
            let text = doc.to_string();
            let parsed = Json::parse(&text)
                .map_err(|e| format!("reparse failed on {text}: {e}"))?;
            if parsed != doc {
                return Err(format!("roundtrip mismatch: {doc:?} vs {parsed:?}"));
            }
            Ok(())
        },
    );
}

fn bits_equal(what: &str, a: &[f64], b: &[f64]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{what}: bit divergence at {i}: {x:e} vs {y:e}"));
        }
    }
    Ok(())
}

/// Random matrix with the occasional exact zero, exercising the blocked
/// kernels' `aik == 0.0` skip (bit-neutral for finite inputs).
fn holey_mat(g: &mut Gen<'_>, rows: usize, cols: usize) -> Mat {
    let mut data = g.normal_vec(rows * cols);
    for v in data.iter_mut().skip(3).step_by(7) {
        *v = 0.0;
    }
    Mat::from_vec(rows, cols, data)
}

/// The blocked `Mat::matmul` is bit-identical to the naive single-
/// accumulator ijk loop across edge shapes: empty, 1×n, n×1, and sizes
/// straddling the MR=4 row quad and NB=256 column block.
#[test]
fn prop_blocked_matmul_bit_equals_naive() {
    check(
        Config { cases: 48, max_size: 32, ..Default::default() },
        |g| {
            let m = g.usize_in(0, 9);
            let k = g.usize_in(0, 9);
            let n = g.usize_in(0, 300);
            let a = holey_mat(g, m, k);
            let b = holey_mat(g, k, n);
            let mut want = Mat::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0;
                    for t in 0..k {
                        s += a.at(i, t) * b.at(t, j);
                    }
                    want.data[i * n + j] = s;
                }
            }
            bits_equal(&format!("matmul {m}×{k}×{n}"), &want.data, &a.matmul(&b).data)
        },
    );
}

/// The blocked `Mat::t_matmul` (AᵀB without materializing Aᵀ) is
/// bit-identical to the naive loop across TB=32 row-tile edges.
#[test]
fn prop_blocked_t_matmul_bit_equals_naive() {
    check(
        Config { cases: 48, max_size: 32, ..Default::default() },
        |g| {
            let k = g.usize_in(0, 9);
            let m = g.usize_in(0, 40);
            let n = g.usize_in(0, 300);
            let a = holey_mat(g, k, m);
            let b = holey_mat(g, k, n);
            let mut want = Mat::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0;
                    for t in 0..k {
                        s += a.at(t, i) * b.at(t, j);
                    }
                    want.data[i * n + j] = s;
                }
            }
            bits_equal(
                &format!("t_matmul {k}×{m}ᵀ·{k}×{n}"),
                &want.data,
                &a.t_matmul(&b).data,
            )
        },
    );
}

/// The dedicated Gram kernel `Mat::syrk` (AᵀA with mirrored triangle) is
/// bit-identical to the naive full product — f64 multiplication is
/// bitwise commutative, so the mirror introduces no divergence.
#[test]
fn prop_syrk_bit_equals_naive() {
    check(
        Config { cases: 48, max_size: 32, ..Default::default() },
        |g| {
            let k = g.usize_in(0, 9);
            let m = g.usize_in(0, 70);
            let a = holey_mat(g, k, m);
            let mut want = Mat::zeros(m, m);
            for i in 0..m {
                for j in 0..m {
                    let mut s = 0.0;
                    for t in 0..k {
                        s += a.at(t, i) * a.at(t, j);
                    }
                    want.data[i * m + j] = s;
                }
            }
            bits_equal(&format!("syrk {k}×{m}"), &want.data, &a.syrk().data)
        },
    );
}

/// The fused oASIS step (`fused_step_update`: diff build and Δ update in
/// one cache-hot pass) is bit-identical to the unfused per-element
/// reference for any chunking — including a forced q entry of exactly
/// 0.0, exercising the skip.
#[test]
fn prop_fused_step_update_bit_equals_two_pass() {
    use oasis::sampling::oasis::fused_step_update;
    check(
        Config { cases: 48, max_size: 32, ..Default::default() },
        |g| {
            let n = g.usize_in(1, 200);
            let k = g.usize_in(0, 6);
            let c = g.normal_vec(k * n);
            let col = g.normal_vec(n);
            let mut q = g.normal_vec(k);
            if k > 0 {
                q[0] = 0.0;
            }
            let s = g.f64_in(-2.0, 2.0);
            let delta0 = g.normal_vec(n);
            let threads = g.usize_in(1, 4);
            let mut want_diff = vec![0.0; n];
            let mut want_delta = delta0.clone();
            for i in 0..n {
                let mut d = -col[i];
                for (t, &qt) in q.iter().enumerate() {
                    if qt == 0.0 {
                        continue;
                    }
                    d += qt * c[t * n + i];
                }
                want_diff[i] = d;
                want_delta[i] -= s * d * d;
            }
            let mut diff = vec![0.0; n];
            let mut delta = delta0;
            fused_step_update(&c, n, &q, &col, s, &mut diff, &mut delta, threads);
            bits_equal(&format!("diff n={n} k={k} t={threads}"), &want_diff, &diff)?;
            bits_equal(&format!("delta n={n} k={k} t={threads}"), &want_delta, &delta)
        },
    );
}

/// Selected Δ values are non-increasing for oASIS on PSD inputs (greedy
/// Schur complements shrink as the span grows).
#[test]
fn prop_deltas_non_increasing() {
    check(
        Config { cases: 12, max_size: 40, ..Default::default() },
        |g| {
            let (m, _) = psd_oracle_case(g);
            let oracle = ExplicitOracle::new(&m);
            let n = m.rows;
            let (_, trace) = Oasis::new(n.min(8), 1, 0.0, 5)
                .sample_traced(&oracle)
                .map_err(|e| e.to_string())?;
            let adaptive: Vec<f64> = trace
                .deltas
                .iter()
                .copied()
                .filter(|d| d.is_finite())
                .collect();
            for w in adaptive.windows(2) {
                // allow tiny numerical wiggle
                if w[1] > w[0] * (1.0 + 1e-6) + 1e-9 {
                    return Err(format!("Δ increased: {} → {}", w[0], w[1]));
                }
            }
            Ok(())
        },
    );
}
