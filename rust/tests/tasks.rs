//! Integration tests for the downstream-tasks layer (`oasis::tasks`):
//! KRR / kernel-PCA / spectral clustering fit on real sampler output,
//! and — the acceptance property — KRR predictions bit-identical across
//! the three ways an approximation reaches a task: a live session
//! snapshot, a finished run, and a loaded artifact (dataset-free).

use oasis::data::generators::{gaussian_clusters, two_moons};
use oasis::data::{loader, Dataset, LoadLimits};
use oasis::engine::{
    DatasetSpec, KernelSpec, LabelsSpec, Method, MethodSpec, RunSpec,
    SessionBuilder, TaskSpec,
};
use oasis::kernels::Gaussian;
use oasis::linalg::Mat;
use oasis::nystrom::{NystromApprox, Provenance, StoredArtifact};
use oasis::sampling::oasis::Oasis;
use oasis::sampling::{
    run_to_completion, ImplicitOracle, SamplerSession, StoppingRule,
};
use oasis::seed::permutation_accuracy;
use oasis::tasks::{FittedTask, TaskConfig, TaskKind, TaskPrediction};

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("oasis-tasks-test")
        .join(format!("{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn values(p: &TaskPrediction) -> &[f64] {
    match p {
        TaskPrediction::Values(v) => v,
        other => panic!("expected krr values, got {other:?}"),
    }
}

/// ACCEPTANCE: the same KRR task, fit from (a) a live session snapshot,
/// (b) the finished run's approximation, and (c) an artifact saved to
/// disk and loaded back — bit-identical dual weights and predictions.
/// The artifact path runs dataset-free: it sees only the stored factors,
/// selected points, and kernel parameters.
#[test]
fn krr_bit_identical_across_live_finished_and_artifact_paths() {
    let n = 160;
    let ds = two_moons(n, 0.05, 31);
    let kern = Gaussian::new(0.7);
    let labels: Vec<f64> = (0..n).map(|i| (i % 2) as f64).collect();
    let queries = vec![vec![0.4, 0.1], vec![-0.8, 0.6], vec![1.5, -0.2]];

    let oracle = ImplicitOracle::new(&ds, &kern);
    let mut session = Oasis::new(40, 5, 1e-12, 9).session(&oracle).unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(40)).unwrap();

    // (a) live snapshot — the session keeps running afterwards
    let live_snap = session.snapshot().unwrap();
    // (b) the finished approximation
    let finished = Box::new(session).finish().unwrap();

    let mut cfg = TaskConfig::new(TaskKind::Krr);
    cfg.labels = Some(vec![labels]);
    cfg.ridge = 1e-3;

    let fit_and_predict = |approx: &NystromApprox,
                           selected: &Dataset,
                           kernel: &dyn oasis::kernels::Kernel|
     -> (Vec<f64>, Vec<f64>) {
        let fit = FittedTask::fit(approx, &cfg).unwrap();
        let beta = match &fit.model {
            FittedTask::Krr(m) => m.beta.clone(),
            other => panic!("unexpected model {other:?}"),
        };
        let preds =
            values(&fit.model.predict(kernel, selected, &queries).unwrap())
                .to_vec();
        (beta, preds)
    };

    let selected = ds.select(&live_snap.indices);
    let (beta_live, preds_live) = fit_and_predict(&live_snap, &selected, &kern);
    let (beta_fin, preds_fin) = fit_and_predict(&finished, &selected, &kern);

    // (c) the artifact path: save, reload, fit dataset-free
    let dir = tmp_dir("krr-parity");
    let path = dir.join("model.oasis");
    StoredArtifact::from_parts(
        finished,
        &ds,
        &kern,
        Provenance { source: "test:two-moons".into(), method: "oasis".into() },
        None,
    )
    .unwrap()
    .save(&path)
    .unwrap();
    let artifact = StoredArtifact::load(&path).unwrap();
    let art_kernel = artifact.kernel.build();
    let (beta_art, preds_art) = fit_and_predict(
        &artifact.approx,
        &artifact.selected_points,
        &*art_kernel,
    );

    for (label, (betas, preds)) in [
        ("finished", (&beta_fin, &preds_fin)),
        ("artifact", (&beta_art, &preds_art)),
    ] {
        assert_eq!(beta_live.len(), betas.len(), "{label}");
        for (a, b) in beta_live.iter().zip(betas.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{label} beta diverged");
        }
        for (a, b) in preds_live.iter().zip(preds.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{label} prediction diverged");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The kernel-PCA embedding of a real oASIS run has orthonormal
/// columns, and the out-of-sample projection agrees with the in-sample
/// embedding at the training points.
#[test]
fn kpca_embedding_orthogonal_on_sampler_output() {
    let n = 140;
    let ds = two_moons(n, 0.05, 13);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let mut session = Oasis::new(36, 5, 1e-12, 3).session(&oracle).unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(36)).unwrap();
    let approx = session.snapshot().unwrap();

    let fit = FittedTask::fit(&approx, &TaskConfig::new(TaskKind::Kpca)).unwrap();
    let model = match &fit.model {
        FittedTask::Kpca(m) => m,
        other => panic!("unexpected model {other:?}"),
    };
    assert_eq!(model.dims(), 2);
    // refit to get the in-sample embedding and check orthonormality
    let (_, u) = oasis::tasks::KpcaModel::fit(&approx, 2).unwrap();
    let utu = u.t_matmul(&u);
    assert!(
        utu.fro_dist(&Mat::eye(2)) < 1e-8,
        "UᵀU deviates from I by {}",
        utu.fro_dist(&Mat::eye(2))
    );
    // out-of-sample projection reproduces in-sample rows
    let selected = ds.select(&approx.indices);
    let points: Vec<Vec<f64>> =
        [2usize, 77, 139].iter().map(|&i| ds.point(i).to_vec()).collect();
    let pred = fit.model.predict(&kern, &selected, &points).unwrap();
    let rows = match &pred {
        TaskPrediction::Embeddings(rows) => rows,
        other => panic!("unexpected prediction {other:?}"),
    };
    for (r, &i) in rows.iter().zip(&[2usize, 77, 139]) {
        for (j, &got) in r.iter().enumerate() {
            assert!(
                (got - u.at(i, j)).abs() < 1e-6,
                "point {i} dim {j}: {got} vs {}",
                u.at(i, j)
            );
        }
    }
}

/// Cluster labels are stable under a fixed seed (bit-identical refits)
/// and recover well-separated clusters through a sampler-built
/// approximation.
#[test]
fn cluster_labels_stable_and_accurate_under_fixed_seed() {
    let n = 150;
    let ds = gaussian_clusters(n, 3, 3, 0.07, 8);
    let truth: Vec<usize> = (0..n).map(|i| i % 3).collect();
    let kern = Gaussian::new(1.5);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let mut session = Oasis::new(30, 5, 1e-12, 17).session(&oracle).unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(30)).unwrap();
    let approx = session.snapshot().unwrap();

    let mut cfg = TaskConfig::new(TaskKind::Cluster);
    cfg.clusters = 3;
    cfg.components = 3;
    cfg.seed = 42;
    let a = FittedTask::fit(&approx, &cfg).unwrap();
    let b = FittedTask::fit(&approx, &cfg).unwrap();
    let (la, lb) = (a.cluster_labels.unwrap(), b.cluster_labels.unwrap());
    assert_eq!(la, lb, "labels changed across refits with the same seed");
    let acc = permutation_accuracy(&la, &truth, 3);
    assert!(acc > 0.9, "clustering accuracy {acc}");
}

/// The engine resolves a task spec end to end: labels load from a CSV
/// column, and a run spec plus task spec produce a fitted model — the
/// CLI's `oasis task` path at the library level.
#[test]
fn engine_resolves_task_with_file_labels() {
    let n = 80;
    let dir = tmp_dir("engine-task");
    let ds = two_moons(n, 0.05, 3);
    // labels file with two columns; take column 1
    let rows: Vec<Vec<f64>> =
        (0..n).map(|i| vec![99.0, (i % 2) as f64]).collect();
    let labels_path = dir.join("labels.csv");
    loader::save_csv(&labels_path, &Dataset::from_rows(rows)).unwrap();

    let run = SessionBuilder::new()
        .resolve(RunSpec {
            dataset: DatasetSpec::Points(
                (0..n).map(|i| ds.point(i).to_vec()).collect(),
            ),
            kernel: KernelSpec::Gaussian { sigma: Some(0.7), sigma_fraction: 0.05 },
            method: MethodSpec {
                method: Method::Oasis,
                max_cols: 24,
                init_cols: 5,
                tol: 1e-12,
                seed: 7,
                batch: 10,
                workers: 1,
                merge_batch: 1,
                listen: None,
            },
            stopping: StoppingRule::budget(24),
            shard_reads: false,
            warm_start: None,
        })
        .unwrap();
    let slot = run.oracle_slot();
    let mut s = run.open_session(&slot).unwrap();
    run_to_completion(s.as_mut(), &run.stopping).unwrap();
    let approx = s.snapshot().unwrap();

    let mut spec = TaskSpec::new(TaskKind::Krr);
    spec.ridge = 1e-2;
    spec.labels = Some(LabelsSpec {
        label: "labels.csv".into(),
        path: labels_path.clone(),
        cols: vec![1],
    });
    let cfg = SessionBuilder::new().resolve_task(&spec).unwrap();
    let cols = cfg.labels.as_ref().unwrap();
    assert_eq!(cols.len(), 1, "one requested column → one label column");
    assert_eq!(cols[0].len(), n);
    assert_eq!(cols[0][1], 1.0);
    let fit = FittedTask::fit(&approx, &cfg).unwrap();
    match &fit.model {
        FittedTask::Krr(m) => assert!(m.train_rmse.is_finite()),
        other => panic!("unexpected model {other:?}"),
    }

    // an out-of-range label column is a clean error
    let mut bad = spec.clone();
    bad.labels.as_mut().unwrap().cols = vec![7];
    let err = SessionBuilder::new().resolve_task(&bad).unwrap_err();
    assert!(format!("{err}").contains("column"), "{err}");
    // a missing labels file names the label
    let mut missing = spec.clone();
    missing.labels.as_mut().unwrap().path = dir.join("absent.csv");
    assert!(SessionBuilder::new().resolve_task(&missing).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// The full pipeline the example drives: sample → save with a fitted
/// task attached → reload → predict without labels, bit-identically;
/// the f32 save keeps working for tasks, at reduced precision.
#[test]
fn saved_task_model_predicts_without_labels() {
    let n = 100;
    let ds = two_moons(n, 0.05, 19);
    let kern = Gaussian::new(0.8);
    let labels: Vec<f64> = (0..n).map(|i| (i % 2) as f64).collect();
    let queries = vec![vec![0.3, 0.2], vec![-0.4, 0.9]];

    let oracle = ImplicitOracle::new(&ds, &kern);
    let mut session = Oasis::new(30, 4, 1e-12, 5).session(&oracle).unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(30)).unwrap();
    let approx = session.snapshot().unwrap();

    let mut cfg = TaskConfig::new(TaskKind::Krr);
    cfg.labels = Some(vec![labels]);
    let fit = FittedTask::fit(&approx, &cfg).unwrap();
    let selected = ds.select(&approx.indices);
    let want = values(&fit.model.predict(&kern, &selected, &queries).unwrap())
        .to_vec();

    let dir = tmp_dir("saved-task");
    let path = dir.join("with-task.oasis");
    StoredArtifact::from_parts(
        approx,
        &ds,
        &kern,
        Provenance { source: "test".into(), method: "oasis".into() },
        None,
    )
    .unwrap()
    .with_task(fit.model)
    .unwrap()
    .save(&path)
    .unwrap();

    // reload: the stored model predicts with no labels in sight
    let back = StoredArtifact::load(&path).unwrap();
    let model = back.task.as_ref().expect("stored task model");
    let kernel = back.kernel.build();
    let got = values(
        &model.predict(&*kernel, &back.selected_points, &queries).unwrap(),
    )
    .to_vec();
    for (a, b) in want.iter().zip(&got) {
        assert_eq!(a.to_bits(), b.to_bits(), "stored-task prediction diverged");
    }

    // f32 compaction: same pipeline, predictions within f32 slack
    let f32_path = dir.join("compact.oasis");
    let compact = back.clone().with_f32(true);
    compact.save(&f32_path).unwrap();
    let cback = StoredArtifact::load(&f32_path).unwrap();
    assert!(cback.f32_payload);
    let cmodel = cback.task.as_ref().expect("task survived f32 save");
    let ckernel = cback.kernel.build();
    let cgot = values(
        &cmodel.predict(&*ckernel, &cback.selected_points, &queries).unwrap(),
    )
    .to_vec();
    for (a, b) in want.iter().zip(&cgot) {
        // the stored β is f64 (task sections stay f64), so the stored
        // model's predictions are bit-identical even in an f32 artifact
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // but a *refit* from the f32 factors only agrees approximately
    let mut cfg2 = TaskConfig::new(TaskKind::Krr);
    cfg2.labels = Some(vec![(0..n).map(|i| (i % 2) as f64).collect()]);
    let refit = FittedTask::fit(&cback.approx, &cfg2).unwrap();
    let rgot = values(
        &refit.model.predict(&*ckernel, &cback.selected_points, &queries).unwrap(),
    )
    .to_vec();
    for (a, b) in want.iter().zip(&rgot) {
        assert!(
            (a - b).abs() < 1e-2 * (1.0 + a.abs()),
            "f32 refit too far off: {a} vs {b}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// ACCEPTANCE (batched serving): a B-point predict call — served as one
/// B×k kernel block plus one blocked product — is bit-identical to B
/// single-point calls, for single-output KRR and every other task; the
/// multi-output path agrees with m independent single-output fits.
#[test]
fn batched_predict_bit_identical_to_single_point_loop() {
    let n = 150;
    let ds = two_moons(n, 0.05, 23);
    let kern = Gaussian::new(0.7);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let mut session = Oasis::new(36, 5, 1e-12, 11).session(&oracle).unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(36)).unwrap();
    let approx = session.snapshot().unwrap();
    let selected = ds.select(&approx.indices);

    let queries: Vec<Vec<f64>> = (0..32)
        .map(|i| vec![(i as f64) * 0.11 - 1.5, ((i * 7) % 13) as f64 * 0.2 - 1.0])
        .collect();

    let y0: Vec<f64> = (0..n).map(|i| (i % 2) as f64).collect();
    let y1: Vec<f64> = (0..n).map(|i| (i as f64 / n as f64).sin()).collect();

    // single-output KRR: batch == loop, bit for bit
    let mut cfg = TaskConfig::new(TaskKind::Krr);
    cfg.ridge = 1e-3;
    cfg.labels = Some(vec![y0.clone()]);
    let fit = FittedTask::fit(&approx, &cfg).unwrap();
    let batched =
        values(&fit.model.predict(&kern, &selected, &queries).unwrap()).to_vec();
    for (i, q) in queries.iter().enumerate() {
        let one = values(
            &fit.model.predict(&kern, &selected, &[q.clone()]).unwrap(),
        )[0];
        assert_eq!(
            batched[i].to_bits(),
            one.to_bits(),
            "batched prediction {i} diverged from the single-point call"
        );
    }

    // multi-output: one shared factorization per-column identical to m
    // separate fits, and the batched Matrix rows line up per output
    let mut multi = cfg.clone();
    multi.labels = Some(vec![y0.clone(), y1.clone()]);
    let mfit = FittedTask::fit(&approx, &multi).unwrap();
    let rows = match mfit.model.predict(&kern, &selected, &queries).unwrap() {
        TaskPrediction::Matrix(rows) => rows,
        other => panic!("expected a B×m prediction matrix, got {other:?}"),
    };
    assert_eq!(rows.len(), queries.len());
    assert!(rows.iter().all(|r| r.len() == 2));
    let mut cfg1 = cfg.clone();
    cfg1.labels = Some(vec![y1.clone()]);
    let fit1 = FittedTask::fit(&approx, &cfg1).unwrap();
    let solo1 =
        values(&fit1.model.predict(&kern, &selected, &queries).unwrap()).to_vec();
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row[0].to_bits(), batched[i].to_bits(), "output 0 diverged");
        assert_eq!(row[1].to_bits(), solo1[i].to_bits(), "output 1 diverged");
    }

    // kpca and cluster predictions batch identically too
    let kfit = FittedTask::fit(&approx, &TaskConfig::new(TaskKind::Kpca)).unwrap();
    let kb = match kfit.model.predict(&kern, &selected, &queries).unwrap() {
        TaskPrediction::Embeddings(rows) => rows,
        other => panic!("unexpected {other:?}"),
    };
    for (i, q) in queries.iter().enumerate() {
        let one = match kfit
            .model
            .predict(&kern, &selected, &[q.clone()])
            .unwrap()
        {
            TaskPrediction::Embeddings(rows) => rows,
            other => panic!("unexpected {other:?}"),
        };
        for (a, b) in kb[i].iter().zip(&one[0]) {
            assert_eq!(a.to_bits(), b.to_bits(), "kpca row {i} diverged");
        }
    }
}

/// The f32 serving path stays within f32 slack of the f64 answers on
/// every output, batched and single-point alike — and the two f32 call
/// shapes agree with each other exactly.
#[test]
fn f32_predict_tracks_f64_within_tolerance() {
    let n = 120;
    let ds = two_moons(n, 0.05, 29);
    let kern = Gaussian::new(0.8);
    let oracle = ImplicitOracle::new(&ds, &kern);
    let mut session = Oasis::new(30, 4, 1e-12, 3).session(&oracle).unwrap();
    run_to_completion(&mut session, &StoppingRule::budget(30)).unwrap();
    let approx = session.snapshot().unwrap();
    let selected = ds.select(&approx.indices);

    let mut cfg = TaskConfig::new(TaskKind::Krr);
    cfg.ridge = 1e-3;
    cfg.labels = Some(vec![
        (0..n).map(|i| (i % 2) as f64).collect(),
        (0..n).map(|i| (i as f64 * 0.01).cos()).collect(),
    ]);
    let fit = FittedTask::fit(&approx, &cfg).unwrap();

    let queries: Vec<Vec<f64>> =
        (0..24).map(|i| vec![i as f64 * 0.13 - 1.4, (i % 5) as f64 * 0.3 - 0.6]).collect();
    let rows64 = match fit.model.predict(&kern, &selected, &queries).unwrap() {
        TaskPrediction::Matrix(rows) => rows,
        other => panic!("unexpected {other:?}"),
    };
    let rows32 = match fit.model.predict_f32(&kern, &selected, &queries).unwrap() {
        TaskPrediction::Matrix(rows) => rows,
        other => panic!("unexpected {other:?}"),
    };
    assert_eq!(rows32.len(), rows64.len());
    for (i, (r64, r32)) in rows64.iter().zip(&rows32).enumerate() {
        for (j, (a, b)) in r64.iter().zip(r32).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + a.abs()),
                "f32 drifted at point {i} output {j}: {a} vs {b}"
            );
        }
    }
    // batched f32 == looped f32 (same accumulation order per element)
    for (i, q) in queries.iter().enumerate() {
        let one = match fit
            .model
            .predict_f32(&kern, &selected, &[q.clone()])
            .unwrap()
        {
            TaskPrediction::Matrix(rows) => rows,
            other => panic!("unexpected {other:?}"),
        };
        for (a, b) in rows32[i].iter().zip(&one[0]) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 batch/loop split at {i}");
        }
    }
}

/// `LoadLimits` bound label files like any dataset.
#[test]
fn label_loading_respects_limits() {
    let dir = tmp_dir("label-limits");
    let labels_path = dir.join("y.csv");
    loader::save_csv(
        &labels_path,
        &Dataset::from_rows((0..50).map(|i| vec![i as f64]).collect()),
    )
    .unwrap();
    let mut spec = TaskSpec::new(TaskKind::Krr);
    spec.labels = Some(LabelsSpec {
        label: "y.csv".into(),
        path: labels_path,
        cols: vec![0],
    });
    let tight = LoadLimits { max_n: 10, max_dim: 4, max_elems: u128::MAX };
    assert!(SessionBuilder::with_limits(tight).resolve_task(&spec).is_err());
    assert!(SessionBuilder::new().resolve_task(&spec).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}
