//! Shared machinery for the figure benches: evaluate error-vs-columns and
//! error-vs-time curves from a sequential sampler's trace by rebuilding
//! the approximation at prefix index sets.

use crate::nystrom::{relative_frobenius_error, sampled_relative_error};
use crate::sampling::{assemble_from_indices, ColumnOracle, SelectionTrace};

/// How the error is measured for a curve point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorMode {
    /// exact ‖G−G̃‖_F/‖G‖_F (explicit class)
    Full,
    /// sampled-entry estimator with this many samples (implicit class)
    Sampled(usize),
}

/// One point of a convergence curve.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub k: usize,
    pub error: f64,
    /// cumulative selection seconds when the k-th column was chosen
    pub secs: f64,
}

/// Evaluate `error(k)` at each k in `ks` from a selection trace, by
/// assembling the Nyström approximation over the first k selected indices.
/// (Valid for the sequential methods — oASIS/SIS/Farahat/random/leverage —
/// whose prefix is exactly the state after k selections; not for K-means,
/// which must be rerun per k, as the paper notes in §V-E.)
pub fn error_curve(
    oracle: &dyn ColumnOracle,
    trace: &SelectionTrace,
    ks: &[usize],
    mode: ErrorMode,
    seed: u64,
) -> Vec<CurvePoint> {
    let mut out = Vec::with_capacity(ks.len());
    for &k in ks {
        let k = k.min(trace.order.len());
        if k == 0 {
            continue;
        }
        let prefix: Vec<usize> = trace.order[..k].to_vec();
        let approx = assemble_from_indices(oracle, prefix, 0.0);
        let error = match mode {
            ErrorMode::Full => relative_frobenius_error(oracle, &approx),
            ErrorMode::Sampled(s) => {
                sampled_relative_error(oracle, &approx, s, seed)
            }
        };
        out.push(CurvePoint { k, error, secs: trace.cum_secs[k - 1] });
    }
    out
}

/// A log-spaced grid of column counts in [k_min, k_max].
pub fn k_grid(k_min: usize, k_max: usize, points: usize) -> Vec<usize> {
    assert!(k_min >= 1 && k_max >= k_min && points >= 1);
    let mut ks: Vec<usize> = (0..points)
        .map(|i| {
            let t = i as f64 / (points - 1).max(1) as f64;
            let v = (k_min as f64).ln() + t * ((k_max as f64).ln() - (k_min as f64).ln());
            v.exp().round() as usize
        })
        .collect();
    ks.dedup();
    ks
}

/// Render a curve as aligned rows for the bench output.
pub fn print_curve(method: &str, curve: &[CurvePoint]) {
    for p in curve {
        println!(
            "{:18} k={:5}  error={:10.3e}  t={:8.3}s",
            method, p.k, p.error, p.secs
        );
    }
}

/// Benchmark scale factor from `$OASIS_BENCH_SCALE`: scales dataset sizes.
/// `OASIS_BENCH_SCALE=1` regenerates the paper-size tables (Table I takes
/// ~20 min, most of it in the baselines' O(n²·ℓ)/O(n³) work — oASIS itself
/// is seconds); the default 0.25 keeps the full `cargo bench` sweep to
/// minutes while preserving every qualitative shape.
pub fn bench_scale() -> f64 {
    std::env::var("OASIS_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25)
}

/// Scale a size, keeping a floor.
pub fn scaled(n: usize, floor: usize) -> usize {
    ((n as f64 * bench_scale()) as usize).max(floor)
}

/// BORG dataset scaled coherently with the column budget ℓ: the paper uses
/// an 8-cube (256 clusters) with ℓ=450 ≈ 1.8× the cluster count. At
/// reduced scale a fixed 8-cube would leave ℓ < #clusters and *every*
/// method floors at ~1 error, destroying the figure's shape — so the cube
/// dimension shrinks to keep ℓ ≳ 1.75 × 2^dim, and points-per-vertex keeps
/// n near `scaled(7680)`.
pub fn borg_scaled(l: usize, seed: u64) -> crate::data::Dataset {
    let dim = ((l as f64 / 1.75).log2().floor() as usize).clamp(4, 8);
    let n_target = scaled(7_680, 192);
    let per_vertex = (n_target >> dim).max(2);
    crate::data::generators::borg(dim, per_vertex, 0.1, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::sampling::{oasis::Oasis, ColumnSampler, ImplicitOracle};

    #[test]
    fn grid_is_monotone_and_bounded() {
        let ks = k_grid(5, 450, 12);
        assert_eq!(*ks.first().unwrap(), 5);
        assert_eq!(*ks.last().unwrap(), 450);
        for w in ks.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn curve_is_consistent_with_direct_run() {
        let ds = two_moons(120, 0.05, 3);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.15);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let sampler = Oasis::new(30, 5, 1e-14, 9);
        let (_, trace) = sampler.sample_traced(&oracle).unwrap();
        let curve = error_curve(&oracle, &trace, &[10, 20, 30], ErrorMode::Full, 1);
        assert_eq!(curve.len(), 3);
        // error decreasing along the curve
        assert!(curve[0].error >= curve[1].error - 1e-9);
        assert!(curve[1].error >= curve[2].error - 1e-9);
        // last point matches a direct run at ℓ=30
        let direct = Oasis::new(30, 5, 1e-14, 9)
            .sample(&oracle)
            .unwrap();
        let e =
            crate::nystrom::relative_frobenius_error(&oracle, &direct);
        assert!((curve[2].error - e).abs() < 1e-9);
    }
}
