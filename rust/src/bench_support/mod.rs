//! Micro-bench harness (criterion substitute): warmup + timed repetitions
//! with median/min/max reporting, and helpers shared by the table/figure
//! benches under `rust/benches/`.

pub mod curves;

use crate::util::timing::{fmt_secs, Stopwatch, Summary};

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 1, reps: 5 }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let skipped = if self.summary.skipped > 0 {
            format!(", {} non-finite skipped", self.summary.skipped)
        } else {
            String::new()
        };
        format!(
            "{:40} median {:>10}  min {:>10}  max {:>10}  (n={}{})",
            self.name,
            fmt_secs(self.summary.median),
            fmt_secs(self.summary.min),
            fmt_secs(self.summary.max),
            self.summary.n,
            skipped,
        )
    }
}

/// Run a closure `cfg.reps` times (after warmup) and summarize wall time.
/// The closure's return value is passed through a black box to prevent
/// dead-code elimination.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(cfg.reps);
    for _ in 0..cfg.reps {
        let sw = Stopwatch::start();
        black_box(f());
        times.push(sw.secs());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&times) }
}

/// Opaque value sink (std::hint::black_box passthrough).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarizes() {
        let mut count = 0usize;
        let cfg = BenchConfig { warmup: 2, reps: 3 };
        let r = bench("noop", &cfg, || {
            count += 1;
            count
        });
        assert_eq!(count, 5);
        assert_eq!(r.summary.n, 3);
        assert!(r.summary.min <= r.summary.median);
        assert!(r.report().contains("noop"));
    }
}
