//! Diffusion-distance normalization (Coifman–Lafon diffusion maps).
//!
//! The paper's Table I "second line" experiments approximate
//! `M = D^{-1/2} N D^{-1/2}` where N is a Gaussian kernel matrix and D is
//! the diagonal of N's row sums. M is symmetric PSD-like (its spectrum lies
//! in [-1, 1] with λmax = 1) and its eigenvectors give the diffusion-map
//! embedding (examples/diffusion_maps.rs).

use crate::linalg::Mat;

/// Normalize a (symmetric, non-negative) kernel matrix in place:
/// `M(i,j) = N(i,j) / sqrt(rowsum_i * rowsum_j)`. Returns the row sums.
pub fn diffusion_normalize(n_mat: &mut Mat) -> Vec<f64> {
    assert_eq!(n_mat.rows, n_mat.cols);
    let n = n_mat.rows;
    let mut rowsum = vec![0.0; n];
    for i in 0..n {
        rowsum[i] = n_mat.row(i).iter().sum();
        assert!(
            rowsum[i] > 0.0,
            "diffusion_normalize: zero row sum at {i} (disconnected point)"
        );
    }
    let inv_sqrt: Vec<f64> = rowsum.iter().map(|&s| 1.0 / s.sqrt()).collect();
    for i in 0..n {
        let si = inv_sqrt[i];
        let row = n_mat.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v *= si * inv_sqrt[j];
        }
    }
    rowsum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::{functions::Gaussian, kernel_matrix};

    #[test]
    fn normalized_matrix_symmetric_and_bounded() {
        let ds = two_moons(50, 0.05, 7);
        let mut m = kernel_matrix(&ds, &Gaussian::new(0.8));
        diffusion_normalize(&mut m);
        for i in 0..50 {
            for j in 0..50 {
                assert!((m.at(i, j) - m.at(j, i)).abs() < 1e-12);
                assert!(m.at(i, j) >= 0.0 && m.at(i, j) <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn top_eigenvalue_is_one() {
        let ds = two_moons(40, 0.05, 8);
        let mut m = kernel_matrix(&ds, &Gaussian::new(1.0));
        diffusion_normalize(&mut m);
        let eig = crate::linalg::sym_eig(&m);
        assert!((eig.vals[0] - 1.0).abs() < 1e-8, "λmax = {}", eig.vals[0]);
        assert!(eig.vals.iter().all(|&l| l > -1.0 - 1e-8));
    }

    #[test]
    fn d_half_vector_is_top_eigenvector() {
        // M (D^{1/2} 1) = D^{-1/2} N 1 = D^{-1/2} d = D^{1/2} 1
        let ds = two_moons(30, 0.05, 9);
        let mut m = kernel_matrix(&ds, &Gaussian::new(1.2));
        let rowsum = diffusion_normalize(&mut m);
        let v: Vec<f64> = rowsum.iter().map(|&s| s.sqrt()).collect();
        let mv = m.matvec(&v);
        for (a, b) in mv.iter().zip(&v) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
