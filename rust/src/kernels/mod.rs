//! Kernel functions and kernel-matrix/column builders.
//!
//! The paper's experiments use Gaussian kernel matrices
//! `G(i,j) = exp(-‖zᵢ-zⱼ‖²/σ²)`, linear Gram matrices `G = ZᵀZ` (theory,
//! Fig. 5), and diffusion-normalized matrices `M = D^{-1/2} N D^{-1/2}`
//! (Table I second rows). All three are implemented here, plus Laplacian
//! and polynomial kernels for completeness.

pub mod builder;
pub mod diffusion;
pub mod functions;

pub use builder::{
    kernel_column_into, kernel_cross_columns_into, kernel_diag, kernel_matrix,
};
pub use diffusion::diffusion_normalize;
pub use functions::{
    Gaussian, Kernel, KernelParams, Laplacian, Linear, Polynomial,
};
