//! Kernel matrix / column construction (threaded).

use super::functions::Kernel;
use crate::data::Dataset;
use crate::linalg::Mat;
use crate::util::parallel;

/// Full n×n kernel matrix (only for datasets small enough to hold it —
/// the Table I / Fig. 6–7 "explicit" experiment class).
pub fn kernel_matrix(ds: &Dataset, k: &dyn Kernel) -> Mat {
    let n = ds.n();
    let mut g = Mat::zeros(n, n);
    let threads = parallel::default_threads();
    parallel::for_each_chunk_mut(&mut g.data, n, threads, |range, chunk| {
        for (local, i) in range.clone().enumerate() {
            let row = &mut chunk[local * n..(local + 1) * n];
            let zi = ds.point(i);
            for (j, out) in row.iter_mut().enumerate() {
                *out = k.eval(zi, ds.point(j));
            }
        }
    });
    // enforce exact symmetry (eval order can differ in the last ulp)
    for i in 0..n {
        for j in i + 1..n {
            let v = g.data[i * n + j];
            g.data[j * n + i] = v;
        }
    }
    g
}

/// Column j of the kernel matrix, written into `out` (length n).
/// Each chunk is one [`Kernel::eval_rows`] call over the contiguous
/// point-major storage — one virtual dispatch per chunk, statically
/// inlined kernel math inside — instead of a per-entry `eval` loop.
pub fn kernel_column_into(ds: &Dataset, k: &dyn Kernel, j: usize, out: &mut [f64]) {
    let n = ds.n();
    assert_eq!(out.len(), n);
    let zj = ds.point(j);
    let dim = ds.dim();
    let flat = ds.flat();
    let threads = if n >= 4096 { parallel::default_threads() } else { 1 };
    parallel::for_each_chunk_mut(out, 1, threads, |range, chunk| {
        k.eval_rows(&flat[range.start * dim..range.end * dim], dim, zj, chunk);
    });
}

/// The diagonal of the kernel matrix.
pub fn kernel_diag(ds: &Dataset, k: &dyn Kernel) -> Vec<f64> {
    (0..ds.n()).map(|i| k.diag_value(ds.point(i))).collect()
}

/// Batched cross-kernel fill: evaluate every dataset point against every
/// point in `points`, writing column-major — the column for `points[t]`
/// occupies `out[t*n .. (t+1)*n]`. This is the oASIS-P worker's "column
/// pull": its shard's slice of the sampled columns C, computed against
/// selected points that may live on other nodes, in one batched pass
/// instead of one eval loop per point. `threads = 1` keeps the fill on
/// the calling thread (workers are already one thread of p).
pub fn kernel_cross_columns_into<P: AsRef<[f64]> + Sync>(
    ds: &Dataset,
    k: &dyn Kernel,
    points: &[P],
    threads: usize,
    out: &mut [f64],
) {
    let n = ds.n();
    let m = points.len();
    assert_eq!(out.len(), m * n, "cross-column buffer must be |points|·n");
    let dim = ds.dim();
    let flat = ds.flat();
    parallel::for_each_chunk_mut(out, n, threads, |range, chunk| {
        for (local, t) in range.clone().enumerate() {
            let zt = points[t].as_ref();
            // one eval_rows sweep per column: a single virtual dispatch
            // with the shard's rows read contiguously
            k.eval_rows(flat, dim, zt, &mut chunk[local * n..(local + 1) * n]);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::functions::{Gaussian, Linear};

    #[test]
    fn matrix_is_symmetric_with_unit_diag() {
        let ds = two_moons(60, 0.05, 1);
        let g = kernel_matrix(&ds, &Gaussian::new(1.0));
        for i in 0..60 {
            assert_eq!(g.at(i, i), 1.0);
            for j in 0..60 {
                assert_eq!(g.at(i, j), g.at(j, i));
            }
        }
    }

    #[test]
    fn column_matches_matrix() {
        let ds = two_moons(40, 0.05, 2);
        let k = Gaussian::new(0.7);
        let g = kernel_matrix(&ds, &k);
        let mut col = vec![0.0; 40];
        for j in [0usize, 17, 39] {
            kernel_column_into(&ds, &k, j, &mut col);
            for i in 0..40 {
                assert_eq!(col[i], g.at(i, j));
            }
        }
    }

    #[test]
    fn diag_matches_matrix() {
        let ds = two_moons(25, 0.05, 3);
        let k = Linear;
        let g = kernel_matrix(&ds, &k);
        let d = kernel_diag(&ds, &k);
        for i in 0..25 {
            assert!((d[i] - g.at(i, i)).abs() < 1e-14);
        }
    }

    #[test]
    fn cross_columns_match_matrix() {
        let ds = two_moons(35, 0.05, 6);
        let k = Gaussian::new(0.8);
        let g = kernel_matrix(&ds, &k);
        let sel = [4usize, 0, 30];
        let pts: Vec<Vec<f64>> = sel.iter().map(|&j| ds.point(j).to_vec()).collect();
        for threads in [1usize, 4] {
            let mut out = vec![0.0; pts.len() * 35];
            kernel_cross_columns_into(&ds, &k, &pts, threads, &mut out);
            for (t, &j) in sel.iter().enumerate() {
                for i in 0..35 {
                    assert_eq!(out[t * 35 + i], g.at(i, j), "({i}, {j})");
                }
            }
        }
    }

    #[test]
    fn gram_matrix_is_psd() {
        let ds = two_moons(30, 0.05, 4);
        let g = kernel_matrix(&ds, &Linear);
        let eig = crate::linalg::sym_eig(&g);
        assert!(eig.vals.iter().all(|&l| l > -1e-9));
    }
}
