//! The kernel functions themselves.

use crate::data::Dataset;
use crate::util::rng::Pcg64;

/// A positive-semidefinite kernel function over data points.
pub trait Kernel: Sync {
    /// k(a, b).
    fn eval(&self, a: &[f64], b: &[f64]) -> f64;

    /// k(a, a) — overridden where it is constant.
    fn diag_value(&self, a: &[f64]) -> f64 {
        self.eval(a, a)
    }

    /// Batched evaluation `out[i] = k(rows_i, z)` over the contiguous
    /// point-major slice `rows` (length `out.len() * dim`) — the form
    /// the hot column fills use. The caller pays one virtual dispatch
    /// per row block instead of one per entry, and because default trait
    /// bodies are compiled per implementing type, `eval` inlines
    /// statically into the loop. Overrides must evaluate exactly
    /// `eval(rows_i, z)` in index order: batched and per-entry column
    /// paths are required (and tested) to agree bit for bit.
    fn eval_rows(&self, rows: &[f64], dim: usize, z: &[f64], out: &mut [f64]) {
        if out.is_empty() {
            return;
        }
        if dim == 0 {
            for o in out.iter_mut() {
                *o = self.eval(&[], z);
            }
            return;
        }
        debug_assert_eq!(rows.len(), out.len() * dim);
        for (o, p) in out.iter_mut().zip(rows.chunks_exact(dim)) {
            *o = self.eval(p, z);
        }
    }

    /// Human-readable name for logs/tables.
    fn name(&self) -> &'static str;

    /// The kernel's *resolved* parameters, if it can be reconstructed
    /// from plain numbers — what the artifact store persists so a saved
    /// approximation can answer out-of-sample queries without the
    /// original kernel object. `None` (the default) marks kernels that
    /// are not storable (e.g. data-dependent or ad-hoc test kernels).
    fn params(&self) -> Option<KernelParams> {
        None
    }
}

/// Resolved, serializable kernel parameters. Unlike the serving layer's
/// request-side kernel spec (which may say "σ = 5% of the max pairwise
/// distance"), these are the concrete numbers a built kernel evaluates
/// with, so [`build`](KernelParams::build) reproduces it bit-exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelParams {
    /// `exp(-‖a-b‖² · inv_sigma_sq)` — stored pre-inverted, exactly as
    /// [`Gaussian`] holds it.
    Gaussian { inv_sigma_sq: f64 },
    Linear,
    /// `exp(-‖a-b‖₁ · inv_sigma)`.
    Laplacian { inv_sigma: f64 },
    Polynomial { degree: u32, offset: f64 },
}

impl KernelParams {
    /// Canonical type name (shared with the CLI/server kernel spellings).
    pub fn name(&self) -> &'static str {
        match self {
            KernelParams::Gaussian { .. } => "gaussian",
            KernelParams::Linear => "linear",
            KernelParams::Laplacian { .. } => "laplacian",
            KernelParams::Polynomial { .. } => "polynomial",
        }
    }

    /// Rebuild the kernel these parameters came from.
    pub fn build(&self) -> Box<dyn Kernel + Send + Sync> {
        match *self {
            KernelParams::Gaussian { inv_sigma_sq } => {
                Box::new(Gaussian { inv_sigma_sq })
            }
            KernelParams::Linear => Box::new(Linear),
            KernelParams::Laplacian { inv_sigma } => {
                Box::new(Laplacian { inv_sigma })
            }
            KernelParams::Polynomial { degree, offset } => {
                Box::new(Polynomial { degree, offset })
            }
        }
    }
}

#[inline]
pub(crate) fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Gaussian (RBF) kernel `exp(-‖a-b‖²/σ²)` — the paper's main kernel.
/// Note the paper's convention divides by σ² (not 2σ²).
#[derive(Debug, Clone)]
pub struct Gaussian {
    pub inv_sigma_sq: f64,
}

impl Gaussian {
    pub fn new(sigma: f64) -> Gaussian {
        assert!(sigma > 0.0);
        Gaussian { inv_sigma_sq: 1.0 / (sigma * sigma) }
    }

    /// The paper sets σ to a fraction of the maximum pairwise Euclidean
    /// distance. Computing the exact maximum is O(n²); for n > 2000 we
    /// estimate it from a deterministic 2000-point subsample (the paper
    /// itself falls back to small-trial estimates at large n, §V-D).
    pub fn with_sigma_fraction(ds: &Dataset, fraction: f64) -> Gaussian {
        let max_d = max_pairwise_distance(ds, 2000, 0xD15C0);
        Gaussian::new((fraction * max_d).max(1e-12))
    }
}

/// Maximum pairwise distance over a subsample of at most `cap` points.
pub fn max_pairwise_distance(ds: &Dataset, cap: usize, seed: u64) -> f64 {
    let idx: Vec<usize> = if ds.n() <= cap {
        (0..ds.n()).collect()
    } else {
        Pcg64::new(seed).sample_without_replacement(ds.n(), cap)
    };
    let mut best: f64 = 0.0;
    for (a, &i) in idx.iter().enumerate() {
        for &j in idx.iter().skip(a + 1) {
            best = best.max(sq_dist(ds.point(i), ds.point(j)));
        }
    }
    best.sqrt()
}

impl Kernel for Gaussian {
    #[inline]
    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        (-sq_dist(a, b) * self.inv_sigma_sq).exp()
    }

    #[inline]
    fn diag_value(&self, _a: &[f64]) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "gaussian"
    }

    fn params(&self) -> Option<KernelParams> {
        Some(KernelParams::Gaussian { inv_sigma_sq: self.inv_sigma_sq })
    }
}

/// Linear kernel `aᵀb` — yields the Gram matrix `G = ZᵀZ` of the theory
/// sections (Lemma 1 / Theorem 1 / Fig. 5).
#[derive(Debug, Clone, Default)]
pub struct Linear;

impl Kernel for Linear {
    #[inline]
    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        crate::linalg::matrix::dot(a, b)
    }

    fn name(&self) -> &'static str {
        "linear"
    }

    fn params(&self) -> Option<KernelParams> {
        Some(KernelParams::Linear)
    }
}

/// Laplacian kernel `exp(-‖a-b‖₁/σ)`.
#[derive(Debug, Clone)]
pub struct Laplacian {
    pub inv_sigma: f64,
}

impl Laplacian {
    pub fn new(sigma: f64) -> Laplacian {
        assert!(sigma > 0.0);
        Laplacian { inv_sigma: 1.0 / sigma }
    }
}

impl Kernel for Laplacian {
    #[inline]
    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        let l1: f64 = a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum();
        (-l1 * self.inv_sigma).exp()
    }

    #[inline]
    fn diag_value(&self, _a: &[f64]) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "laplacian"
    }

    fn params(&self) -> Option<KernelParams> {
        Some(KernelParams::Laplacian { inv_sigma: self.inv_sigma })
    }
}

/// Polynomial kernel `(aᵀb + c)^d`.
#[derive(Debug, Clone)]
pub struct Polynomial {
    pub degree: u32,
    pub offset: f64,
}

impl Kernel for Polynomial {
    #[inline]
    fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        (crate::linalg::matrix::dot(a, b) + self.offset).powi(self.degree as i32)
    }

    fn name(&self) -> &'static str {
        "polynomial"
    }

    fn params(&self) -> Option<KernelParams> {
        Some(KernelParams::Polynomial {
            degree: self.degree,
            offset: self.offset,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    #[test]
    fn gaussian_identity_and_symmetry() {
        let g = Gaussian::new(2.0);
        let a = [1.0, 2.0];
        let b = [3.0, -1.0];
        assert_eq!(g.eval(&a, &a), 1.0);
        assert_eq!(g.diag_value(&a), 1.0);
        assert_eq!(g.eval(&a, &b), g.eval(&b, &a));
        // exp(-13/4)
        assert!((g.eval(&a, &b) - (-13.0f64 / 4.0).exp()).abs() < 1e-15);
    }

    #[test]
    fn linear_is_dot() {
        let k = Linear;
        assert_eq!(k.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(k.diag_value(&[3.0, 4.0]), 25.0);
    }

    #[test]
    fn laplacian_range() {
        let k = Laplacian::new(1.0);
        assert_eq!(k.eval(&[0.0], &[0.0]), 1.0);
        assert!((k.eval(&[0.0], &[2.0]) - (-2.0f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn polynomial_known() {
        let k = Polynomial { degree: 2, offset: 1.0 };
        assert_eq!(k.eval(&[1.0, 1.0], &[2.0, 3.0]), 36.0);
    }

    /// `params()` → `build()` must reproduce the kernel bit-exactly —
    /// the artifact store round-trips kernels through this pair.
    #[test]
    fn params_rebuild_evaluates_identically() {
        let a = [0.3, -1.7, 2.0];
        let b = [1.1, 0.4, -0.9];
        let kernels: Vec<Box<dyn Kernel + Send + Sync>> = vec![
            Box::new(Gaussian::new(0.73)),
            Box::new(Linear),
            Box::new(Laplacian::new(2.4)),
            Box::new(Polynomial { degree: 3, offset: 0.5 }),
        ];
        for k in kernels {
            let p = k.params().expect("concrete kernels are storable");
            let rebuilt = p.build();
            assert_eq!(rebuilt.name(), k.name());
            assert_eq!(
                rebuilt.eval(&a, &b).to_bits(),
                k.eval(&a, &b).to_bits(),
                "{} diverged after params round-trip",
                k.name()
            );
            assert_eq!(rebuilt.params(), Some(p));
        }
    }

    /// The batched `eval_rows` default must agree bit for bit with the
    /// per-entry `eval` loop for every concrete kernel — the column
    /// fills rely on this to devirtualize without changing results.
    #[test]
    fn eval_rows_bit_equals_per_entry_eval() {
        let dim = 3;
        let rows: Vec<f64> =
            (0..7 * dim).map(|i| (i as f64 * 0.37 - 2.0).sin()).collect();
        let z = [0.4, -1.2, 0.9];
        let kernels: Vec<Box<dyn Kernel + Send + Sync>> = vec![
            Box::new(Gaussian::new(0.8)),
            Box::new(Linear),
            Box::new(Laplacian::new(1.3)),
            Box::new(Polynomial { degree: 2, offset: 0.25 }),
        ];
        for k in kernels {
            let mut out = vec![0.0; 7];
            k.eval_rows(&rows, dim, &z, &mut out);
            for (i, &got) in out.iter().enumerate() {
                let want = k.eval(&rows[i * dim..(i + 1) * dim], &z);
                assert_eq!(got.to_bits(), want.to_bits(), "{} row {i}", k.name());
            }
        }
        // degenerate shapes stay well-defined
        let mut empty: [f64; 0] = [];
        Linear.eval_rows(&[], 3, &z, &mut empty);
        let mut two = [0.0; 2];
        Gaussian::new(1.0).eval_rows(&[], 0, &[], &mut two);
        assert_eq!(two, [1.0, 1.0]);
    }

    #[test]
    fn sigma_fraction_scales_with_data() {
        // two points distance 10 apart; fraction 0.5 → σ=5
        let ds = Dataset::from_rows(vec![vec![0.0, 0.0], vec![10.0, 0.0]]);
        let g = Gaussian::with_sigma_fraction(&ds, 0.5);
        assert!((g.inv_sigma_sq - 1.0 / 25.0).abs() < 1e-12);
    }

    #[test]
    fn max_pairwise_distance_exact_small() {
        let ds = Dataset::from_rows(vec![
            vec![0.0, 0.0],
            vec![3.0, 4.0],
            vec![1.0, 1.0],
        ]);
        assert!((max_pairwise_distance(&ds, 100, 1) - 5.0).abs() < 1e-12);
    }
}
