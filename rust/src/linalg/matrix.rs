//! Row-major dense matrix with the product kernels the library needs.

use crate::util::parallel;

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure.
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Select rows by index.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Select columns by index.
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            for (c, &j) in idx.iter().enumerate() {
                out.data[i * idx.len() + c] = self.at(i, j);
            }
        }
        out
    }

    /// Matrix product `self * other` (blocked over rows, threaded when big).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        let threads = if m * k * n > 1 << 18 { parallel::default_threads() } else { 1 };
        let a = &self.data;
        let b = &other.data;
        parallel::for_each_chunk_mut(&mut out.data, n, threads, |range, chunk| {
            for (local, i) in range.clone().enumerate() {
                let orow = &mut chunk[local * n..(local + 1) * n];
                let arow = &a[i * k..(i + 1) * k];
                // ikj loop order: stream through b rows
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += aik * bv;
                    }
                }
            }
        });
        out
    }

    /// `selfᵀ * other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul dims");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for kk in 0..k {
            let arow = &self.data[kk * m..(kk + 1) * m];
            let brow = &other.data[kk * n..(kk + 1) * n];
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * bv;
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec dims");
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    /// `selfᵀ x` without transposing.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len(), "t_matvec dims");
        let mut out = vec![0.0; self.cols];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += xi * a;
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// `self - other` Frobenius norm without allocating the difference.
    pub fn fro_dist(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// In-place scaled add: `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Symmetrize in place: `self = (self + selfᵀ)/2`.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        for i in 0..n {
            for j in i + 1..n {
                let avg = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = avg;
                self.data[j * n + i] = avg;
            }
        }
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled for the hot paths (Δ scoring uses this shape)
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(5, 5, |i, j| (i * 5 + j) as f64);
        let c = a.matmul(&Mat::eye(5));
        assert_eq!(c, a);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::from_fn(4, 3, |i, j| (i + 2 * j) as f64);
        let b = Mat::from_fn(4, 5, |i, j| (i as f64 - j as f64) * 0.5);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        assert!(got.fro_dist(&want) < 1e-12);
    }

    #[test]
    fn matvec_and_t_matvec() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.matvec(&[1., 1., 1.]), vec![6., 15.]);
        assert_eq!(a.t_matvec(&[1., 1.]), vec![5., 7., 9.]);
    }

    #[test]
    fn select_rows_cols() {
        let a = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let r = a.select_rows(&[2, 0]);
        assert_eq!(r.row(0), &[8., 9., 10., 11.]);
        assert_eq!(r.row(1), &[0., 1., 2., 3.]);
        let c = a.select_cols(&[3, 1]);
        assert_eq!(c.row(0), &[3., 1.]);
        assert_eq!(c.row(2), &[11., 9.]);
    }

    #[test]
    fn norms() {
        let a = Mat::from_vec(1, 2, vec![3., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        // big enough to trigger the threaded path
        let a = Mat::from_fn(128, 64, |i, j| ((i * 7 + j * 13) % 11) as f64 - 5.0);
        let b = Mat::from_fn(64, 96, |i, j| ((i * 3 + j * 5) % 7) as f64 - 3.0);
        let big = a.matmul(&b);
        // serial reference
        let mut want = Mat::zeros(128, 96);
        for i in 0..128 {
            for j in 0..96 {
                let mut s = 0.0;
                for k in 0..64 {
                    s += a.at(i, k) * b.at(k, j);
                }
                want.data[i * 96 + j] = s;
            }
        }
        assert!(big.fro_dist(&want) < 1e-9);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        for n in [0usize, 1, 3, 4, 7, 16, 33] {
            let a: Vec<f64> = (0..n).map(|i| i as f64 * 0.3 - 1.0).collect();
            let b: Vec<f64> = (0..n).map(|i| (i * i) as f64 * 0.01).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9);
        }
    }

    #[test]
    fn symmetrize() {
        let mut a = Mat::from_vec(2, 2, vec![1., 2., 4., 3.]);
        a.symmetrize();
        assert_eq!(a.data, vec![1., 3., 3., 3.]);
    }
}
