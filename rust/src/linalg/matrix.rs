//! Row-major dense matrix with the product kernels the library needs.
//!
//! # Bit-identity invariant (read before touching the kernels)
//!
//! Every sampler selection sequence, stored artifact, and parity test in
//! this repo depends on the products below being *bit-reproducible*: the
//! blocked kernels must return the exact bits the naive triple loops
//! return. The rule that makes blocking safe is:
//!
//! * for each output element, the k-sum is accumulated into a **single
//!   accumulator in increasing-k order** — tiling may reorder *which
//!   element* is updated next, never the order of terms within one
//!   element (no split accumulators, no k-reordering, no FMA contraction
//!   assumptions);
//! * the `aik == 0.0` skip is preserved as-is — for finite inputs it is
//!   bit-neutral (a `+0.0`-initialized accumulator never becomes `-0.0`,
//!   and adding `±0.0` to such a value changes no bits), and it keeps
//!   sparse-oracle columns cheap;
//! * [`Mat::syrk`] computes the upper triangle with that same order and
//!   mirrors it, which is bit-identical to computing both halves because
//!   f64 multiplication is bitwise commutative.
//!
//! `rust/tests/properties.rs` pins blocked-vs-naive bit equality across
//! edge shapes, and `benches/perf.rs` re-asserts it on the bench shapes
//! every CI run.

use crate::util::parallel;

/// Row micro-tile for [`Mat::matmul`]: process MR output rows per pass
/// over a B block so each loaded B segment is reused MR times from L1.
const MR: usize = 4;
/// Column block: B/out segments of NB f64 (2 KiB) keep the working set
/// (MR out segments + one B segment) far under L1 size.
const NB: usize = 256;
/// Row tile for [`Mat::t_matmul`] / [`Mat::syrk`]: bounds the out tile a
/// thread revisits per column block to TB × NB f64 (64 KiB, L2-hot).
const TB: usize = 32;

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure.
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Select rows by index.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Select columns by index.
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            for (c, &j) in idx.iter().enumerate() {
                out.data[i * idx.len() + c] = self.at(i, j);
            }
        }
        out
    }

    /// Matrix product `self * other` — cache-blocked (MR row micro-tiles
    /// × NB column blocks), threaded over row chunks when big. Results
    /// are bit-identical to the naive ikj/ijk loops (module docs).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        if m == 0 || k == 0 || n == 0 {
            return out;
        }
        let threads = if m * k * n > 1 << 18 { parallel::default_threads() } else { 1 };
        let a = &self.data;
        let b = &other.data;
        parallel::for_each_chunk_mut(&mut out.data, n, threads, |range, chunk| {
            matmul_rows(a, b, k, n, range.start, range.end, chunk);
        });
        out
    }

    /// `selfᵀ * other` without materializing the transpose — blocked
    /// like [`matmul`](Mat::matmul) (TB row tiles × NB column blocks) and
    /// threaded over output-row chunks when big; previously a serial
    /// unblocked sweep. Bit-identical to it (module docs).
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul dims");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        if m == 0 || k == 0 || n == 0 {
            return out;
        }
        let threads = if m * k * n > 1 << 18 { parallel::default_threads() } else { 1 };
        let a = &self.data;
        let b = &other.data;
        parallel::for_each_chunk_mut(&mut out.data, n, threads, |range, chunk| {
            t_matmul_rows(a, b, m, n, k, range.start, range.end, chunk);
        });
        out
    }

    /// Symmetric Gram product `selfᵀ * self` (treating `self` as k×m, the
    /// k-rows-of-samples layout [`t_matmul`](Mat::t_matmul) uses): the
    /// dedicated syrk primitive for `ΦᵀΦ` / `BᵀB` shapes. Computes only
    /// the upper triangle — with the exact per-element accumulation order
    /// of `self.t_matmul(self)` — and mirrors it, so for finite inputs
    /// the result is bit-identical to the general product at roughly half
    /// the flops (module docs give the `−0.0` argument).
    pub fn syrk(&self) -> Mat {
        let (k, m) = (self.rows, self.cols);
        let mut out = Mat::zeros(m, m);
        if m == 0 || k == 0 {
            return out;
        }
        let threads = if m * m * k > 1 << 18 { parallel::default_threads() } else { 1 };
        let a = &self.data;
        parallel::for_each_chunk_mut(&mut out.data, m, threads, |range, chunk| {
            let mut ib = range.start;
            while ib < range.end {
                let ih = (ib + TB).min(range.end);
                for kk in 0..k {
                    let arow = &a[kk * m..(kk + 1) * m];
                    for i in ib..ih {
                        let aik = arow[i];
                        if aik == 0.0 {
                            continue;
                        }
                        let base = (i - range.start) * m;
                        // upper-triangle row segment j = i..m
                        let orow = &mut chunk[base + i..base + m];
                        for (o, &av) in orow.iter_mut().zip(&arow[i..]) {
                            *o += aik * av;
                        }
                    }
                }
                ib = ih;
            }
        });
        // mirror the strict lower triangle (threads own disjoint row
        // chunks above, so the mirror must run after the join)
        for i in 1..m {
            for j in 0..i {
                out.data[i * m + j] = out.data[j * m + i];
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec dims");
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    /// `selfᵀ x` without transposing.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len(), "t_matvec dims");
        let mut out = vec![0.0; self.cols];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += xi * a;
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// `self - other` Frobenius norm without allocating the difference.
    pub fn fro_dist(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// In-place scaled add: `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Symmetrize in place: `self = (self + selfᵀ)/2`.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        for i in 0..n {
            for j in i + 1..n {
                let avg = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = avg;
                self.data[j * n + i] = avg;
            }
        }
    }
}

/// Blocked row-panel kernel behind [`Mat::matmul`]: computes output rows
/// `lo..hi` (`chunk`) of A·B. Loop order is (row quad, column block, k,
/// row): for every output element the k-terms still land in a single
/// accumulator in increasing-k order with the `aik == 0.0` skip of the
/// original ikj loop, so the result is bit-identical — blocking only buys
/// L1 reuse of each B segment across MR output rows.
fn matmul_rows(
    a: &[f64],
    b: &[f64],
    k: usize,
    n: usize,
    lo: usize,
    hi: usize,
    chunk: &mut [f64],
) {
    let mut i = lo;
    while i < hi {
        let mr = MR.min(hi - i);
        let mut jb = 0;
        while jb < n {
            let nb = NB.min(n - jb);
            for kk in 0..k {
                let bseg = &b[kk * n + jb..kk * n + jb + nb];
                for r in 0..mr {
                    let aik = a[(i + r) * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let base = (i + r - lo) * n + jb;
                    let oseg = &mut chunk[base..base + nb];
                    for (o, &bv) in oseg.iter_mut().zip(bseg) {
                        *o += aik * bv;
                    }
                }
            }
            jb += nb;
        }
        i += mr;
    }
}

/// Blocked kernel behind [`Mat::t_matmul`]: output rows `lo..hi` of AᵀB
/// with A stored k×m. Streams the k dimension outermost per (TB × NB)
/// output tile — A and B rows are read contiguously — while each output
/// element keeps the single-accumulator increasing-k order and the
/// `a == 0.0` skip of the original serial sweep (bit-identical).
fn t_matmul_rows(
    a: &[f64],
    b: &[f64],
    m: usize,
    n: usize,
    k: usize,
    lo: usize,
    hi: usize,
    chunk: &mut [f64],
) {
    let mut ib = lo;
    while ib < hi {
        let ih = (ib + TB).min(hi);
        let mut jb = 0;
        while jb < n {
            let nb = NB.min(n - jb);
            for kk in 0..k {
                let arow = &a[kk * m..(kk + 1) * m];
                let bseg = &b[kk * n + jb..kk * n + jb + nb];
                for i in ib..ih {
                    let aik = arow[i];
                    if aik == 0.0 {
                        continue;
                    }
                    let base = (i - lo) * n + jb;
                    let oseg = &mut chunk[base..base + nb];
                    for (o, &bv) in oseg.iter_mut().zip(bseg) {
                        *o += aik * bv;
                    }
                }
            }
            jb += nb;
        }
        ib = ih;
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled for the hot paths (Δ scoring uses this shape)
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `dot` in f32 — same 4-way unrolled accumulation shape, single
/// precision end to end. The f32 serving path accumulates in f32 on
/// purpose (that *is* the reduced-precision mode; see the store's
/// precision caveat), so this is not `dot` with casts at the edges.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_f32_matches_f64_within_single_precision() {
        let a: Vec<f64> = (0..13).map(|i| (i as f64 * 0.61).sin()).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64 * 0.23).cos()).collect();
        let af: Vec<f32> = a.iter().map(|&x| x as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&x| x as f32).collect();
        let want = dot(&a, &b);
        let got = dot_f32(&af, &bf) as f64;
        assert!((got - want).abs() < 1e-5, "{got} vs {want}");
    }

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(5, 5, |i, j| (i * 5 + j) as f64);
        let c = a.matmul(&Mat::eye(5));
        assert_eq!(c, a);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::from_fn(4, 3, |i, j| (i + 2 * j) as f64);
        let b = Mat::from_fn(4, 5, |i, j| (i as f64 - j as f64) * 0.5);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        assert!(got.fro_dist(&want) < 1e-12);
    }

    #[test]
    fn matvec_and_t_matvec() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.matvec(&[1., 1., 1.]), vec![6., 15.]);
        assert_eq!(a.t_matvec(&[1., 1.]), vec![5., 7., 9.]);
    }

    #[test]
    fn select_rows_cols() {
        let a = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let r = a.select_rows(&[2, 0]);
        assert_eq!(r.row(0), &[8., 9., 10., 11.]);
        assert_eq!(r.row(1), &[0., 1., 2., 3.]);
        let c = a.select_cols(&[3, 1]);
        assert_eq!(c.row(0), &[3., 1.]);
        assert_eq!(c.row(2), &[11., 9.]);
    }

    #[test]
    fn norms() {
        let a = Mat::from_vec(1, 2, vec![3., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(a.max_abs(), 4.0);
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        // big enough to trigger the threaded path
        let a = Mat::from_fn(128, 64, |i, j| ((i * 7 + j * 13) % 11) as f64 - 5.0);
        let b = Mat::from_fn(64, 96, |i, j| ((i * 3 + j * 5) % 7) as f64 - 3.0);
        let big = a.matmul(&b);
        // serial reference
        let mut want = Mat::zeros(128, 96);
        for i in 0..128 {
            for j in 0..96 {
                let mut s = 0.0;
                for k in 0..64 {
                    s += a.at(i, k) * b.at(k, j);
                }
                want.data[i * 96 + j] = s;
            }
        }
        assert!(big.fro_dist(&want) < 1e-9);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        for n in [0usize, 1, 3, 4, 7, 16, 33] {
            let a: Vec<f64> = (0..n).map(|i| i as f64 * 0.3 - 1.0).collect();
            let b: Vec<f64> = (0..n).map(|i| (i * i) as f64 * 0.01).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9);
        }
    }

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for kk in 0..a.cols {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                out.data[i * b.cols + j] = s;
            }
        }
        out
    }

    fn fill_pseudo(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data);
        // sprinkle exact zeros so the skip path is exercised
        for (i, v) in m.data.iter_mut().enumerate() {
            if i % 17 == 0 {
                *v = 0.0;
            }
        }
        m
    }

    #[test]
    fn blocked_matmul_bit_equals_naive_across_tile_edges() {
        for (m, k, n) in
            [(1, 1, 1), (3, 5, 255), (4, 2, 256), (5, 7, 257), (9, 3, 300)]
        {
            let a = fill_pseudo(m, k, 1);
            let b = fill_pseudo(k, n, 2);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            for (g, w) in got.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), w.to_bits(), "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn empty_products_are_well_defined() {
        for (m, k, n) in [(0usize, 3usize, 4usize), (3, 0, 4), (3, 4, 0)] {
            let a = Mat::zeros(m, k);
            let b = Mat::zeros(k, n);
            assert_eq!(a.matmul(&b), Mat::zeros(m, n));
            let at = Mat::zeros(k, m);
            assert_eq!(at.t_matmul(&b), Mat::zeros(m, n));
        }
        assert_eq!(Mat::zeros(0, 5).syrk(), Mat::zeros(5, 5));
        assert_eq!(Mat::zeros(5, 0).syrk(), Mat::zeros(0, 0));
    }

    #[test]
    fn blocked_t_matmul_bit_equals_transpose_matmul() {
        // (k, m, n) shapes crossing the TB and NB tile edges
        for (k, m, n) in [(1, 1, 1), (5, 33, 257), (7, 40, 300), (3, 64, 256)] {
            let a = fill_pseudo(k, m, 3);
            let b = fill_pseudo(k, n, 4);
            let got = a.t_matmul(&b);
            let want = naive_matmul(&a.transpose(), &b);
            for (g, w) in got.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), w.to_bits(), "({k},{m},{n})");
            }
        }
    }

    #[test]
    fn syrk_bit_equals_t_matmul_self() {
        for (k, m) in [(1usize, 1usize), (40, 33), (9, 70), (200, 48)] {
            let a = fill_pseudo(k, m, 5);
            let got = a.syrk();
            let want = a.t_matmul(&a);
            assert_eq!(got.rows, m);
            for (g, w) in got.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), w.to_bits(), "({k},{m})");
            }
        }
    }

    #[test]
    fn threaded_kernels_bit_equal_naive() {
        // past the 2^18 flops threading cutoff for all three kernels
        let a = fill_pseudo(70, 70, 6);
        let b = fill_pseudo(70, 270, 7);
        let got = a.matmul(&b);
        let want = naive_matmul(&a, &b);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        let got = a.t_matmul(&b);
        let want = naive_matmul(&a.transpose(), &b);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        let tall = fill_pseudo(300, 70, 8);
        let got = tall.syrk();
        let want = naive_matmul(&tall.transpose(), &tall);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn symmetrize() {
        let mut a = Mat::from_vec(2, 2, vec![1., 2., 4., 3.]);
        a.symmetrize();
        assert_eq!(a.data, vec![1., 3., 3., 3.]);
    }
}
