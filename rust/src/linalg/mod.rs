//! Dense linear algebra substrate, written from scratch (no BLAS/LAPACK in
//! this environment): row-major [`Mat`], blocked matrix products, Cholesky,
//! LU inverse, symmetric Jacobi eigendecomposition, QR, and pseudo-inverse.
//!
//! Everything the samplers need: `W⁻¹` bootstrap (LU), leverage scores
//! (subspace iteration = matmul + QR + small eig), K-means Nyström pinv,
//! Nyström SVD (eig of W), and exact Frobenius error evaluation.

pub mod chol;
pub mod eig;
pub mod lu;
pub mod matrix;
pub mod qr;

pub use chol::Cholesky;
pub use eig::{pinv_psd, psd_sqrt, sym_eig, SymEig};
pub use lu::{inverse, solve as lu_solve};
pub use matrix::Mat;
pub use qr::thin_qr;
