//! Thin QR via modified Gram–Schmidt with one reorthogonalization pass.
//! Used by the randomized subspace iteration behind leverage scores.

use super::matrix::{dot, Mat};

/// Thin QR of an m×n matrix (m ≥ n): returns Q (m×n with orthonormal
/// columns) and R (n×n upper triangular). Rank-deficient columns are
/// replaced by zero columns in Q (their R diagonal is 0).
pub fn thin_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "thin_qr expects tall matrix");
    // work with columns
    let mut q_cols: Vec<Vec<f64>> = (0..n).map(|j| a.col(j)).collect();
    let mut r = Mat::zeros(n, n);
    for j in 0..n {
        // two-pass MGS for numerical robustness
        for _pass in 0..2 {
            for i in 0..j {
                let rij = dot(&q_cols[i], &q_cols[j]);
                *r.at_mut(i, j) += rij;
                let (qi, qj) = split_two(&mut q_cols, i, j);
                for (x, y) in qj.iter_mut().zip(qi.iter()) {
                    *x -= rij * y;
                }
            }
        }
        let norm = dot(&q_cols[j], &q_cols[j]).sqrt();
        *r.at_mut(j, j) = norm;
        if norm > 1e-300 {
            for x in q_cols[j].iter_mut() {
                *x /= norm;
            }
        } else {
            for x in q_cols[j].iter_mut() {
                *x = 0.0;
            }
        }
    }
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        for i in 0..m {
            *q.at_mut(i, j) = q_cols[j][i];
        }
    }
    (q, r)
}

/// Borrow two distinct elements of a Vec mutably.
fn split_two<'a, T>(v: &'a mut [T], i: usize, j: usize) -> (&'a T, &'a mut T) {
    assert!(i < j);
    let (head, tail) = v.split_at_mut(j);
    (&head[i], &mut tail[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::new(21);
        let mut a = Mat::zeros(20, 6);
        rng.fill_normal(&mut a.data);
        let (q, r) = thin_qr(&a);
        let qr = q.matmul(&r);
        assert!(qr.fro_dist(&a) < 1e-10 * (1.0 + a.fro_norm()));
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg64::new(22);
        let mut a = Mat::zeros(30, 8);
        rng.fill_normal(&mut a.data);
        let (q, _) = thin_qr(&a);
        let qtq = q.t_matmul(&q);
        assert!(qtq.fro_dist(&Mat::eye(8)) < 1e-10);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg64::new(23);
        let mut a = Mat::zeros(10, 5);
        rng.fill_normal(&mut a.data);
        let (_, r) = thin_qr(&a);
        for i in 1..5 {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn handles_rank_deficiency() {
        // column 1 = 2 * column 0
        let a = Mat::from_fn(6, 3, |i, j| match j {
            0 => i as f64 + 1.0,
            1 => 2.0 * (i as f64 + 1.0),
            _ => (i * i) as f64,
        });
        let (q, r) = thin_qr(&a);
        assert!(r.at(1, 1).abs() < 1e-9);
        // reconstruction still holds
        assert!(q.matmul(&r).fro_dist(&a) < 1e-9 * a.fro_norm());
    }
}
