//! LU factorization with partial pivoting: general solve and inverse.
//! Used to bootstrap `W₀⁻¹` from the random seed columns (W₀ is symmetric
//! but may be near-singular if the seed drew near-duplicate points, so we
//! prefer pivoted LU over Cholesky here).

use super::Mat;

/// LU decomposition with row pivoting (Doolittle).
#[derive(Debug, Clone)]
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
    /// number of row swaps mod 2 (for determinant sign)
    swaps: usize,
}

impl Lu {
    /// Factor. Returns `None` on exact singularity.
    pub fn new(a: &Mat) -> Option<Lu> {
        assert_eq!(a.rows, a.cols, "lu: square required");
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut swaps = 0;
        for col in 0..n {
            // pivot
            let mut pi = col;
            let mut pmax = lu.at(col, col).abs();
            for r in col + 1..n {
                let v = lu.at(r, col).abs();
                if v > pmax {
                    pmax = v;
                    pi = r;
                }
            }
            if pmax == 0.0 {
                return None;
            }
            if pi != col {
                for j in 0..n {
                    let tmp = lu.at(col, j);
                    *lu.at_mut(col, j) = lu.at(pi, j);
                    *lu.at_mut(pi, j) = tmp;
                }
                piv.swap(col, pi);
                swaps += 1;
            }
            let d = lu.at(col, col);
            for r in col + 1..n {
                let f = lu.at(r, col) / d;
                *lu.at_mut(r, col) = f;
                if f != 0.0 {
                    for j in col + 1..n {
                        *lu.at_mut(r, j) -= f * lu.at(col, j);
                    }
                }
            }
        }
        Some(Lu { lu, piv, swaps })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows;
        assert_eq!(b.len(), n);
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // forward
        for i in 1..n {
            let mut s = x[i];
            for k in 0..i {
                s -= self.lu.at(i, k) * x[k];
            }
            x[i] = s;
        }
        // backward
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in i + 1..n {
                s -= self.lu.at(i, k) * x[k];
            }
            x[i] = s / self.lu.at(i, i);
        }
        x
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = if self.swaps % 2 == 0 { 1.0 } else { -1.0 };
        for i in 0..self.lu.rows {
            d *= self.lu.at(i, i);
        }
        d
    }
}

/// Solve `A x = b` (convenience).
pub fn solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    Lu::new(a).map(|lu| lu.solve(b))
}

/// Matrix inverse via pivoted LU. Returns `None` if singular.
pub fn inverse(a: &Mat) -> Option<Mat> {
    let n = a.rows;
    let lu = Lu::new(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let x = lu.solve(&e);
        for i in 0..n {
            *inv.at_mut(i, j) = x[i];
        }
        e[j] = 0.0;
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn solve_known_system() {
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 3.0]);
        let x = solve(&a, &[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn inverse_roundtrip_random() {
        let mut rng = Pcg64::new(11);
        for n in [1usize, 2, 5, 20] {
            let mut a = Mat::zeros(n, n);
            rng.fill_normal(&mut a.data);
            for i in 0..n {
                *a.at_mut(i, i) += 3.0;
            }
            let inv = inverse(&a).expect("invertible");
            let eye = a.matmul(&inv);
            assert!(eye.fro_dist(&Mat::eye(n)) < 1e-8, "n={n}");
        }
    }

    #[test]
    fn detects_singular() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(inverse(&a).is_none());
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let x = solve(&a, &[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn det_sign_with_swaps() {
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let lu = Lu::new(&a).unwrap();
        assert!((lu.det() + 1.0).abs() < 1e-12);
    }
}
