//! Cholesky factorization of symmetric positive-definite matrices, with
//! solve/inverse. Used to bootstrap `W₀⁻¹` and in tests as an independent
//! check of the iterated Eq. 5 inverse.

use super::Mat;

/// Lower-triangular Cholesky factor: `A = L Lᵀ`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    pub l: Mat,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix. Returns `None` if a
    /// non-positive pivot is met (matrix not PD to working precision).
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows, a.cols, "cholesky: square required");
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a.at(i, j);
                for k in 0..j {
                    s -= l.at(i, k) * l.at(j, k);
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    *l.at_mut(i, j) = s.sqrt();
                } else {
                    *l.at_mut(i, j) = s / l.at(j, j);
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.at(i, k) * y[k];
            }
            y[i] = s / self.l.at(i, i);
        }
        // backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l.at(k, i) * x[k];
            }
            x[i] = s / self.l.at(i, i);
        }
        x
    }

    /// Inverse via n solves.
    pub fn inverse(&self) -> Mat {
        let n = self.l.rows;
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let x = self.solve(&e);
            for i in 0..n {
                *inv.at_mut(i, j) = x[i];
            }
            e[j] = 0.0;
        }
        inv
    }

    /// log-determinant of A.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.at(i, i).ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut x = Mat::zeros(n, n);
        rng.fill_normal(&mut x.data);
        let mut a = x.t_matmul(&x);
        for i in 0..n {
            *a.at_mut(i, i) += n as f64; // well conditioned
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(8, 1);
        let ch = Cholesky::new(&a).unwrap();
        let recon = ch.l.matmul(&ch.l.transpose());
        assert!(recon.fro_dist(&a) < 1e-9 * a.fro_norm());
    }

    #[test]
    fn solve_is_correct() {
        let a = random_spd(10, 2);
        let ch = Cholesky::new(&a).unwrap();
        let b: Vec<f64> = (0..10).map(|i| i as f64 - 4.0).collect();
        let x = ch.solve(&b);
        let ax = a.matvec(&x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(6, 3);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let eye = a.matmul(&inv);
        assert!(eye.fro_dist(&Mat::eye(6)) < 1e-8);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigvals 3, -1
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn log_det_matches_2x2() {
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]); // det = 11
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - 11f64.ln()).abs() < 1e-10);
    }
}
