//! Symmetric eigendecomposition (cyclic Jacobi) and PSD pseudo-inverse.
//!
//! Jacobi is O(n³) with a healthy constant but is simple, branch-light and
//! extremely accurate for the small/medium symmetric matrices we feed it:
//! `W` (ℓ×ℓ, ℓ ≤ a few thousand), subspace-iteration projections
//! ((k+p)×(k+p)), and test matrices. For the n×n leverage-score path we use
//! randomized subspace iteration (see sampling/leverage.rs) so Jacobi only
//! ever sees small matrices there.

use super::Mat;

/// Eigendecomposition `A = V diag(vals) Vᵀ`, eigenvalues descending.
#[derive(Debug, Clone)]
pub struct SymEig {
    pub vals: Vec<f64>,
    /// column j of `vecs` is the eigenvector for `vals[j]`
    pub vecs: Mat,
}

/// Symmetric eigendecomposition. Dispatches on size: cyclic Jacobi for
/// small matrices (n ≤ 48; simplest and extremely accurate), Householder
/// tridiagonalization + implicit QL for larger ones (~30× faster at
/// n = 450 — see EXPERIMENTS.md §Perf).
pub fn sym_eig(a: &Mat) -> SymEig {
    if a.rows <= 48 {
        sym_eig_jacobi(a)
    } else {
        sym_eig_tridiag(a)
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
pub fn sym_eig_jacobi(a: &Mat) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig: square required");
    let n = a.rows;
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m.at(i, j) * m.at(i, j);
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m.at(p, q);
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m.at(k, p);
                    let mkq = m.at(k, q);
                    *m.at_mut(k, p) = c * mkp - s * mkq;
                    *m.at_mut(k, q) = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m.at(p, k);
                    let mqk = m.at(q, k);
                    *m.at_mut(p, k) = c * mpk - s * mqk;
                    *m.at_mut(q, k) = s * mpk + c * mqk;
                }
                // accumulate rotations
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    *v.at_mut(k, p) = c * vkp - s * vkq;
                    *v.at_mut(k, q) = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort descending
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m.at(j, j).partial_cmp(&m.at(i, i)).unwrap());
    let vals: Vec<f64> = order.iter().map(|&i| m.at(i, i)).collect();
    let vecs = v.select_cols(&order);
    SymEig { vals, vecs }
}

/// Householder tridiagonalization + implicit-shift QL eigendecomposition
/// (tred2/tqli, Numerical Recipes style). O(n³) with a far smaller
/// constant than Jacobi; the default for n > 48.
pub fn sym_eig_tridiag(a: &Mat) -> SymEig {
    let n = a.rows;
    assert_eq!(n, a.cols, "sym_eig: square required");
    // z starts as (symmetrized) A and accumulates the orthogonal transform
    let mut z = a.clone();
    z.symmetrize();
    let mut d = vec![0.0f64; n]; // diagonal
    let mut e = vec![0.0f64; n]; // off-diagonal

    // --- tred2: reduce to tridiagonal, accumulating transforms in z ---
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z.at(i, k).abs();
            }
            if scale == 0.0 {
                e[i] = z.at(i, l);
            } else {
                for k in 0..=l {
                    *z.at_mut(i, k) /= scale;
                    h += z.at(i, k) * z.at(i, k);
                }
                let mut f = z.at(i, l);
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                *z.at_mut(i, l) = f - g;
                f = 0.0;
                for j in 0..=l {
                    *z.at_mut(j, i) = z.at(i, j) / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z.at(j, k) * z.at(i, k);
                    }
                    for k in j + 1..=l {
                        g += z.at(k, j) * z.at(i, k);
                    }
                    e[j] = g / h;
                    f += e[j] * z.at(i, j);
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z.at(i, j);
                    e[j] -= hh * f;
                    let g = e[j];
                    for k in 0..=j {
                        *z.at_mut(j, k) -= f * e[k] + g * z.at(i, k);
                    }
                }
            }
        } else {
            e[i] = z.at(i, l);
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z.at(i, k) * z.at(k, j);
                }
                for k in 0..i {
                    *z.at_mut(k, j) -= g * z.at(k, i);
                }
            }
        }
        d[i] = z.at(i, i);
        *z.at_mut(i, i) = 1.0;
        for j in 0..i {
            *z.at_mut(j, i) = 0.0;
            *z.at_mut(i, j) = 0.0;
        }
    }

    // --- tqli: implicit-shift QL on the tridiagonal, rotating z ---
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find a small subdiagonal element to split
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tqli: no convergence at l={l}");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate rotation into eigenvector matrix
                for k in 0..n {
                    f = z.at(k, i + 1);
                    *z.at_mut(k, i + 1) = s * z.at(k, i) + c * f;
                    *z.at_mut(k, i) = c * z.at(k, i) - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // sort descending
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[j].partial_cmp(&d[i]).unwrap());
    let vals: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let vecs = z.select_cols(&order);
    SymEig { vals, vecs }
}

impl SymEig {
    /// Reconstruct `V diag(f(vals)) Vᵀ`.
    pub fn apply_spectral(&self, f: impl Fn(f64) -> f64) -> Mat {
        let n = self.vals.len();
        let mut scaled = self.vecs.clone(); // V
        for j in 0..n {
            let fv = f(self.vals[j]);
            for i in 0..n {
                *scaled.at_mut(i, j) *= fv;
            }
        }
        // scaled * Vᵀ
        scaled.matmul(&self.vecs.transpose())
    }
}

/// Symmetric PSD square root `A^{1/2} = V diag(λ₊^{1/2}) Vᵀ`, clamping
/// tiny negative eigenvalues (from pseudo-inverses) to zero. Shared by
/// the Nyström eigendecomposition ([`crate::nystrom::nystrom_eig`]) and
/// the downstream-task fits ([`crate::tasks`]), both of which split
/// `G̃ = C W⁺ Cᵀ` into the factor form `B Bᵀ` with `B = C (W⁺)^{1/2}`.
pub fn psd_sqrt(a: &Mat) -> Mat {
    let eig = sym_eig(a);
    eig.apply_spectral(|l| l.max(0.0).sqrt())
}

/// Moore–Penrose pseudo-inverse of a symmetric PSD matrix, with relative
/// eigenvalue cutoff `rcond` (eigenvalues ≤ rcond·λmax are treated as zero).
pub fn pinv_psd(a: &Mat, rcond: f64) -> Mat {
    let eig = sym_eig(a);
    let lmax = eig.vals.first().copied().unwrap_or(0.0).max(0.0);
    let cut = rcond * lmax;
    eig.apply_spectral(|l| if l > cut && l > 0.0 { 1.0 / l } else { 0.0 })
}

/// Effective rank at relative tolerance `rtol`.
pub fn psd_rank(a: &Mat, rtol: f64) -> usize {
    let eig = sym_eig(a);
    let lmax = eig.vals.first().copied().unwrap_or(0.0).max(0.0);
    if lmax == 0.0 {
        return 0;
    }
    eig.vals.iter().filter(|&&l| l > rtol * lmax).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut a = Mat::zeros(n, n);
        rng.fill_normal(&mut a.data);
        a.symmetrize();
        a
    }

    #[test]
    fn eig_reconstructs() {
        for n in [1usize, 2, 3, 8, 25] {
            let a = random_sym(n, n as u64);
            let e = sym_eig(&a);
            let recon = e.apply_spectral(|l| l);
            assert!(recon.fro_dist(&a) < 1e-9 * (1.0 + a.fro_norm()), "n={n}");
        }
    }

    #[test]
    fn eig_orthonormal_vectors() {
        let a = random_sym(12, 5);
        let e = sym_eig(&a);
        let vtv = e.vecs.t_matmul(&e.vecs);
        assert!(vtv.fro_dist(&Mat::eye(12)) < 1e-10);
    }

    #[test]
    fn eig_values_sorted_descending() {
        let a = random_sym(10, 6);
        let e = sym_eig(&a);
        for w in e.vals.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn eig_known_2x2() {
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = sym_eig(&a);
        assert!((e.vals[0] - 3.0).abs() < 1e-12);
        assert!((e.vals[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn psd_sqrt_squares_back() {
        // PSD with a zero eigenvalue: sqrt² must reproduce A
        let x = [1.0, 2.0, 3.0];
        let mut a = Mat::from_fn(3, 3, |i, j| x[i] * x[j]);
        *a.at_mut(0, 0) += 2.0;
        *a.at_mut(1, 1) += 2.0;
        *a.at_mut(2, 2) += 2.0;
        let r = psd_sqrt(&a);
        assert!(r.matmul(&r).fro_dist(&a) < 1e-9 * (1.0 + a.fro_norm()));
        // exactly symmetric inputs with negative noise clamp cleanly
        let rank1 = Mat::from_fn(3, 3, |i, j| x[i] * x[j]);
        let r1 = psd_sqrt(&rank1);
        assert!(r1.matmul(&r1).fro_dist(&rank1) < 1e-8 * rank1.fro_norm());
    }

    #[test]
    fn pinv_of_rank_deficient() {
        // G = x xᵀ rank 1
        let x = [1.0, 2.0, 3.0];
        let a = Mat::from_fn(3, 3, |i, j| x[i] * x[j]);
        let p = pinv_psd(&a, 1e-12);
        // A P A = A (Moore–Penrose)
        let apa = a.matmul(&p).matmul(&a);
        assert!(apa.fro_dist(&a) < 1e-9);
        assert_eq!(psd_rank(&a, 1e-9), 1);
    }

    #[test]
    fn pinv_of_invertible_is_inverse() {
        let mut a = random_sym(6, 9);
        for i in 0..6 {
            *a.at_mut(i, i) += 10.0;
        }
        let p = pinv_psd(&a, 1e-14);
        assert!(a.matmul(&p).fro_dist(&Mat::eye(6)) < 1e-8);
    }

    #[test]
    fn tridiag_matches_jacobi() {
        for n in [3usize, 10, 30, 80, 150] {
            let a = random_sym(n, 100 + n as u64);
            let ej = sym_eig_jacobi(&a);
            let et = sym_eig_tridiag(&a);
            for (x, y) in ej.vals.iter().zip(&et.vals) {
                assert!(
                    (x - y).abs() < 1e-8 * (1.0 + x.abs()),
                    "n={n}: {x} vs {y}"
                );
            }
            // both reconstruct A
            let recon = et.apply_spectral(|l| l);
            assert!(recon.fro_dist(&a) < 1e-8 * (1.0 + a.fro_norm()), "n={n}");
            // orthonormal vectors
            let vtv = et.vecs.t_matmul(&et.vecs);
            assert!(vtv.fro_dist(&Mat::eye(n)) < 1e-8, "n={n}");
        }
    }

    #[test]
    fn tridiag_handles_degenerate_matrices() {
        // identity: all eigenvalues 1
        let et = sym_eig_tridiag(&Mat::eye(60));
        assert!(et.vals.iter().all(|&l| (l - 1.0).abs() < 1e-12));
        // zero matrix
        let et = sym_eig_tridiag(&Mat::zeros(50, 50));
        assert!(et.vals.iter().all(|&l| l.abs() < 1e-12));
        // rank-1 PSD at scale
        let x: Vec<f64> = (0..70).map(|i| (i as f64 * 0.1).sin()).collect();
        let a = Mat::from_fn(70, 70, |i, j| x[i] * x[j]);
        let et = sym_eig_tridiag(&a);
        let expected: f64 = x.iter().map(|v| v * v).sum();
        assert!((et.vals[0] - expected).abs() < 1e-8 * expected);
        assert!(et.vals[1].abs() < 1e-8 * expected);
    }
}
