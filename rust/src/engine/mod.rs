//! The unified run pipeline: one spec-driven path from "where is the
//! data and which sampler" to a live [`SamplerSession`], shared by every
//! front end.
//!
//! Before this layer existed the CLI (`main.rs`), the HTTP server
//! (`server::registry`) and the oASIS-P coordinator each hand-rolled the
//! dataset → kernel → oracle → session → stopping wiring, and features
//! like artifact warm-start or per-worker shard reads had no single seam
//! to plug into. Now the pipeline is *data*:
//!
//! * [`RunSpec`] — dataset source (generator | inline points | file),
//!   kernel + params, method + sampler parameters, stopping criteria,
//!   optional `warm_start` artifact, optional `shard_reads`.
//! * [`SessionBuilder`] — resolves a spec once (materializes or
//!   header-peeks the dataset, resolves σ, clamps budgets to n,
//!   validates the warm-start artifact) into a [`ResolvedRun`].
//! * [`ResolvedRun`] — opens sessions: [`open_session`]
//!   (stepwise, all hosted methods), [`one_shot`]
//!   (`random`/`leverage`/`kmeans`), [`open_oasis_p`]
//!   (concrete distributed session with its run report), and
//!   [`open_accel_session`] (the PJRT path).
//!
//! [`open_session`]: ResolvedRun::open_session
//! [`one_shot`]: ResolvedRun::one_shot
//! [`open_oasis_p`]: ResolvedRun::open_oasis_p
//! [`open_accel_session`]: ResolvedRun::open_accel_session
//!
//! Two capabilities live here because every front end gets them for free
//! through the spec:
//!
//! * **Artifact warm-start** (`RunSpec::warm_start`) — a stored
//!   artifact's Λ seeds a new session that *resumes* selection instead
//!   of starting cold (CLI `approximate --resume-from`, server create
//!   option `"warm_start"`). The replay is bit-exact: given the same
//!   dataset/kernel/`init_cols`, the warm session's state equals the
//!   state of the session that saved the artifact, so continued
//!   selection matches an uninterrupted run bit for bit.
//! * **Sharded worker reads** (`RunSpec::shard_reads`) — oASIS-P workers
//!   each read only their own byte range of a binary dataset file
//!   ([`data::loader::load_shard`](crate::data::loader::load_shard));
//!   the leader never materializes the dataset (the paper's Algorithm 2
//!   distributed-data setting).
//!
//! ```no_run
//! use oasis::engine::{
//!     stopping_rule, DatasetSpec, KernelSpec, Method, MethodSpec, RunSpec,
//!     SessionBuilder,
//! };
//! use oasis::sampling::{run_to_completion, SamplerSession};
//!
//! let spec = RunSpec {
//!     dataset: DatasetSpec::Generator {
//!         name: "two-moons".into(), n: 2_000, seed: 42, noise: 0.05, dim: 0,
//!     },
//!     kernel: KernelSpec::Gaussian { sigma: None, sigma_fraction: 0.05 },
//!     method: MethodSpec {
//!         method: Method::Oasis, max_cols: 450, init_cols: 10,
//!         tol: 1e-12, seed: 7, batch: 10, workers: 4,
//!         merge_batch: 1, listen: None,
//!     },
//!     stopping: stopping_rule(450, Some(1e-3), None),
//!     shard_reads: false,
//!     warm_start: None,
//! };
//! let run = SessionBuilder::new().resolve(spec).unwrap();
//! let slot = run.oracle_slot();
//! let mut session = run.open_session(&slot).unwrap();
//! let reason = run_to_completion(session.as_mut(), &run.stopping).unwrap();
//! println!("stopped after {} columns ({reason:?})", session.k());
//! ```
//!
//! [`SamplerSession`]: crate::sampling::SamplerSession

pub mod builder;
pub mod spec;

pub use builder::{OracleSlot, ResolvedRun, RunData, SessionBuilder, WarmStart};
pub use spec::{
    stopping_rule, DatasetSpec, KernelSpec, LabelsSpec, Method, MethodSpec,
    RunSpec, TaskSpec, WarmStartSpec,
};
