//! Resolving a [`RunSpec`] into a live run.
//!
//! [`SessionBuilder::resolve`] performs every effectful part of the
//! pipeline exactly once — materialize (or header-peek) the dataset,
//! resolve the kernel, clamp the sampler parameters to n, load and
//! validate the warm-start artifact — and returns a [`ResolvedRun`] that
//! any front end can open sessions from. Because the sequential sessions
//! borrow their oracle (which borrows the dataset and kernel), opening
//! is two-step: [`ResolvedRun::oracle_slot`] pins the oracle on the
//! caller's stack, then [`ResolvedRun::open_session`] builds the session
//! against it — the same shape the server's actor threads already use.

use super::spec::{
    DatasetSpec, KernelSpec, LabelsSpec, Method, MethodSpec, RunSpec, TaskSpec,
    WarmStartSpec,
};
use crate::coordinator::{
    OasisPConfig, OasisPSession, ShardPlan, TcpTransport, Transport,
};
use crate::data::{loader, Dataset, LoadLimits};
use crate::kernels::Kernel;
use crate::nystrom::{NystromApprox, StoredArtifact};
use crate::runtime::accel::PjrtOasis;
use crate::runtime::Accel;
use crate::sampling::{
    adaptive_random::AdaptiveRandom, farahat::Farahat, icd::IncompleteCholesky,
    kmeans::KMeansNystrom, leverage::LeverageScores, oasis::Oasis, sis::Sis,
    uniform::Uniform, ColumnSampler, ImplicitOracle, SamplerSession,
    StoppingRule,
};
use crate::Result;
use crate::{anyhow, bail};
use std::sync::Arc;

/// Resolves [`RunSpec`]s under a set of dataset size caps: the CLI uses
/// [`SessionBuilder::new`] (unlimited), the server
/// [`SessionBuilder::with_limits`] with its serving caps.
pub struct SessionBuilder {
    limits: LoadLimits,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder::new()
    }
}

impl SessionBuilder {
    /// A builder without dataset caps (CLI / library use).
    pub fn new() -> SessionBuilder {
        SessionBuilder { limits: LoadLimits::unlimited() }
    }

    /// A builder whose dataset loads/generators are bounded by `limits`
    /// while they materialize (the serving layer's caps).
    pub fn with_limits(limits: LoadLimits) -> SessionBuilder {
        SessionBuilder { limits }
    }

    /// Resolve the spec: build or header-peek the dataset, resolve the
    /// kernel, clamp the method parameters to n, clamp stopping budgets
    /// to n, and load + validate any warm-start artifact.
    pub fn resolve(&self, spec: RunSpec) -> Result<ResolvedRun> {
        let _span = crate::obs::span("engine_resolve", "engine");
        let RunSpec { dataset, kernel, mut method, stopping, shard_reads, warm_start } =
            spec;
        let source = dataset.describe();
        let data = if shard_reads {
            if method.method != Method::OasisP {
                bail!(
                    "shard_reads applies to method 'oasis-p' only (got '{}')",
                    method.method.as_str()
                );
            }
            let path = match dataset {
                DatasetSpec::File { path, .. } => path,
                other => bail!(
                    "shard_reads needs a file dataset (got {})",
                    other.describe()
                ),
            };
            let (n, dim) = loader::peek_matrix_dims(&path)?;
            self.limits.check_dim(dim)?;
            self.limits.check_n(n, dim)?;
            RunData::ShardFile { path, n, dim }
        } else {
            RunData::Full(Arc::new(dataset.build(&self.limits)?))
        };
        let kernel: Arc<dyn Kernel + Send + Sync> = match &data {
            RunData::Full(ds) => kernel.build(ds),
            RunData::ShardFile { .. } => kernel.build_resolved().ok_or_else(|| {
                anyhow!(
                    "shard_reads cannot resolve this kernel without the \
                     dataset — give the Gaussian an explicit sigma instead \
                     of sigma_fraction"
                )
            })?,
        };
        let n = data.n();
        // a budget past n is just "all columns" — same clamp every front
        // end used to apply by hand
        method.max_cols = method.max_cols.min(n).max(1);
        method.init_cols = method.init_cols.min(method.max_cols).max(1);
        let stopping = stopping.clamp_budget(n);
        let warm = match warm_start {
            None => None,
            Some(ws) => Some(resolve_warm(&ws, &data, &*kernel, &method)?),
        };
        Ok(ResolvedRun {
            data,
            kernel,
            method,
            stopping,
            source,
            warm,
            limits: self.limits,
        })
    }

    /// Resolve a [`TaskSpec`] into a validated
    /// [`tasks::TaskConfig`](crate::tasks::TaskConfig): load the label
    /// file (under this builder's dataset caps — labels are data too),
    /// pick the label column, and validate the task parameters. The
    /// returned config fits against any approximation via
    /// [`FittedTask::fit`](crate::tasks::FittedTask::fit).
    pub fn resolve_task(&self, spec: &TaskSpec) -> Result<crate::tasks::TaskConfig> {
        let labels = match &spec.labels {
            None => None,
            Some(ls) => Some(self.load_labels(ls)?),
        };
        let cfg = crate::tasks::TaskConfig {
            kind: spec.kind,
            ridge: spec.ridge,
            components: spec.components,
            clusters: spec.clusters,
            seed: spec.seed,
            labels,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load the requested columns of a CSV/binary dataset file as label
    /// columns (output-major: one `Vec` per requested column, in
    /// request order). The file is read once regardless of how many
    /// columns a multi-output fit pulls from it.
    fn load_labels(&self, ls: &LabelsSpec) -> Result<Vec<Vec<f64>>> {
        let ds = loader::load_dataset(&ls.path, &self.limits)
            .map_err(|e| e.wrap(format!("loading labels '{}'", ls.label)))?;
        if ls.cols.is_empty() {
            bail!("labels '{}': no columns requested", ls.label);
        }
        if let Some(&bad) = ls.cols.iter().find(|&&c| c >= ds.dim()) {
            bail!(
                "labels '{}': column {} requested but the file has {} columns",
                ls.label,
                bad,
                ds.dim()
            );
        }
        Ok(ls
            .cols
            .iter()
            .map(|&c| (0..ds.n()).map(|i| ds.point(i)[c]).collect())
            .collect())
    }
}

/// Load the warm-start artifact and verify it describes *this* run —
/// resuming selection against a different dataset or kernel would
/// silently corrupt every Δ score.
fn resolve_warm(
    ws: &WarmStartSpec,
    data: &RunData,
    kernel: &dyn Kernel,
    method: &MethodSpec,
) -> Result<WarmStart> {
    if !matches!(method.method, Method::Oasis | Method::Sis) {
        bail!(
            "warm_start resumes the 'oasis' and 'sis' methods only (got '{}')",
            method.method.as_str()
        );
    }
    // header-only read: a warm start needs Λ and the kernel params, never
    // the n×k factor payload (replay rebuilds state from the oracle), so
    // the artifact's factors are not materialized
    let header = StoredArtifact::peek_warm_start(&ws.path)
        .map_err(|e| e.wrap("warm_start"))?;
    if header.n != data.n() {
        bail!(
            "warm_start artifact '{}' has n = {} but this run's dataset has \
             {} points",
            ws.label,
            header.n,
            data.n()
        );
    }
    if header.dim != data.dim() {
        bail!(
            "warm_start artifact '{}' stores dimension {} but this run's \
             dataset has {}",
            ws.label,
            header.dim,
            data.dim()
        );
    }
    match kernel.params() {
        None => bail!(
            "warm_start needs a storable kernel, but '{}' has no resolved \
             parameters",
            kernel.name()
        ),
        Some(p) if p != header.kernel => bail!(
            "warm_start kernel mismatch: this run resolves to {:?} but \
             artifact '{}' stores {:?} — Δ scores would not be comparable",
            p,
            ws.label,
            header.kernel
        ),
        Some(_) => {}
    }
    // shape agreement is not identity: the artifact's stored Z_Λ must be
    // bit-equal to this dataset's points at Λ, or the replayed prefix
    // was never a selection over this data (warm starts only run against
    // materialized datasets — the oasis-method check above rules out the
    // shard-read oasis-p path)
    if let RunData::Full(ds) = data {
        for (t, &j) in header.indices.iter().enumerate() {
            let (stored, ours) = (header.selected_points.point(t), ds.point(j));
            if stored.len() != ours.len()
                || stored
                    .iter()
                    .zip(ours)
                    .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                bail!(
                    "warm_start artifact '{}' was computed on a different \
                     dataset: its stored point for column {j} does not match \
                     this run's data",
                    ws.label
                );
            }
        }
    }
    Ok(WarmStart { label: ws.label.clone(), indices: header.indices })
}

/// The run's resolved data: a materialized dataset, or — for shard-read
/// oASIS-P — just the file coordinates the workers will read their own
/// byte ranges from.
pub enum RunData {
    Full(Arc<Dataset>),
    ShardFile { path: std::path::PathBuf, n: usize, dim: usize },
}

impl RunData {
    pub fn n(&self) -> usize {
        match self {
            RunData::Full(ds) => ds.n(),
            RunData::ShardFile { n, .. } => *n,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            RunData::Full(ds) => ds.dim(),
            RunData::ShardFile { dim, .. } => *dim,
        }
    }
}

/// A validated warm start: the stored Λ the new session replays before
/// its first fresh selection.
pub struct WarmStart {
    pub label: String,
    pub indices: Vec<usize>,
}

/// The oracle pinned on the caller's stack (sequential sessions borrow
/// it). Empty for shard-read runs, whose only session type (oASIS-P)
/// reads no oracle.
pub struct OracleSlot<'a>(Option<ImplicitOracle<'a>>);

impl<'a> OracleSlot<'a> {
    pub fn get(&self) -> Option<&ImplicitOracle<'a>> {
        self.0.as_ref()
    }
}

fn boxed<'a, S: SamplerSession + 'a>(s: S) -> Box<dyn SamplerSession + 'a> {
    Box::new(s)
}

/// A resolved run: owned dataset/kernel plus the clamped method spec.
/// Open any number of sessions from it (each `oracle_slot` +
/// `open_session` pair is an independent run of the same spec).
pub struct ResolvedRun {
    pub data: RunData,
    pub kernel: Arc<dyn Kernel + Send + Sync>,
    pub method: MethodSpec,
    pub stopping: StoppingRule,
    /// Provenance line (dataset description) for reports and artifacts.
    pub source: String,
    pub warm: Option<WarmStart>,
    limits: LoadLimits,
}

impl ResolvedRun {
    pub fn n(&self) -> usize {
        self.data.n()
    }

    pub fn dim(&self) -> usize {
        self.data.dim()
    }

    /// The materialized dataset — an error for shard-read runs, which
    /// deliberately never hold one.
    pub fn dataset(&self) -> Result<&Arc<Dataset>> {
        match &self.data {
            RunData::Full(ds) => Ok(ds),
            RunData::ShardFile { .. } => bail!(
                "this run reads per-worker shards; no full dataset is \
                 materialized"
            ),
        }
    }

    /// Pin the run's column oracle on the caller's stack (see module
    /// docs for why this is a separate step).
    pub fn oracle_slot(&self) -> OracleSlot<'_> {
        match &self.data {
            RunData::Full(ds) => {
                OracleSlot(Some(ImplicitOracle::new(ds, &*self.kernel)))
            }
            RunData::ShardFile { .. } => OracleSlot(None),
        }
    }

    fn need_oracle<'a, 'o>(
        &self,
        slot: &'a OracleSlot<'o>,
    ) -> Result<&'a ImplicitOracle<'o>> {
        slot.get().ok_or_else(|| {
            anyhow!(
                "method '{}' needs the materialized dataset (shard_reads \
                 applies to oasis-p only)",
                self.method.method.as_str()
            )
        })
    }

    /// Open the spec's stepwise session: dispatches the method, applies
    /// any warm start, and boxes the result behind [`SamplerSession`].
    /// One-shot methods (`random`/`leverage`/`kmeans`) error here — run
    /// them through [`one_shot`](ResolvedRun::one_shot).
    pub fn open_session<'a, 'o>(
        &self,
        slot: &'a OracleSlot<'o>,
    ) -> Result<Box<dyn SamplerSession + 'a>> {
        let m = &self.method;
        if let Some(w) = &self.warm {
            // resolve() restricts warm starts to the oasis/sis methods
            let oracle = self.need_oracle(slot)?;
            let wrap = |e: crate::error::Error| {
                e.wrap(format!("warm start from '{}'", w.label))
            };
            return Ok(match m.method {
                Method::Oasis => boxed(
                    Oasis::new(m.max_cols, m.init_cols, m.tol, m.seed)
                        .session_from_indices(oracle, &w.indices)
                        .map_err(wrap)?,
                ),
                Method::Sis => boxed(
                    Sis::new(m.max_cols, m.init_cols, m.tol, m.seed)
                        .session_from_indices(oracle, &w.indices)
                        .map_err(wrap)?,
                ),
                other => bail!(
                    "warm_start resumes the 'oasis' and 'sis' methods only \
                     (got '{}')",
                    other.as_str()
                ),
            });
        }
        Ok(match m.method {
            Method::Oasis => boxed(
                Oasis::new(m.max_cols, m.init_cols, m.tol, m.seed)
                    .session(self.need_oracle(slot)?)?,
            ),
            Method::Sis => boxed(
                Sis::new(m.max_cols, m.init_cols, m.tol, m.seed)
                    .session(self.need_oracle(slot)?)?,
            ),
            Method::Farahat => {
                boxed(Farahat::new(m.max_cols).session(self.need_oracle(slot)?)?)
            }
            Method::Icd => boxed(
                IncompleteCholesky::new(m.max_cols, m.tol)
                    .session(self.need_oracle(slot)?)?,
            ),
            Method::AdaptiveRandom => boxed(
                AdaptiveRandom::new(m.max_cols, m.batch, m.seed)
                    .session(self.need_oracle(slot)?)?,
            ),
            Method::OasisP => boxed(self.open_oasis_p()?),
            Method::Uniform | Method::Leverage | Method::Kmeans => bail!(
                "method '{}' has no stepwise session — run it with one_shot",
                m.method.as_str()
            ),
        })
    }

    /// Open the distributed session with its concrete type (the CLI
    /// needs [`OasisPSession::finish_run`]'s report; the server is happy
    /// with the boxed trait object from
    /// [`open_session`](ResolvedRun::open_session)).
    pub fn open_oasis_p(&self) -> Result<OasisPSession> {
        if let Some(addr) = &self.method.listen {
            let transport = TcpTransport::bind(addr)?;
            return self.open_oasis_p_with(Box::new(transport));
        }
        let (cfg, plan) = self.oasis_p_run()?;
        match (&self.data, plan) {
            (RunData::Full(ds), _) => {
                OasisPSession::start(ds, self.kernel.clone(), cfg)
            }
            (_, Some(plan)) => {
                OasisPSession::start_with_plan(plan, self.kernel.clone(), cfg)
            }
            _ => unreachable!("shard runs always have a file plan"),
        }
    }

    /// Open the distributed session over an explicit [`Transport`] —
    /// the CLI binds a [`TcpTransport`] itself so it can print the
    /// join address *before* blocking in the worker accept loop.
    /// TCP fleets need shard reads (a file plan): the worker processes
    /// read the dataset themselves.
    pub fn open_oasis_p_with(
        &self,
        transport: Box<dyn Transport>,
    ) -> Result<OasisPSession> {
        let (cfg, plan) = self.oasis_p_run()?;
        let plan = plan.ok_or_else(|| {
            crate::anyhow!(
                "a TCP worker fleet needs --shard-reads with a binary file \
                 dataset (worker processes read their own shards)"
            )
        })?;
        OasisPSession::start_with_transport(
            transport,
            plan,
            self.kernel.clone(),
            cfg,
        )
    }

    /// Shared oASIS-P config/plan derivation. The plan is `None` for
    /// in-memory (non-shard-read) runs.
    fn oasis_p_run(&self) -> Result<(OasisPConfig, Option<ShardPlan>)> {
        let m = &self.method;
        if m.method != Method::OasisP {
            bail!("open_oasis_p called on method '{}'", m.method.as_str());
        }
        let cfg = OasisPConfig::new(m.max_cols, m.init_cols, m.workers)
            .with_seed(m.seed)
            .with_tol(m.tol)
            .with_merge_batch(m.merge_batch);
        let plan = match &self.data {
            RunData::Full(_) => None,
            RunData::ShardFile { path, n, .. } => Some(ShardPlan::File {
                path: path.clone(),
                n: *n,
                limits: self.limits,
            }),
        };
        Ok((cfg, plan))
    }

    /// Run one of the one-shot methods (`random`/`leverage`/`kmeans`) to
    /// its column budget and assemble the approximation.
    pub fn one_shot(&self, slot: &OracleSlot<'_>) -> Result<NystromApprox> {
        let m = &self.method;
        let oracle = self.need_oracle(slot)?;
        match m.method {
            Method::Uniform => Uniform::new(m.max_cols, m.seed).sample(oracle),
            Method::Leverage => {
                LeverageScores::new(m.max_cols, m.max_cols, m.seed).sample(oracle)
            }
            Method::Kmeans => {
                let ds = self.dataset()?;
                KMeansNystrom::new(ds, &*self.kernel, m.max_cols, m.seed)
                    .sample(oracle)
            }
            other => bail!(
                "method '{}' is stepwise — open it with open_session",
                other.as_str()
            ),
        }
    }

    /// Open the PJRT-accelerated oASIS session (the CLI's `--accel`
    /// path). Fails cleanly when no artifacts are available, on non-oasis
    /// methods, and on warm starts (the accelerated session has no replay
    /// path) — callers fall back to [`open_session`].
    pub fn open_accel_session<'a, 'o>(
        &self,
        accel: &'a mut Accel,
        slot: &'a OracleSlot<'o>,
    ) -> Result<Box<dyn SamplerSession + 'a>> {
        let m = &self.method;
        if m.method != Method::Oasis {
            bail!("--accel supports method 'oasis' only");
        }
        if self.warm.is_some() {
            bail!("the accelerated path has no warm start — drop --accel");
        }
        let oracle = self.need_oracle(slot)?;
        Ok(boxed(
            PjrtOasis::new(m.max_cols, m.init_cols, m.tol, m.seed)
                .session(accel, oracle)?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::run_to_completion;

    fn generator_spec(method: Method, n: usize, max_cols: usize) -> RunSpec {
        RunSpec {
            dataset: DatasetSpec::Generator {
                name: "two-moons".into(),
                n,
                seed: 42,
                noise: 0.05,
                dim: 0,
            },
            kernel: KernelSpec::Gaussian { sigma: None, sigma_fraction: 0.05 },
            method: MethodSpec {
                method,
                max_cols,
                init_cols: 5,
                tol: 1e-12,
                seed: 7,
                batch: 10,
                workers: 2,
                merge_batch: 1,
                listen: None,
            },
            stopping: super::super::spec::stopping_rule(max_cols, None, None),
            shard_reads: false,
            warm_start: None,
        }
    }

    // clamping, one-shot dispatch, warm-start validation, and shard-read
    // resolution are covered end to end in rust/tests/engine.rs; the
    // unit tests here keep only what that file does not exercise.

    #[test]
    fn open_session_steps_every_hosted_method() {
        for m in [
            Method::Oasis,
            Method::Sis,
            Method::Farahat,
            Method::Icd,
            Method::AdaptiveRandom,
            Method::OasisP,
        ] {
            let run = SessionBuilder::new()
                .resolve(generator_spec(m, 60, 12))
                .unwrap();
            let slot = run.oracle_slot();
            let mut s = run.open_session(&slot).unwrap();
            let reason = run_to_completion(s.as_mut(), &run.stopping).unwrap();
            assert!(s.k() >= 5, "{m:?} stopped at k = {} ({reason:?})", s.k());
            let snap = s.snapshot().unwrap();
            assert_eq!(snap.k(), s.k(), "{m:?}");
        }
    }

    #[test]
    fn shard_reads_validation() {
        // wrong method
        let mut spec = generator_spec(Method::Oasis, 40, 10);
        spec.shard_reads = true;
        let err = SessionBuilder::new().resolve(spec).unwrap_err();
        assert!(format!("{err}").contains("oasis-p"), "{err}");
        // right method, but no file dataset
        let mut spec = generator_spec(Method::OasisP, 40, 10);
        spec.shard_reads = true;
        let err = SessionBuilder::new().resolve(spec).unwrap_err();
        assert!(format!("{err}").contains("file dataset"), "{err}");
    }
}
