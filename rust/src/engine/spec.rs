//! The run pipeline *as data*: where the points come from, which kernel
//! evaluates them, which sampler selects columns, and when to stop — one
//! [`RunSpec`] that the CLI, the HTTP server, and the oASIS-P coordinator
//! all resolve through [`SessionBuilder`](super::SessionBuilder) instead
//! of hand-wiring dataset → kernel → oracle → session themselves.
//!
//! The wire format (`server::protocol`) parses JSON *into* these types;
//! the CLI builds them from flags; tests construct them directly. None
//! of the variants hold live resources — resolution (file loads,
//! generator runs, kernel σ estimation, artifact loads) happens in
//! [`SessionBuilder::resolve`](super::SessionBuilder::resolve).

use crate::data::{generators, loader, Dataset, LoadLimits};
use crate::kernels::{Gaussian, Kernel, Laplacian, Linear, Polynomial};
use crate::sampling::{StoppingCriterion, StoppingRule};
use crate::tasks::TaskKind;
use crate::Result;
use crate::{anyhow, bail};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// Where the run's data comes from.
#[derive(Clone, Debug)]
pub enum DatasetSpec {
    /// One of the crate's deterministic generators. `dim` is 0 for the
    /// generator's default dimensionality; `noise` applies to two-moons.
    Generator { name: String, n: usize, seed: u64, noise: f64, dim: usize },
    /// Points supplied inline (the server's request-body dataset).
    Points(Vec<Vec<f64>>),
    /// A CSV or binary `oasis-matrix` file on disk. `label` is the
    /// caller's spelling of the path (what provenance records — the
    /// serving layer must not leak its `--fs-root` resolution into
    /// artifacts or listings); `path` is where the bytes actually live.
    File { label: String, path: PathBuf },
}

impl DatasetSpec {
    /// Materialize the dataset, enforcing `limits` *while* it builds
    /// (generators are size-checked before allocating; file loads are
    /// capped during the parse). Consumes the spec so inline point rows
    /// move instead of being copied.
    pub fn build(self, limits: &LoadLimits) -> Result<Dataset> {
        Ok(match self {
            DatasetSpec::Points(rows) => {
                if rows.is_empty() || rows[0].is_empty() {
                    bail!("inline points must be a non-empty list of non-empty rows");
                }
                let (n, dim) = (rows.len(), rows[0].len());
                if let Some(i) = rows.iter().position(|r| r.len() != dim) {
                    bail!(
                        "inline point {i} has dimension {} but point 0 has {dim}",
                        rows[i].len()
                    );
                }
                limits.check_dim(dim)?;
                limits.check_n(n, dim)?;
                Dataset::from_rows(rows)
            }
            DatasetSpec::Generator { name, n, seed, noise, dim } => {
                let d = generators::dim_by_name(&name, dim)
                    .ok_or_else(|| anyhow!("unknown dataset generator '{name}'"))?;
                limits.check_dim(d)?;
                limits.check_n(n, d)?;
                generators::by_name(&name, n, dim, noise, seed)
                    .ok_or_else(|| anyhow!("unknown dataset generator '{name}'"))?
            }
            DatasetSpec::File { path, .. } => loader::load_dataset(&path, limits)?,
        })
    }

    /// Provenance line recorded with sessions and saved artifacts.
    pub fn describe(&self) -> String {
        match self {
            DatasetSpec::Generator { name, n, seed, dim, .. } => {
                if *dim == 0 {
                    format!("generator:{name}?n={n}&seed={seed}")
                } else {
                    format!("generator:{name}?n={n}&seed={seed}&dim={dim}")
                }
            }
            DatasetSpec::Points(rows) => format!("points:n={}", rows.len()),
            DatasetSpec::File { label, .. } => format!("file:{label}"),
        }
    }
}

/// Which kernel the run evaluates.
#[derive(Clone, Debug)]
pub enum KernelSpec {
    /// `sigma: None` resolves σ as `sigma_fraction` of the max pairwise
    /// distance — which requires the materialized dataset.
    Gaussian { sigma: Option<f64>, sigma_fraction: f64 },
    Linear,
    Laplacian { sigma: f64 },
    Polynomial { degree: u32, offset: f64 },
}

impl KernelSpec {
    /// Resolve against a materialized dataset (always succeeds).
    pub fn build(&self, ds: &Dataset) -> Arc<dyn Kernel + Send + Sync> {
        match self {
            KernelSpec::Gaussian { sigma: None, sigma_fraction } => {
                Arc::new(Gaussian::with_sigma_fraction(ds, *sigma_fraction))
            }
            other => other
                .build_resolved()
                .expect("only sigma_fraction needs the dataset"),
        }
    }

    /// Resolve without a dataset — `None` when the spec needs one (a
    /// Gaussian σ given as a fraction of the max pairwise distance).
    /// Shard-read runs, whose leader never materializes the dataset, can
    /// only use kernels that resolve this way.
    pub fn build_resolved(&self) -> Option<Arc<dyn Kernel + Send + Sync>> {
        Some(match self {
            KernelSpec::Gaussian { sigma: Some(s), .. } => {
                Arc::new(Gaussian::new(*s))
            }
            KernelSpec::Gaussian { sigma: None, .. } => return None,
            KernelSpec::Linear => Arc::new(Linear),
            KernelSpec::Laplacian { sigma } => Arc::new(Laplacian::new(*sigma)),
            KernelSpec::Polynomial { degree, offset } => {
                Arc::new(Polynomial { degree: *degree, offset: *offset })
            }
        })
    }
}

/// Every sampling method the crate ships, under its CLI/server spelling.
/// The first six run as stepwise
/// [`SamplerSession`](crate::sampling::SamplerSession)s (and are
/// hostable by the server); the last three are one-shot samplers driven
/// through [`ResolvedRun::one_shot`](super::ResolvedRun::one_shot).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Oasis,
    Sis,
    Farahat,
    Icd,
    AdaptiveRandom,
    OasisP,
    /// uniform random column sampling (spelled `random`).
    Uniform,
    /// ridge leverage-score sampling.
    Leverage,
    /// K-means Nyström (centroid landmarks, not matrix columns).
    Kmeans,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "oasis" => Method::Oasis,
            "sis" => Method::Sis,
            "farahat" => Method::Farahat,
            "icd" => Method::Icd,
            "adaptive-random" => Method::AdaptiveRandom,
            "oasis-p" => Method::OasisP,
            "random" => Method::Uniform,
            "leverage" => Method::Leverage,
            "kmeans" => Method::Kmeans,
            other => bail!(
                "unknown method '{other}' (expected oasis|sis|farahat|icd|\
                 adaptive-random|oasis-p|random|leverage|kmeans)"
            ),
        })
    }

    /// The canonical spelling [`parse`](Method::parse) accepts.
    pub fn as_str(self) -> &'static str {
        match self {
            Method::Oasis => "oasis",
            Method::Sis => "sis",
            Method::Farahat => "farahat",
            Method::Icd => "icd",
            Method::AdaptiveRandom => "adaptive-random",
            Method::OasisP => "oasis-p",
            Method::Uniform => "random",
            Method::Leverage => "leverage",
            Method::Kmeans => "kmeans",
        }
    }

    /// Does this method run as a stepwise session (vs one-shot)?
    pub fn has_session(self) -> bool {
        !matches!(self, Method::Uniform | Method::Leverage | Method::Kmeans)
    }
}

/// Sampler parameters. Fields a method does not use are ignored by it
/// (`batch` is adaptive-random's deflation batch; `workers`,
/// `merge_batch`, and `listen` are oASIS-P's).
#[derive(Clone, Debug)]
pub struct MethodSpec {
    pub method: Method,
    pub max_cols: usize,
    pub init_cols: usize,
    pub tol: f64,
    pub seed: u64,
    pub batch: usize,
    pub workers: usize,
    /// oASIS-P: SQUEAK-style merge width — picks applied per argmax
    /// gather round. 1 (the default) is the paper's exact protocol,
    /// bit-identical to the sequential sampler; larger batches trade
    /// selection-order exactness for fewer gather rounds.
    pub merge_batch: usize,
    /// oASIS-P: serve the worker fleet over TCP on this address
    /// (`HOST:PORT`) instead of spawning in-process threads. Workers are
    /// separate `oasis worker --join` processes; requires `shard_reads`
    /// (a binary file dataset) and a dataset-free kernel.
    pub listen: Option<String>,
}

/// A stored artifact whose selected indices Λ seed the run (selection
/// *resumes* from them instead of starting cold). `label` is the
/// caller's spelling for error messages and provenance; `path` is where
/// the artifact file lives.
#[derive(Clone, Debug)]
pub struct WarmStartSpec {
    pub label: String,
    pub path: PathBuf,
}

/// One full run, as data. Everything the CLI's `approximate`/`parallel`,
/// the server's `POST /sessions`, and the oASIS-P coordinator need to
/// build identical pipelines — same spec, bit-identical selection.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub dataset: DatasetSpec,
    pub kernel: KernelSpec,
    pub method: MethodSpec,
    /// Any-of stopping criteria for drivers that run the session to
    /// completion (the CLI). The server leaves this empty — its stopping
    /// rules arrive per step request. Column budgets are clamped to n at
    /// resolve time.
    pub stopping: StoppingRule,
    /// oASIS-P + binary file datasets only: each worker reads its own
    /// byte range of the file via `loader::load_shard`; the leader never
    /// materializes the dataset (Algorithm 2's distributed-data setting).
    pub shard_reads: bool,
    pub warm_start: Option<WarmStartSpec>,
}

/// Where a task's training labels come from: one or more columns of a
/// CSV or binary dataset file (one label per data point per column,
/// same row order as the training data). `label` is the caller's
/// spelling (for errors and provenance); `path` is where the bytes live
/// — the serving layer resolves it under `--fs-root` like every other
/// client path.
#[derive(Clone, Debug)]
pub struct LabelsSpec {
    pub label: String,
    pub path: PathBuf,
    /// Columns of the file to read labels from, in output order. One
    /// column is single-output KRR; several fit a multi-output model
    /// sharing one factorization.
    pub cols: Vec<usize>,
}

impl LabelsSpec {
    /// Parse the CLI/server column-list spelling: comma-separated
    /// indices and inclusive ranges (`"0"`, `"0,3"`, `"1-4,7"`). One
    /// shared parser so `--label-col` and the server's `label_cols`
    /// cannot drift.
    pub fn parse_cols(s: &str) -> Result<Vec<usize>> {
        let mut out = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                bail!("label columns: empty entry in '{s}'");
            }
            let parse_one = |t: &str| -> Result<usize> {
                t.parse().map_err(|_| {
                    anyhow!("label columns: '{t}' is not a column index")
                })
            };
            match part.split_once('-') {
                Some((a, b)) => {
                    let (lo, hi) = (parse_one(a.trim())?, parse_one(b.trim())?);
                    if hi < lo {
                        bail!("label columns: range '{part}' is reversed");
                    }
                    if hi - lo >= 1024 {
                        bail!("label columns: range '{part}' is implausibly wide");
                    }
                    out.extend(lo..=hi);
                }
                None => out.push(parse_one(part)?),
            }
        }
        if out.is_empty() {
            bail!("label columns: no columns in '{s}'");
        }
        Ok(out)
    }
}

/// A downstream task *as data* — which task, its parameters, and where
/// any labels come from. Resolved by
/// [`SessionBuilder::resolve_task`](super::SessionBuilder::resolve_task)
/// into a [`tasks::TaskConfig`](crate::tasks::TaskConfig) (labels
/// loaded, parameters validated), which then fits against any
/// approximation: a live session snapshot, a finished run, or a loaded
/// artifact — dataset-free in the artifact case. The CLI builds this
/// from `oasis task` flags; the server parses it from the task-endpoint
/// JSON; tests construct it directly.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub kind: TaskKind,
    /// Ridge λ (KRR).
    pub ridge: f64,
    /// Embedding dimensions (KPCA / cluster embedding).
    pub components: usize,
    /// Cluster count (cluster task).
    pub clusters: usize,
    /// K-means seeding RNG (cluster task).
    pub seed: u64,
    pub labels: Option<LabelsSpec>,
}

impl TaskSpec {
    /// A spec with the shared CLI/server defaults for `kind`. Callers
    /// that change `clusters` should also refresh `components` via
    /// [`TaskKind::default_components`] (the front ends do) — the
    /// cluster task defaults to one embedding dimension per cluster.
    pub fn new(kind: TaskKind) -> TaskSpec {
        TaskSpec {
            kind,
            ridge: 1e-3,
            components: 2,
            clusters: 2,
            seed: 7,
            labels: None,
        }
    }
}

/// The shared CLI/run-spec stopping rule: `target_err` and `deadline_ms`
/// are listed before the column budget so their reasons win the report
/// when several criteria hold at once.
pub fn stopping_rule(
    budget: usize,
    target_err: Option<f64>,
    deadline_ms: Option<u64>,
) -> StoppingRule {
    let mut rule = StoppingRule::new();
    if let Some(t) = target_err {
        rule = rule.with(StoppingCriterion::ErrorBelow(t));
    }
    if let Some(ms) = deadline_ms {
        rule = rule.with(StoppingCriterion::Deadline(Duration::from_millis(ms)));
    }
    rule.with(StoppingCriterion::ColumnBudget(budget))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_cols_parse_lists_and_ranges() {
        assert_eq!(LabelsSpec::parse_cols("0").unwrap(), vec![0]);
        assert_eq!(LabelsSpec::parse_cols("2, 0").unwrap(), vec![2, 0]);
        assert_eq!(
            LabelsSpec::parse_cols("1-4,7").unwrap(),
            vec![1, 2, 3, 4, 7]
        );
        assert!(LabelsSpec::parse_cols("").is_err());
        assert!(LabelsSpec::parse_cols("a").is_err());
        assert!(LabelsSpec::parse_cols("4-1").is_err());
        assert!(LabelsSpec::parse_cols("1,,2").is_err());
        assert!(LabelsSpec::parse_cols("0-99999").is_err());
    }

    #[test]
    fn method_spellings_round_trip() {
        for m in [
            Method::Oasis,
            Method::Sis,
            Method::Farahat,
            Method::Icd,
            Method::AdaptiveRandom,
            Method::OasisP,
            Method::Uniform,
            Method::Leverage,
            Method::Kmeans,
        ] {
            assert_eq!(Method::parse(m.as_str()).unwrap(), m);
        }
        assert!(Method::parse("magic").is_err());
    }

    #[test]
    fn session_methods_classified() {
        assert!(Method::Oasis.has_session());
        assert!(Method::OasisP.has_session());
        assert!(!Method::Uniform.has_session());
        assert!(!Method::Kmeans.has_session());
    }

    #[test]
    fn generator_spec_builds_and_describes() {
        let spec = DatasetSpec::Generator {
            name: "two-moons".into(),
            n: 40,
            seed: 3,
            noise: 0.05,
            dim: 0,
        };
        assert_eq!(spec.describe(), "generator:two-moons?n=40&seed=3");
        let ds = spec.build(&LoadLimits::unlimited()).unwrap();
        assert_eq!((ds.n(), ds.dim()), (40, 2));
        let bad = DatasetSpec::Generator {
            name: "nope".into(),
            n: 10,
            seed: 0,
            noise: 0.0,
            dim: 0,
        };
        assert!(bad.build(&LoadLimits::unlimited()).is_err());
    }

    #[test]
    fn generator_caps_checked_before_allocation() {
        let spec = DatasetSpec::Generator {
            name: "mnist".into(),
            n: 1000,
            seed: 1,
            noise: 0.0,
            dim: 0,
        };
        let tight =
            LoadLimits { max_n: 1000, max_dim: 1024, max_elems: 100_000 };
        // 1000 × 784 elems exceeds the cap; dim 784 is under max_dim
        assert!(spec.build(&tight).is_err());
    }

    #[test]
    fn kernel_resolution_with_and_without_dataset() {
        let frac = KernelSpec::Gaussian { sigma: None, sigma_fraction: 0.05 };
        assert!(frac.build_resolved().is_none());
        let fixed = KernelSpec::Gaussian { sigma: Some(0.7), sigma_fraction: 0.05 };
        assert_eq!(fixed.build_resolved().unwrap().name(), "gaussian");
        assert_eq!(KernelSpec::Linear.build_resolved().unwrap().name(), "linear");
    }

    #[test]
    fn stopping_rule_orders_criteria() {
        let rule = stopping_rule(40, Some(0.1), Some(500));
        assert_eq!(
            rule.criteria(),
            &[
                StoppingCriterion::ErrorBelow(0.1),
                StoppingCriterion::Deadline(Duration::from_millis(500)),
                StoppingCriterion::ColumnBudget(40),
            ]
        );
        let bare = stopping_rule(10, None, None);
        assert_eq!(bare.criteria(), &[StoppingCriterion::ColumnBudget(10)]);
    }
}
