//! Support substrates built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, timing/statistics, table rendering, a
//! property-testing harness, and scoped-thread parallel helpers.
//!
//! These replace crates.io dependencies (rand, serde_json, clap, criterion,
//! proptest, rayon) that are unavailable in this container — see
//! DESIGN.md §6 (Substitutions).

pub mod args;
pub mod framing;
pub mod fsio;
pub mod json;
pub mod parallel;
pub mod propcheck;
pub mod rng;
pub mod table;
pub mod timing;
