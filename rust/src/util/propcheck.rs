//! Minimal property-testing harness (proptest substitute).
//!
//! Runs a property over many randomly generated cases with an explicit
//! deterministic seed; on failure it reports the case index and the seed so
//! the exact case can be replayed. Generation helpers cover the shapes the
//! library's invariants need (sizes, ranks, PSD matrices, datasets).
//!
//! Shrinking is intentionally simple: cases are generated smallest-first on
//! a size ramp, so the first failure is already near-minimal.

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// size ramp: case i gets `size = min_size + (max_size-min_size)*i/cases`
    pub min_size: usize,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0x0A51_5517, min_size: 1, max_size: 64 }
    }
}

/// Per-case context handed to the property.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg64,
    /// current point on the size ramp
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    /// A size-ramped dimension in [1, size].
    pub fn dim(&mut self) -> usize {
        self.usize_in(1, self.size.max(1))
    }

    /// Random normal vector.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v);
        v
    }

    /// Random rank-`r` PSD matrix G = Xᵀ X (n×n, row-major) built from an
    /// r×n factor. Returns (g, r_effective).
    pub fn psd_matrix(&mut self, n: usize, r: usize) -> Vec<f64> {
        let x = self.normal_vec(r * n); // r×n
        let mut g = vec![0.0; n * n];
        for i in 0..n {
            for j in i..n {
                let mut s = 0.0;
                for k in 0..r {
                    s += x[k * n + i] * x[k * n + j];
                }
                g[i * n + j] = s;
                g[j * n + i] = s;
            }
        }
        g
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct Failure {
    pub case: usize,
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (seed={:#x}, size={}): {}",
            self.case, self.seed, self.size, self.message
        )
    }
}

/// Run `prop` over `config.cases` generated cases. The property returns
/// `Err(message)` to signal failure. Panics (like proptest) with a
/// replayable report on the first failure.
pub fn check<F>(config: Config, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    if let Some(fail) = check_quiet(&config, &prop) {
        panic!("{fail}");
    }
}

/// Non-panicking variant for meta-testing the harness itself.
pub fn check_quiet<F>(config: &Config, prop: &F) -> Option<Failure>
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..config.cases {
        // Deterministic per-case stream → replayable independently.
        let case_seed = config.seed ^ ((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Pcg64::new(case_seed);
        let ramp = if config.cases > 1 {
            config.min_size
                + (config.max_size - config.min_size) * case / (config.cases - 1)
        } else {
            config.max_size
        };
        let mut g = Gen { rng: &mut rng, size: ramp };
        if let Err(message) = prop(&mut g) {
            return Some(Failure { case, seed: case_seed, size: ramp, message });
        }
    }
    None
}

/// Assert two floats are close; returns an Err for use inside properties.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(Config::default(), |g| {
            let n = g.dim();
            let v = g.normal_vec(n);
            if v.len() == n {
                Ok(())
            } else {
                Err("len mismatch".into())
            }
        });
    }

    #[test]
    fn reports_failure_with_small_size_first() {
        let cfg = Config { cases: 50, min_size: 1, max_size: 100, ..Default::default() };
        let fail = check_quiet(&cfg, &|g: &mut Gen| {
            if g.size > 40 {
                Err("too big".into())
            } else {
                Ok(())
            }
        })
        .expect("must fail");
        // ramped generation ⇒ failing size is just past the threshold
        assert!(fail.size > 40 && fail.size <= 45, "size {}", fail.size);
    }

    #[test]
    fn psd_matrix_is_symmetric_psd() {
        check(Config { cases: 16, max_size: 12, ..Default::default() }, |g| {
            let n = g.dim().max(2);
            let r = g.usize_in(1, n);
            let m = g.psd_matrix(n, r);
            for i in 0..n {
                for j in 0..n {
                    if (m[i * n + j] - m[j * n + i]).abs() > 1e-12 {
                        return Err("not symmetric".into());
                    }
                }
                if m[i * n + i] < -1e-12 {
                    return Err("negative diagonal".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn close_scales() {
        assert!(close(1e9, 1e9 + 1.0, 1e-6, "x").is_ok());
        assert!(close(1.0, 1.1, 1e-6, "x").is_err());
    }
}
