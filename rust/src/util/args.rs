//! Tiny CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! typed access with defaults. Used by the `oasis` binary and the bench
//! drivers.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// option keys in the order they were consumed (for usage errors)
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.known.borrow_mut().push(name.to_string());
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        match self.get(name) {
            None => default,
            Some(v) => v
                .replace('_', "")
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.usize_or(name, default as usize) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("approximate --n 2000 --method oasis two-moons");
        assert_eq!(a.positional, vec!["approximate", "two-moons"]);
        assert_eq!(a.get("n"), Some("2000"));
        assert_eq!(a.get_or("method", "x"), "oasis");
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("--cols=450 --accel --sigma-frac=0.05");
        assert_eq!(a.usize_or("cols", 0), 450);
        assert!(a.flag("accel"));
        assert!(!a.flag("verbose"));
        assert!((a.f64_or("sigma-frac", 0.0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("tol", 1e-8), 1e-8);
        assert_eq!(a.get_or("kernel", "gaussian"), "gaussian");
    }

    #[test]
    fn underscored_integers() {
        let a = parse("--n 1_000_000");
        assert_eq!(a.usize_or("n", 0), 1_000_000);
    }

    #[test]
    fn negative_numbers_as_values() {
        // `--key value` where value starts with '-' but not '--'
        let a = parse("--shift -3.5");
        assert_eq!(a.f64_or("shift", 0.0), -3.5);
    }
}
