//! Wall-clock timing and summary statistics for the bench harness and the
//! coordinator metrics.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.secs())
}

/// Summary statistics over a sample of measurements. Non-finite samples
/// (NaN, ±∞ — e.g. a bench rep that divided by a zero elapsed count)
/// are excluded from every statistic and reported in `skipped`.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// finite samples the statistics cover.
    pub n: usize,
    /// non-finite samples excluded from the statistics.
    pub skipped: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let mut sorted: Vec<f64> =
            xs.iter().copied().filter(|x| x.is_finite()).collect();
        let skipped = xs.len() - sorted.len();
        if sorted.is_empty() {
            // every sample was NaN/∞: keep the contract total-order safe
            // instead of panicking mid-bench
            return Summary {
                n: 0,
                skipped,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                median: f64::NAN,
                max: f64::NAN,
            };
        }
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        sorted.sort_by(f64::total_cmp);
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            skipped,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median,
            max: sorted[n - 1],
        }
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Format a byte count human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u + 1 < UNITS.len() {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{x:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[3.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.skipped, 0);
    }

    #[test]
    fn summary_skips_non_finite_instead_of_panicking() {
        // regression: sort_by(partial_cmp().unwrap()) panicked on NaN
        let s = Summary::of(&[2.0, f64::NAN, 1.0, f64::INFINITY, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.skipped, 2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        // an all-bad sample reports NaN stats rather than panicking
        let bad = Summary::of(&[f64::NAN, f64::NEG_INFINITY]);
        assert_eq!(bad.n, 0);
        assert_eq!(bad.skipped, 2);
        assert!(bad.median.is_nan());
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.5e-6 * 2.0), "1.0µs");
        assert_eq!(fmt_secs(0.25), "250.00ms");
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.secs() >= 0.002);
    }
}
