//! Scoped-thread data-parallel helpers (rayon substitute).
//!
//! The hot loops of the library (kernel column generation, Δ scoring over
//! large n, error estimation) are chunked over OS threads with
//! `std::thread::scope`; there is no work stealing, which is fine for the
//! regular, evenly-sized loops used here.

/// Number of worker threads to use by default (capped — this container's
/// benches are noise-dominated past 8).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get().min(8))
        .unwrap_or(4)
}

/// Split `n` items into at most `threads` contiguous ranges of near-equal
/// size. Returns an empty vec when `n == 0`.
pub fn chunk_ranges(n: usize, threads: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return vec![];
    }
    let t = threads.max(1).min(n);
    let base = n / t;
    let rem = n % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f(range, chunk)` over disjoint mutable chunks of `data`, where the
/// chunk boundaries are item ranges of size `stride` elements each (i.e.
/// `data.len() == n * stride`). Single-threaded when `threads <= 1` or the
/// work is tiny.
pub fn for_each_chunk_mut<T: Send, F>(
    data: &mut [T],
    stride: usize,
    threads: usize,
    f: F,
) where
    F: Fn(std::ops::Range<usize>, &mut [T]) + Sync,
{
    assert!(stride > 0 && data.len() % stride == 0);
    let n = data.len() / stride;
    let ranges = chunk_ranges(n, threads);
    if ranges.len() <= 1 {
        f(0..n, data);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = data;
        for r in ranges {
            let (chunk, tail) = rest.split_at_mut((r.end - r.start) * stride);
            rest = tail;
            let f = &f;
            scope.spawn(move || f(r, chunk));
        }
    });
}

/// Run `f(range, chunk_a, chunk_b)` over two same-length slices split at
/// identical item boundaries (stride 1), so each invocation sees the
/// matching windows `a[range]` and `b[range]`. This is the fused-sweep
/// shape: produce into `a` and immediately consume against `b` while the
/// chunk is cache-hot, without the second full pass a separate
/// [`for_each_chunk_mut`] call would make.
pub fn for_each_chunk_mut2<A: Send, B: Send, F>(
    a: &mut [A],
    b: &mut [B],
    threads: usize,
    f: F,
) where
    F: Fn(std::ops::Range<usize>, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), b.len(), "for_each_chunk_mut2: slice lengths differ");
    let n = a.len();
    let ranges = chunk_ranges(n, threads);
    if ranges.len() <= 1 {
        f(0..n, a, b);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest_a = a;
        let mut rest_b = b;
        for r in ranges {
            let (chunk_a, tail_a) = rest_a.split_at_mut(r.end - r.start);
            let (chunk_b, tail_b) = rest_b.split_at_mut(r.end - r.start);
            rest_a = tail_a;
            rest_b = tail_b;
            let f = &f;
            scope.spawn(move || f(r, chunk_a, chunk_b));
        }
    });
}

/// Map each range of `0..n` on its own thread and collect results in order.
pub fn map_ranges<R: Send, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    F: Fn(std::ops::Range<usize>) -> R + Sync,
{
    let ranges = chunk_ranges(n, threads);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&f).collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| {
                let f = &f;
                scope.spawn(move || f(r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_exactly() {
        for n in [0usize, 1, 7, 64, 1000] {
            for t in [1usize, 2, 3, 8, 64] {
                let rs = chunk_ranges(n, t);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    next = r.end;
                    assert!(!r.is_empty());
                }
            }
        }
    }

    #[test]
    fn for_each_chunk_mut_writes_all() {
        let mut data = vec![0usize; 30];
        for_each_chunk_mut(&mut data, 3, 4, |range, chunk| {
            for (i, item) in range.clone().enumerate() {
                for j in 0..3 {
                    chunk[i * 3 + j] = item * 10 + j;
                }
            }
        });
        for item in 0..10 {
            for j in 0..3 {
                assert_eq!(data[item * 3 + j], item * 10 + j);
            }
        }
    }

    #[test]
    fn for_each_chunk_mut2_pairs_windows() {
        for (n, t) in [(0usize, 1usize), (1, 4), (17, 3), (64, 8)] {
            let mut a = vec![0usize; n];
            let mut b = vec![0usize; n];
            for_each_chunk_mut2(&mut a, &mut b, t, |range, ca, cb| {
                for (local, i) in range.clone().enumerate() {
                    ca[local] = i * 2;
                    cb[local] = ca[local] + 1;
                }
            });
            for i in 0..n {
                assert_eq!(a[i], i * 2);
                assert_eq!(b[i], i * 2 + 1);
            }
        }
    }

    #[test]
    fn map_ranges_ordered() {
        let sums = map_ranges(100, 7, |r| r.sum::<usize>());
        assert_eq!(sums.iter().sum::<usize>(), 4950);
    }
}
