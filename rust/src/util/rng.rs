//! Deterministic PRNG: PCG64 (O'Neill) plus the distribution helpers the
//! library needs (uniform, normal, shuffling, sampling without replacement).
//!
//! Substitute for the unavailable `rand` crate. Every stochastic component
//! of the library (dataset generators, uniform sampler, k-means init,
//! property harness) threads an explicit seed through this type, so all
//! experiments are reproducible bit-for-bit.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id (used to derive
    /// independent per-worker generators from one master seed).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive a child generator (for parallel workers / sub-tasks).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::with_stream(self.next_u64() ^ tag, tag.wrapping_mul(2) | 1)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let threshold = n.wrapping_neg() % n;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; generators here are not on the request hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices drawn uniformly from [0, n) (partial Fisher–Yates).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // For small k relative to n use a set-based draw; otherwise shuffle.
        if k * 8 < n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let c = self.below(n);
                if seen.insert(c) {
                    out.push(c);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }

    /// Draw an index from an (unnormalized, non-negative) weight vector.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: all weights zero");
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg64::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Pcg64::new(6);
        for &(n, k) in &[(10usize, 10usize), (100, 7), (50, 49), (1, 1)] {
            let s = r.sample_without_replacement(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Pcg64::new(7);
        let w = [0.0, 0.0, 5.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted_index(&w), 2);
        }
        let w = [1.0, 3.0];
        let mut counts = [0usize; 2];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        let frac = counts[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(8);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
