//! Little-endian binary framing and checksumming for the crate's
//! on-disk formats (the approximation artifact store in
//! [`crate::nystrom::store`] and the binary matrix files read by
//! [`crate::data::loader`]).
//!
//! Both formats share one layout: an ASCII magic line, one line of JSON
//! header, then a binary payload of framed f64 sections. Each section is
//! `[u64 LE element count][count × f64 LE]`, and the header carries the
//! total payload byte count plus an FNV-1a 64 checksum of the payload so
//! truncation and corruption are detected before any numbers are trusted.
//! Everything here is dependency-free (tier-1 builds offline).

use crate::Result;
use crate::{anyhow, bail};

/// FNV-1a 64-bit hash — the store's integrity checksum. Not
/// cryptographic; it exists to catch truncation, bit rot, and partial
/// writes, and round-trips through the JSON header as a fixed-width hex
/// string (u64 does not survive an f64 JSON number above 2^53).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Render a checksum the way headers store it (16 hex digits).
pub fn checksum_hex(h: u64) -> String {
    format!("{h:016x}")
}

/// Parse a header checksum rendered by [`checksum_hex`].
pub fn parse_checksum_hex(s: &str) -> Result<u64> {
    if s.len() != 16 {
        bail!("checksum must be 16 hex digits, got {:?}", s);
    }
    u64::from_str_radix(s, 16).map_err(|_| anyhow!("bad checksum {s:?}"))
}

/// Append one framed f64 section: `[u64 LE count][count × f64 LE]`.
pub fn push_f64_section(out: &mut Vec<u8>, xs: &[f64]) {
    out.reserve(8 + xs.len() * 8);
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append one framed **f32** section: `[u64 LE count][count × f32 LE]`,
/// narrowing each value with an `as f32` cast — the artifact store's
/// compact payload encoding (`encoding: "f32"`; lossy, ~half the bytes).
pub fn push_f32_section(out: &mut Vec<u8>, xs: &[f64]) {
    out.reserve(8 + xs.len() * 4);
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&(x as f32).to_le_bytes());
    }
}

/// Sequential reader over a framed payload.
pub struct SectionReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> SectionReader<'a> {
    pub fn new(payload: &'a [u8]) -> SectionReader<'a> {
        SectionReader { b: payload, i: 0 }
    }

    /// Bytes left unread (0 once every section was consumed).
    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated payload: {what} needs {n} bytes, {} left",
                self.remaining()
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    /// Read one framed f64 section, checking the frame's element count
    /// against `expect` (what the header's dimensions imply).
    pub fn read_f64_section(&mut self, expect: usize, what: &str) -> Result<Vec<f64>> {
        let len_bytes = self.take(8, what)?;
        let len = u64::from_le_bytes(len_bytes.try_into().unwrap());
        if len != expect as u64 {
            bail!("{what}: frame holds {len} values but the header implies {expect}");
        }
        let raw = self.take(expect * 8, what)?;
        let mut out = Vec::with_capacity(expect);
        for chunk in raw.chunks_exact(8) {
            out.push(f64::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(out)
    }

    /// Read one framed **f32** section written by [`push_f32_section`],
    /// widening each value back to f64 (exact — every f32 is an f64).
    pub fn read_f32_section(&mut self, expect: usize, what: &str) -> Result<Vec<f64>> {
        let len_bytes = self.take(8, what)?;
        let len = u64::from_le_bytes(len_bytes.try_into().unwrap());
        if len != expect as u64 {
            bail!("{what}: frame holds {len} values but the header implies {expect}");
        }
        let raw = self.take(expect * 4, what)?;
        let mut out = Vec::with_capacity(expect);
        for chunk in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(chunk.try_into().unwrap()) as f64);
        }
        Ok(out)
    }
}

/// Split a magic-line file into its JSON header line and binary payload:
/// `<magic>\n<json header>\n<payload>`. The magic must include its
/// trailing newline. Returns `(header_str, payload_bytes)`.
pub fn split_magic_file<'a>(
    bytes: &'a [u8],
    magic: &[u8],
    what: &str,
) -> Result<(&'a str, &'a [u8])> {
    if !bytes.starts_with(magic) {
        bail!("not a {what} file (bad magic)");
    }
    let rest = &bytes[magic.len()..];
    let nl = rest
        .iter()
        .position(|&b| b == b'\n')
        .ok_or_else(|| anyhow!("{what}: truncated before the header line ended"))?;
    let header = std::str::from_utf8(&rest[..nl])
        .map_err(|_| anyhow!("{what}: header is not UTF-8"))?;
    Ok((header, &rest[nl + 1..]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn checksum_hex_round_trips() {
        for h in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(parse_checksum_hex(&checksum_hex(h)).unwrap(), h);
        }
        assert!(parse_checksum_hex("xyz").is_err());
        assert!(parse_checksum_hex("0123").is_err());
    }

    #[test]
    fn f64_sections_round_trip_bit_exactly() {
        let a = vec![0.1, -0.0, 1.0 / 3.0, f64::MAX, 5e-324];
        let b = vec![42.0; 3];
        let mut payload = Vec::new();
        push_f64_section(&mut payload, &a);
        push_f64_section(&mut payload, &b);
        let mut r = SectionReader::new(&payload);
        let ra = r.read_f64_section(a.len(), "a").unwrap();
        let rb = r.read_f64_section(b.len(), "b").unwrap();
        assert_eq!(r.remaining(), 0);
        for (x, y) in a.iter().zip(&ra) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(rb, b);
    }

    #[test]
    fn f32_sections_round_trip_at_f32_precision() {
        let a = vec![0.1, -0.0, 1.0 / 3.0, 2.5];
        let mut payload = Vec::new();
        push_f32_section(&mut payload, &a);
        assert_eq!(payload.len(), 8 + 4 * a.len());
        let mut r = SectionReader::new(&payload);
        let back = r.read_f32_section(a.len(), "a").unwrap();
        assert_eq!(r.remaining(), 0);
        for (x, y) in a.iter().zip(&back) {
            // exact round trip of the f32 cast (f32 → f64 is lossless)
            assert_eq!((*x as f32) as f64, *y);
        }
        // -0.0 keeps its sign through the narrow-widen pair
        assert!(back[1] == 0.0 && back[1].is_sign_negative());
        // truncation and frame/header disagreement still error
        let cut = &payload[..payload.len() - 2];
        assert!(SectionReader::new(cut).read_f32_section(4, "a").is_err());
        assert!(SectionReader::new(&payload).read_f32_section(5, "a").is_err());
    }

    #[test]
    fn truncated_and_miscounted_sections_error() {
        let mut payload = Vec::new();
        push_f64_section(&mut payload, &[1.0, 2.0, 3.0]);
        // truncation mid-section
        let cut = &payload[..payload.len() - 4];
        assert!(SectionReader::new(cut).read_f64_section(3, "x").is_err());
        // header/frame disagreement
        assert!(SectionReader::new(&payload).read_f64_section(4, "x").is_err());
        // empty payload
        assert!(SectionReader::new(&[]).read_f64_section(1, "x").is_err());
    }

    #[test]
    fn magic_split() {
        let file = b"magic\n{\"v\":1}\n\x01\x02";
        let (h, p) = split_magic_file(file, b"magic\n", "test").unwrap();
        assert_eq!(h, "{\"v\":1}");
        assert_eq!(p, b"\x01\x02");
        assert!(split_magic_file(b"other\nx", b"magic\n", "test").is_err());
        assert!(split_magic_file(b"magic\nno newline", b"magic\n", "test").is_err());
    }
}
