//! Little-endian binary framing and checksumming for the crate's
//! on-disk formats (the approximation artifact store in
//! [`crate::nystrom::store`] and the binary matrix files read by
//! [`crate::data::loader`]) and for the coordinator's TCP wire protocol
//! ([`crate::coordinator::net`]).
//!
//! The on-disk formats share one layout: an ASCII magic line, one line
//! of JSON header, then a binary payload of framed f64 sections. Each
//! section is `[u64 LE element count][count × f64 LE]`, and the header
//! carries the total payload byte count plus an FNV-1a 64 checksum of
//! the payload so truncation and corruption are detected before any
//! numbers are trusted.
//!
//! The wire protocol uses checksummed stream frames
//! ([`write_frame`]/[`read_frame`]): `[u64 LE payload length][u64 LE
//! FNV-1a 64 of payload][payload]`. A reader bounds every frame with a
//! caller-supplied size cap, so a corrupt or hostile length prefix is a
//! clean error instead of an unbounded allocation, and every failure
//! mode — truncation inside the header, truncation inside the payload,
//! checksum mismatch, oversize — surfaces as `Err`, never a panic. EOF
//! *between* frames is the one non-error end: `Ok(None)`.
//! Everything here is dependency-free (tier-1 builds offline).

use crate::Result;
use crate::{anyhow, bail};
use std::io::{Read, Write};

/// FNV-1a 64-bit hash — the store's integrity checksum. Not
/// cryptographic; it exists to catch truncation, bit rot, and partial
/// writes, and round-trips through the JSON header as a fixed-width hex
/// string (u64 does not survive an f64 JSON number above 2^53).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Render a checksum the way headers store it (16 hex digits).
pub fn checksum_hex(h: u64) -> String {
    format!("{h:016x}")
}

/// Parse a header checksum rendered by [`checksum_hex`].
pub fn parse_checksum_hex(s: &str) -> Result<u64> {
    if s.len() != 16 {
        bail!("checksum must be 16 hex digits, got {:?}", s);
    }
    u64::from_str_radix(s, 16).map_err(|_| anyhow!("bad checksum {s:?}"))
}

/// Append one framed f64 section: `[u64 LE count][count × f64 LE]`.
pub fn push_f64_section(out: &mut Vec<u8>, xs: &[f64]) {
    out.reserve(8 + xs.len() * 8);
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append one framed **f32** section: `[u64 LE count][count × f32 LE]`,
/// narrowing each value with an `as f32` cast — the artifact store's
/// compact payload encoding (`encoding: "f32"`; lossy, ~half the bytes).
pub fn push_f32_section(out: &mut Vec<u8>, xs: &[f64]) {
    out.reserve(8 + xs.len() * 4);
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&(x as f32).to_le_bytes());
    }
}

/// Write one checksummed stream frame:
/// `[u64 LE payload length][u64 LE fnv1a64(payload)][payload]`.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    let mut head = [0u8; 16];
    head[..8].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    head[8..].copy_from_slice(&fnv1a64(payload).to_le_bytes());
    w.write_all(&head)
        .map_err(|e| anyhow!("writing frame header: {e}"))?;
    w.write_all(payload)
        .map_err(|e| anyhow!("writing frame payload: {e}"))?;
    Ok(())
}

/// Read one frame written by [`write_frame`], verifying the checksum.
///
/// Returns `Ok(None)` on EOF at a frame boundary (the peer closed the
/// stream cleanly). Every mid-frame failure is an error with a specific
/// message: EOF inside the 16-byte header or inside the payload
/// ("truncated frame"), a length prefix above `max_bytes` ("oversized
/// frame" — refused *before* allocating), or a payload that does not
/// hash to the header's checksum ("corrupt frame").
pub fn read_frame<R: Read>(r: &mut R, max_bytes: u64) -> Result<Option<Vec<u8>>> {
    let mut head = [0u8; 16];
    let mut got = 0usize;
    while got < head.len() {
        match r.read(&mut head[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                bail!(
                    "truncated frame: EOF after {got} of the 16 header bytes"
                );
            }
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow!("reading frame header: {e}")),
        }
    }
    let len = u64::from_le_bytes(head[..8].try_into().unwrap());
    let sum = u64::from_le_bytes(head[8..].try_into().unwrap());
    if len > max_bytes {
        bail!("oversized frame: {len} bytes exceeds the cap of {max_bytes}");
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(|e| {
        anyhow!("truncated frame: expected {len} payload bytes: {e}")
    })?;
    let computed = fnv1a64(&payload);
    if computed != sum {
        bail!(
            "corrupt frame: payload hashes to {} but the header says {}",
            checksum_hex(computed),
            checksum_hex(sum)
        );
    }
    Ok(Some(payload))
}

/// Sequential reader over a framed payload.
pub struct SectionReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> SectionReader<'a> {
    pub fn new(payload: &'a [u8]) -> SectionReader<'a> {
        SectionReader { b: payload, i: 0 }
    }

    /// Bytes left unread (0 once every section was consumed).
    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated payload: {what} needs {n} bytes, {} left",
                self.remaining()
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    /// `expect × width` with overflow checking — a crafted element count
    /// near `usize::MAX` must be a clean error, not a wrapped-to-small
    /// byte count that silently mis-frames the rest of the payload.
    fn take_elems(
        &mut self,
        expect: usize,
        width: usize,
        what: &str,
    ) -> Result<&'a [u8]> {
        let bytes = expect.checked_mul(width).ok_or_else(|| {
            anyhow!("{what}: element count {expect} overflows the payload size")
        })?;
        self.take(bytes, what)
    }

    /// Read one framed f64 section, checking the frame's element count
    /// against `expect` (what the header's dimensions imply).
    pub fn read_f64_section(&mut self, expect: usize, what: &str) -> Result<Vec<f64>> {
        let len_bytes = self.take(8, what)?;
        let len = u64::from_le_bytes(len_bytes.try_into().unwrap());
        if len != expect as u64 {
            bail!("{what}: frame holds {len} values but the header implies {expect}");
        }
        let raw = self.take_elems(expect, 8, what)?;
        let mut out = Vec::with_capacity(expect);
        for chunk in raw.chunks_exact(8) {
            out.push(f64::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(out)
    }

    /// Read one framed **f32** section written by [`push_f32_section`],
    /// widening each value back to f64 (exact — every f32 is an f64).
    pub fn read_f32_section(&mut self, expect: usize, what: &str) -> Result<Vec<f64>> {
        let len_bytes = self.take(8, what)?;
        let len = u64::from_le_bytes(len_bytes.try_into().unwrap());
        if len != expect as u64 {
            bail!("{what}: frame holds {len} values but the header implies {expect}");
        }
        let raw = self.take_elems(expect, 4, what)?;
        let mut out = Vec::with_capacity(expect);
        for chunk in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(chunk.try_into().unwrap()) as f64);
        }
        Ok(out)
    }
}

/// Split a magic-line file into its JSON header line and binary payload:
/// `<magic>\n<json header>\n<payload>`. The magic must include its
/// trailing newline. Returns `(header_str, payload_bytes)`.
pub fn split_magic_file<'a>(
    bytes: &'a [u8],
    magic: &[u8],
    what: &str,
) -> Result<(&'a str, &'a [u8])> {
    if !bytes.starts_with(magic) {
        bail!("not a {what} file (bad magic)");
    }
    let rest = &bytes[magic.len()..];
    let nl = rest
        .iter()
        .position(|&b| b == b'\n')
        .ok_or_else(|| anyhow!("{what}: truncated before the header line ended"))?;
    let header = std::str::from_utf8(&rest[..nl])
        .map_err(|_| anyhow!("{what}: header is not UTF-8"))?;
    Ok((header, &rest[nl + 1..]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn checksum_hex_round_trips() {
        for h in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(parse_checksum_hex(&checksum_hex(h)).unwrap(), h);
        }
        assert!(parse_checksum_hex("xyz").is_err());
        assert!(parse_checksum_hex("0123").is_err());
    }

    #[test]
    fn f64_sections_round_trip_bit_exactly() {
        let a = vec![0.1, -0.0, 1.0 / 3.0, f64::MAX, 5e-324];
        let b = vec![42.0; 3];
        let mut payload = Vec::new();
        push_f64_section(&mut payload, &a);
        push_f64_section(&mut payload, &b);
        let mut r = SectionReader::new(&payload);
        let ra = r.read_f64_section(a.len(), "a").unwrap();
        let rb = r.read_f64_section(b.len(), "b").unwrap();
        assert_eq!(r.remaining(), 0);
        for (x, y) in a.iter().zip(&ra) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(rb, b);
    }

    #[test]
    fn f32_sections_round_trip_at_f32_precision() {
        let a = vec![0.1, -0.0, 1.0 / 3.0, 2.5];
        let mut payload = Vec::new();
        push_f32_section(&mut payload, &a);
        assert_eq!(payload.len(), 8 + 4 * a.len());
        let mut r = SectionReader::new(&payload);
        let back = r.read_f32_section(a.len(), "a").unwrap();
        assert_eq!(r.remaining(), 0);
        for (x, y) in a.iter().zip(&back) {
            // exact round trip of the f32 cast (f32 → f64 is lossless)
            assert_eq!((*x as f32) as f64, *y);
        }
        // -0.0 keeps its sign through the narrow-widen pair
        assert!(back[1] == 0.0 && back[1].is_sign_negative());
        // truncation and frame/header disagreement still error
        let cut = &payload[..payload.len() - 2];
        assert!(SectionReader::new(cut).read_f32_section(4, "a").is_err());
        assert!(SectionReader::new(&payload).read_f32_section(5, "a").is_err());
    }

    #[test]
    fn truncated_and_miscounted_sections_error() {
        let mut payload = Vec::new();
        push_f64_section(&mut payload, &[1.0, 2.0, 3.0]);
        // truncation mid-section
        let cut = &payload[..payload.len() - 4];
        assert!(SectionReader::new(cut).read_f64_section(3, "x").is_err());
        // header/frame disagreement
        assert!(SectionReader::new(&payload).read_f64_section(4, "x").is_err());
        // empty payload
        assert!(SectionReader::new(&[]).read_f64_section(1, "x").is_err());
    }

    #[test]
    fn stream_frames_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[0xFFu8; 300]).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r, 1024).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r, 1024).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut r, 1024).unwrap().unwrap(), vec![0xFFu8; 300]);
        // clean EOF at a frame boundary is the non-error end of stream
        assert!(read_frame(&mut r, 1024).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_header_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        for cut in 1..16 {
            let mut r = &buf[..cut];
            let err = read_frame(&mut r, 1024).unwrap_err();
            assert!(format!("{err}").contains("truncated frame"), "{err}");
        }
    }

    #[test]
    fn truncated_frame_payload_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        let mut r = &buf[..buf.len() - 2];
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert!(format!("{err}").contains("truncated frame"), "{err}");
    }

    #[test]
    fn corrupt_frame_payload_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x10;
        let mut r = &buf[..];
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert!(format!("{err}").contains("corrupt frame"), "{err}");
    }

    #[test]
    fn corrupt_frame_checksum_field_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        buf[8] ^= 0x01; // first checksum byte
        let mut r = &buf[..];
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert!(format!("{err}").contains("corrupt frame"), "{err}");
    }

    #[test]
    fn oversized_frame_refused_before_allocation() {
        // hand-build a header promising u64::MAX bytes; the cap must
        // reject it without touching the (absent) payload
        let mut buf = Vec::new();
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        let mut r = &buf[..];
        let err = read_frame(&mut r, 1 << 20).unwrap_err();
        assert!(format!("{err}").contains("oversized frame"), "{err}");
        // a frame exactly at the cap is fine
        let mut ok = Vec::new();
        write_frame(&mut ok, &[7u8; 32]).unwrap();
        let mut r = &ok[..];
        assert_eq!(read_frame(&mut r, 32).unwrap().unwrap(), vec![7u8; 32]);
        // and one byte over the cap is not
        let mut r = &ok[..];
        assert!(read_frame(&mut r, 31).is_err());
    }

    #[test]
    fn garbage_mid_stream_is_an_error_not_a_panic() {
        // random bytes where a header should be: either an oversize
        // refusal or a checksum/truncation error, never a panic
        let garbage: Vec<u8> = (0..64u8).map(|i| i.wrapping_mul(37)).collect();
        let mut r = &garbage[..];
        assert!(read_frame(&mut r, 1024).is_err());
    }

    #[test]
    fn overflowing_section_count_errors_cleanly() {
        // a section header whose element count × 8 overflows usize must
        // error, not wrap into a small in-bounds read
        let mut payload = Vec::new();
        payload.extend_from_slice(&u64::MAX.to_le_bytes());
        payload.extend_from_slice(&[0u8; 64]);
        let err = SectionReader::new(&payload)
            .read_f64_section(usize::MAX, "x")
            .unwrap_err();
        let msg = format!("{err}");
        assert!(
            msg.contains("overflow") || msg.contains("truncated"),
            "{msg}"
        );
    }

    #[test]
    fn magic_split() {
        let file = b"magic\n{\"v\":1}\n\x01\x02";
        let (h, p) = split_magic_file(file, b"magic\n", "test").unwrap();
        assert_eq!(h, "{\"v\":1}");
        assert_eq!(p, b"\x01\x02");
        assert!(split_magic_file(b"other\nx", b"magic\n", "test").is_err());
        assert!(split_magic_file(b"magic\nno newline", b"magic\n", "test").is_err());
    }
}
