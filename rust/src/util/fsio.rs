//! Filesystem helpers shared by the artifact store and dataset writers.

use crate::anyhow;
use crate::Result;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-process uniquifier for temp file names: two concurrent
/// [`write_atomic`] calls targeting the same destination must not write
/// through the same temp file.
static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to `path` atomically: the bytes go to a uniquely named
/// temp file in the destination directory, are synced to stable storage,
/// and the temp file is then renamed into place. A crash mid-write —
/// process *or* system — leaves at worst a stray `.tmp` file, never a
/// truncated `path`, and readers racing the writer see either the old
/// complete file or the new complete one. (Rename is atomic only within
/// one filesystem; writing the temp file next to the destination
/// guarantees they share one. The fsync before the rename is what makes
/// the guarantee hold across power loss: without it the rename can land
/// on disk ahead of the data.)
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => Path::new("."),
    };
    let name = path.file_name().and_then(|s| s.to_str()).unwrap_or("out");
    let tmp = dir.join(format!(
        ".{name}.{}-{}.tmp",
        std::process::id(),
        TEMP_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let write = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(anyhow!("writing {}: {e}", tmp.display()));
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(anyhow!("renaming {} into place: {e}", path.display()));
    }
    // best effort: make the rename itself durable (the directory entry
    // lives in the directory's data)
    #[cfg(unix)]
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_overwrites_without_residue() {
        let dir = std::env::temp_dir()
            .join("oasis-fsio-test")
            .join(format!("r{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.bin");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        // overwrite renames over the existing file
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        // no temp files left behind
        let stray: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty(), "stray temp files: {stray:?}");
        // a missing destination directory is a clean error
        assert!(write_atomic(&dir.join("absent/deep.bin"), b"x").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
