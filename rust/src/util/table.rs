//! Plain-text table rendering for the bench harness (the paper's tables are
//! regenerated as aligned text tables on stdout).

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Table {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    /// Render with per-column alignment padding.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {t} ==\n"));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let c = &cells[i];
                line.push_str(c);
                for _ in c.chars().count()..widths[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Scientific notation like the paper's tables: `1.23e-6`.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    format!("{x:.2e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Problem", "n", "oASIS"]);
        t.row(vec!["Two Moons".into(), "2000".into(), "1.00e-6".into()]);
        t.row(vec!["BORG".into(), "7680".into(), "5.30e-2".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("Problem"));
        assert!(lines[1].starts_with("---"));
        assert!(lines[2].contains("Two Moons"));
        // columns aligned: 'n' column starts at same offset in all rows
        let pos_header = lines[0].find("n ").unwrap();
        let pos_row = lines[2].find("2000").unwrap();
        assert_eq!(pos_header, pos_row);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(1.23e-6), "1.23e-6");
        assert_eq!(sci(530.0), "5.30e2");
    }
}
