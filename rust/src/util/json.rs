//! Minimal JSON reader/writer (serde_json substitute).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest (`artifacts/manifest.json`), experiment result dumps,
//! and coordinator configs. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access: `j.get("a")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> JsonError {
        JsonError { offset: self.i, message: m.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulls").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arts":[{"dims":{"l":512,"n":1024},"name":"d"}],"v":1}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn display_escapes() {
        let j = Json::Str("a\"b\\c\n".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\n""#);
    }

    #[test]
    fn manifest_shape() {
        // exactly the structure aot.py emits
        let src = r#"{"version":1,"artifacts":[{"name":"delta_n1024_l512",
          "file":"delta_n1024_l512.hlo.txt","op":"delta_scores",
          "dims":{"n":1024,"l":512},
          "inputs":[{"name":"c","shape":[1024,512],"dtype":"float32"}],
          "outputs":["delta"]}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(
            arts[0].get("dims").unwrap().get("n").unwrap().as_usize(),
            Some(1024)
        );
    }
}
