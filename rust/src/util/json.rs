//! Minimal JSON reader/writer (serde_json substitute).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest (`artifacts/manifest.json`), experiment result dumps,
//! and coordinator configs. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Recursion cap for the recursive-descent parser: parsing is a
/// network-facing input path (the `oasis serve` request bodies), and an
/// unbounded `[[[[…` would overflow the stack — an uncatchable abort.
const MAX_DEPTH: usize = 128;

impl Json {
    /// Parse a JSON document (containers nested at most 128 deep).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access: `j.get("a")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity; null is the conventional
                    // lossy mapping (what serde_json does for f64::NAN).
                    write!(f, "null")
                } else if x.fract() == 0.0
                    && x.abs() < 1e15
                    && !(*x == 0.0 && x.is_sign_negative())
                {
                    // -0.0 is excluded: the i64 cast would drop the sign
                    // bit; the Display branch prints it as "-0", which
                    // parses back bit-exactly.
                    write!(f, "{}", *x as i64)
                } else {
                    // Rust's f64 Display is the shortest string that parses
                    // back to the same value, so Display→parse round-trips
                    // bit-exactly for every finite non-integer.
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// current container nesting (bounded by [`MAX_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> JsonError {
        JsonError { offset: self.i, message: m.to_string() }
    }

    /// Four hex digits starting at byte `at`.
    fn hex4(&self, at: usize) -> Result<u32, JsonError> {
        if at + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[at..at + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting deeper than 128 levels"));
        }
        self.depth += 1;
        let v = match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        };
        self.depth -= 1;
        v
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hi = self.hex4(self.i + 1)?;
                            self.i += 4;
                            if (0xD800..0xDC00).contains(&hi) {
                                // high surrogate: must pair with \uDC00–DFFF
                                // to form one supplementary-plane scalar
                                let paired = self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u');
                                let lo = if paired { self.hex4(self.i + 3).ok() } else { None };
                                match lo {
                                    Some(lo) if (0xDC00..0xE000).contains(&lo) => {
                                        let cp = 0x10000
                                            + ((hi - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        out.push(
                                            char::from_u32(cp).unwrap_or('\u{fffd}'),
                                        );
                                        self.i += 6;
                                    }
                                    _ => out.push('\u{fffd}'), // lone surrogate
                                }
                            } else {
                                out.push(char::from_u32(hi).unwrap_or('\u{fffd}'));
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulls").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    /// Deep nesting must be a clean error, not a stack-overflow abort —
    /// the parser handles network-facing request bodies.
    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
        // …while reasonable nesting is unaffected
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arts":[{"dims":{"l":512,"n":1024},"name":"d"}],"v":1}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn display_escapes() {
        let j = Json::Str("a\"b\\c\n".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\n""#);
    }

    /// Serialize→parse must reproduce every finite f64 bit-exactly: the
    /// server's snapshot/query responses ship factor matrices as numbers,
    /// and the acceptance tests compare them against offline runs.
    #[test]
    fn f64_round_trip_is_exact() {
        let values = [
            0.0,
            -0.0, // sign bit must survive (serialized as "-0")
            1.0,
            -1.0,
            0.1,
            1.0 / 3.0,
            -1234.567_8,
            1e-7,
            2.5e-300,
            1.7976931348623157e308, // f64::MAX
            5e-324,                 // smallest subnormal
            1e15,                   // integer-format boundary
            9.007199254740992e15,   // 2^53
            123456.75,
        ];
        for &v in &values {
            let s = Json::Num(v).to_string();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(
                back.to_bits(),
                v.to_bits(),
                "value {v:e} serialized as {s} parsed back as {back:e}"
            );
        }
    }

    #[test]
    fn non_finite_serializes_as_null() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).to_string(), "null");
        }
        // …and stays valid inside containers
        let j = Json::Arr(vec![Json::Num(1.5), Json::Num(f64::NAN)]);
        assert_eq!(j.to_string(), "[1.5,null]");
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn string_round_trip_with_tricky_contents() {
        let cases = [
            "plain",
            "quote \" backslash \\ slash /",
            "ctrl \u{1} \u{1f} tab\t newline\n cr\r",
            "unicode é ☃ 語",
            "emoji 😀 outside the BMP",
        ];
        for case in cases {
            let s = Json::Str(case.to_string()).to_string();
            assert_eq!(
                Json::parse(&s).unwrap().as_str(),
                Some(case),
                "round-trip failed for {case:?} via {s}"
            );
        }
    }

    #[test]
    fn surrogate_pair_escapes_parse() {
        // the UTF-16 escape pair for U+1F600 (grinning-face emoji)
        let j = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(j.as_str(), Some("\u{1f600}"));
        // a lone high surrogate degrades to U+FFFD instead of erroring
        let lone = Json::parse(r#""a\ud83db""#).unwrap();
        assert_eq!(lone.as_str(), Some("a\u{fffd}b"));
    }

    #[test]
    fn manifest_shape() {
        // exactly the structure aot.py emits
        let src = r#"{"version":1,"artifacts":[{"name":"delta_n1024_l512",
          "file":"delta_n1024_l512.hlo.txt","op":"delta_scores",
          "dims":{"n":1024,"l":512},
          "inputs":[{"name":"c","shape":[1024,512],"dtype":"float32"}],
          "outputs":["delta"]}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(
            arts[0].get("dims").unwrap().get("n").unwrap().as_usize(),
            Some(1024)
        );
    }
}
