//! The oASIS-P leader: seeds the run, reduces gathered shard argmaxes,
//! broadcasts selected points, detects worker failure, and assembles the
//! final Nyström approximation from the gathered column blocks.
//!
//! The leader is itself a [`SamplerSession`]: [`OasisPSession::start`]
//! spawns the worker threads and seeds them, each
//! [`step`](SamplerSession::step) performs one gather → reduce → broadcast
//! round (the paper's one-vector-per-iteration communication pattern), and
//! [`finish_run`](OasisPSession::finish_run) gathers the column blocks and
//! joins the workers. [`run_oasis_p`] is the one-shot adapter driving a
//! session under a column-budget [`StoppingRule`]; callers can instead
//! drive a session with any stopping rule — the workers ship shard-local
//! `Σ|Δ|` piggybacked on every argmax, so even the error-target criterion
//! works distributed with zero extra messages.

use super::comm::{FromWorker, LeaderHandle, ToWorker, WorkerHandle};
use super::config::OasisPConfig;
use super::metrics::Metrics;
use super::worker::Worker;
use crate::data::{loader, shard, Dataset, LoadLimits, Shard};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::sampling::{
    run_to_completion, SamplerSession, SelectionTrace, StepOutcome, StopReason,
    StoppingRule,
};
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::{anyhow, bail};
use crate::Result;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;

/// Where the workers' shards come from.
///
/// `Memory` is the in-process setting: the caller (usually the
/// [`engine`](crate::engine)) splits a materialized dataset and each
/// worker thread receives its block. `File` is the paper's
/// distributed-data setting (Alg. 2: "load separate n/p column blocks of
/// Z into each node"): every worker opens the binary dataset file itself
/// and reads only its own byte range via [`loader::load_shard`] — the
/// leader never materializes the dataset, only the `n` its caller read
/// from the file header ([`loader::peek_matrix_dims`]).
pub enum ShardPlan {
    Memory(Vec<Shard>),
    File { path: std::path::PathBuf, n: usize, limits: LoadLimits },
}

impl ShardPlan {
    /// Total points across all shards.
    pub fn n(&self) -> usize {
        match self {
            ShardPlan::Memory(shards) => shards.iter().map(Shard::len).sum(),
            ShardPlan::File { n, .. } => *n,
        }
    }
}

/// Outcome report of a distributed run.
#[derive(Debug)]
pub struct OasisPReport {
    pub trace: SelectionTrace,
    pub metrics: Arc<Metrics>,
    pub workers: usize,
    pub wall_secs: f64,
}

/// Run oASIS-P over `cfg.workers` threads. The selection sequence is
/// identical to the sequential [`crate::sampling::oasis::Oasis`] sampler
/// with the same seed/tolerance (PaperR variant semantics).
pub fn run_oasis_p(
    ds: &Dataset,
    kernel: Arc<dyn Kernel + Send + Sync>,
    cfg: &OasisPConfig,
) -> Result<(NystromApprox, OasisPReport)> {
    let mut session = OasisPSession::start(ds, kernel, cfg.clone())?;
    run_to_completion(&mut session, &StoppingRule::budget(cfg.max_cols))?;
    session.finish_run()
}

/// A live distributed oASIS-P run: worker threads spawned and seeded, one
/// selection round per [`step`](SamplerSession::step).
///
/// Unlike the sequential sessions this one holds no oracle borrow (the
/// workers own their shards), so it is `'static`; its per-run capacity is
/// fixed at `cfg.max_cols` because every worker pre-allocates its W⁻¹
/// replica at that stride — stepping past it reports
/// [`StopReason::Exhausted`]. Mid-run
/// [`snapshot`](SamplerSession::snapshot) performs a non-terminal column
/// gather ([`ToWorker::GatherColumns`]): the workers ship their current C
/// blocks and keep running, so a serving caller can hand out the current
/// factors and continue the run; [`finish_run`](OasisPSession::finish_run)
/// remains the terminal gather that also joins the workers.
pub struct OasisPSession {
    cfg: OasisPConfig,
    n: usize,
    /// hard capacity: min(cfg.max_cols, n).
    capacity: usize,
    p: usize,
    owner_ranges: Vec<std::ops::Range<usize>>,
    handles: Vec<WorkerHandle>,
    joins: Vec<std::thread::JoinHandle<()>>,
    inbox: mpsc::Receiver<FromWorker>,
    /// Argmax replies pulled off the inbox while a mid-run snapshot was
    /// draining its `Columns` messages; consumed by the next `step`.
    /// (`RefCell` because `snapshot` is a `&self` trait method.)
    pending: RefCell<VecDeque<FromWorker>>,
    metrics: Arc<Metrics>,
    trace: SelectionTrace,
    /// Leader-side mirror of the selected points Z_Λ (selection order).
    /// The leader sees every selected point anyway — seeds are fetched
    /// during init, winners fetched before each broadcast — so the
    /// mirror costs no extra communication. It is what
    /// [`SamplerSession::selected_points`] serves, letting shard-read
    /// deployments (whose caller holds no dataset) answer queries and
    /// save artifacts from Λ's points alone.
    z_sel: Vec<Vec<f64>>,
    d_scale: f64,
    /// Σ|Δ| / Σ|d| from the most recent gather round.
    resid_sum: Option<f64>,
    d_sum: f64,
    exhausted: Option<StopReason>,
    torn_down: bool,
    busy_secs: f64,
}

impl OasisPSession {
    /// Spawn the workers over an in-memory dataset split (the
    /// single-process setting). See [`start_with_plan`] for the
    /// plan-driven entry the engine uses — including per-worker file
    /// reads.
    ///
    /// [`start_with_plan`]: OasisPSession::start_with_plan
    pub fn start(
        ds: &Dataset,
        kernel: Arc<dyn Kernel + Send + Sync>,
        cfg: OasisPConfig,
    ) -> Result<OasisPSession> {
        // start_with_plan validates against the plan's n
        let p = cfg.workers.min(ds.n()).max(1);
        Self::start_with_plan(ShardPlan::Memory(shard::split(ds, p)), kernel, cfg)
    }

    /// Spawn the workers from a [`ShardPlan`], replicate the seed state
    /// (identical RNG stream and rejection rule to the sequential
    /// sampler), and broadcast Init. Workers reply with their first
    /// shard argmaxes, which the first `step` will gather.
    ///
    /// With [`ShardPlan::File`], each worker thread reads only its own
    /// byte range of the binary dataset file ([`loader::load_shard`])
    /// before entering its message loop; a failed read surfaces through
    /// the normal worker-failure path during seeding. Worker state
    /// construction (including the kernel-diagonal pass) happens on the
    /// worker threads for both plans, so per-shard init runs in
    /// parallel.
    pub fn start_with_plan(
        plan: ShardPlan,
        kernel: Arc<dyn Kernel + Send + Sync>,
        cfg: OasisPConfig,
    ) -> Result<OasisPSession> {
        let sw = Stopwatch::start();
        let n = plan.n();
        cfg.validate(n)?;
        let metrics = Arc::new(Metrics::default());

        // --- spawn workers ---
        // one spawn path for both plans: the worker thread obtains its
        // shard (already-split block, or its own byte-range read of the
        // file), constructs its state — including the kernel-diagonal
        // pass, so per-shard init runs in parallel — and enters its
        // message loop; an Err from the source surfaces at the leader's
        // next recv as a worker failure
        let (to_leader_tx, inbox) = mpsc::channel::<FromWorker>();
        let mut handles = Vec::new();
        let mut joins = Vec::new();
        let p;
        {
            let mut spawn =
                |w: usize, source: Box<dyn FnOnce() -> Result<Shard> + Send>| {
                    let (tx, rx) = mpsc::channel::<ToWorker>();
                    handles.push(WorkerHandle::new(w, tx, metrics.clone()));
                    let worker_kernel = kernel.clone();
                    let leader =
                        LeaderHandle::new(to_leader_tx.clone(), metrics.clone());
                    let worker_metrics = metrics.clone();
                    let (max_cols, failure) = (cfg.max_cols, cfg.failure);
                    joins.push(std::thread::spawn(move || match source() {
                        Ok(s) => Worker::new(
                            w,
                            s,
                            worker_kernel,
                            leader,
                            worker_metrics,
                            max_cols,
                            failure,
                        )
                        .run(rx),
                        Err(e) => {
                            leader.send(FromWorker::Failed {
                                worker: w,
                                message: format!("{e}"),
                            });
                        }
                    }));
                };
            match plan {
                ShardPlan::Memory(shards) => {
                    p = shards.len();
                    for s in shards {
                        let w = s.worker;
                        spawn(w, Box::new(move || Ok(s)));
                    }
                }
                ShardPlan::File { path, n: _, limits } => {
                    p = cfg.workers.min(n).max(1);
                    // the leader's ownership ranges come from the plan's
                    // n; each worker re-derives its range from the
                    // file's *actual* header, so cross-check the two —
                    // a stale plan (file replaced since it was peeked)
                    // or a caller-supplied wrong n must fail loudly at
                    // seeding, not misroute FetchPoints or silently
                    // select over mismatched blocks. If total rows
                    // differ, at least one worker's range differs.
                    let expected = shard::shard_ranges(n, p);
                    for w in 0..p {
                        let path = path.clone();
                        let want = expected[w].clone();
                        spawn(
                            w,
                            Box::new(move || {
                                let s = loader::load_shard(&path, w, p, &limits)?;
                                if s.start != want.start || s.len() != want.len() {
                                    return Err(anyhow!(
                                        "shard {w} of {} covers rows {}..{} \
                                         but this run expects {}..{} — the \
                                         file changed since the run was \
                                         planned",
                                        path.display(),
                                        s.start,
                                        s.start + s.len(),
                                        want.start,
                                        want.end
                                    ));
                                }
                                Ok(s)
                            }),
                        );
                    }
                }
            }
        }
        drop(to_leader_tx);

        let capacity = cfg.max_cols.min(n);
        let mut session = OasisPSession {
            cfg,
            n,
            capacity,
            p,
            owner_ranges: shard::shard_ranges(n, p),
            handles,
            joins,
            inbox,
            pending: RefCell::new(VecDeque::new()),
            metrics,
            trace: SelectionTrace::default(),
            z_sel: Vec::new(),
            d_scale: 0.0,
            resid_sum: None,
            d_sum: 0.0,
            exhausted: None,
            torn_down: false,
            busy_secs: 0.0,
        };
        if let Err(e) = session.init_seed(&kernel, &sw) {
            session.teardown();
            return Err(e);
        }
        session.busy_secs = sw.secs();
        Ok(session)
    }

    /// Seed selection, replicating the sequential sampler exactly, then
    /// the Init broadcast.
    fn init_seed(
        &mut self,
        kernel: &Arc<dyn Kernel + Send + Sync>,
        sw: &Stopwatch,
    ) -> Result<()> {
        let n = self.n;
        let l = self.capacity;
        let k0 = self.cfg.init_cols.min(l);
        let mut rng = Pcg64::new(self.cfg.seed);
        let seed_indices: Vec<usize>;
        let seed_points: Vec<Vec<f64>>;
        let winv0: Mat;
        loop {
            let cand = rng.sample_without_replacement(n, k0);
            // fetch candidate points from their owners
            let mut pts: Vec<Option<Vec<f64>>> = vec![None; k0];
            for (slot, &g) in cand.iter().enumerate() {
                let w = self.owner_of(g);
                if !self.handles[w].send(ToWorker::FetchPoint { global_idx: g }) {
                    bail!("worker {w} unavailable during seeding");
                }
                match self.recv()? {
                    FromWorker::Point { global_idx, point } => {
                        debug_assert_eq!(global_idx, g);
                        pts[slot] = Some(point);
                    }
                    FromWorker::Failed { worker, message } => {
                        bail!("worker {worker} failed during seeding: {message}")
                    }
                    other => bail!("unexpected message during seeding: {other:?}"),
                }
            }
            let pts: Vec<Vec<f64>> = pts.into_iter().map(Option::unwrap).collect();
            // W₀ from kernel evaluations on the gathered points — identical
            // values to the sequential sampler's fetched-column entries.
            let mut w = Mat::zeros(k0, k0);
            for i in 0..k0 {
                for j in 0..k0 {
                    *w.at_mut(i, j) = kernel.eval(&pts[i], &pts[j]);
                }
            }
            if let Some(inv) = crate::linalg::inverse(&w) {
                let cond = inv.max_abs() * w.max_abs();
                if cond.is_finite() && cond <= 1e12 {
                    seed_indices = cand;
                    seed_points = pts;
                    winv0 = inv;
                    break;
                }
            }
        }

        // broadcast Init — every worker replies with its first argmax
        self.z_sel = seed_points.clone();
        let init = ToWorker::Init {
            seed_indices: seed_indices.clone(),
            seed_points,
            winv0: winv0.data.clone(),
        };
        for h in &self.handles {
            if !h.send(init.clone()) {
                bail!("worker {} unavailable at init", h.worker);
            }
        }
        for &g in &seed_indices {
            self.trace.order.push(g);
            self.trace.cum_secs.push(sw.secs());
            self.trace.deltas.push(f64::NAN);
        }
        Ok(())
    }

    fn owner_of(&self, g: usize) -> usize {
        self.owner_ranges
            .iter()
            .position(|r| r.contains(&g))
            .expect("index in range")
    }

    fn recv(&self) -> Result<FromWorker> {
        self.inbox
            .recv_timeout(self.cfg.timeout)
            .map_err(|e| anyhow!("leader recv: {e} (worker died or deadlock)"))
    }

    /// Next message for the selection loop: messages stashed by a mid-run
    /// snapshot are replayed before the live inbox is read.
    fn next_msg(&self) -> Result<FromWorker> {
        if let Some(m) = self.pending.borrow_mut().pop_front() {
            return Ok(m);
        }
        self.recv()
    }

    /// Drain the p `Columns` replies of a gather (terminal or not) and
    /// assemble (C, W⁻¹) at the current k. `stash_argmax` is the mid-run
    /// mode: in-flight `Argmax` replies are buffered for the next `step`
    /// (and the live inbox is read directly — `pending` can only hold
    /// `Argmax`); the terminal mode consumes stashed-and-live `Argmax`
    /// replies alike and discards them as stale.
    fn gather_columns(&self, k: usize, stash_argmax: bool) -> Result<(Mat, Mat)> {
        let n = self.n;
        let mut c = Mat::zeros(n, k);
        let mut winv: Option<Mat> = None;
        let mut got = 0;
        while got < self.p {
            let msg = if stash_argmax { self.recv()? } else { self.next_msg()? };
            match msg {
                FromWorker::Columns { start, local_n, c_block, winv: w, .. } => {
                    for i in 0..local_n {
                        c.data[(start + i) * k..(start + i + 1) * k]
                            .copy_from_slice(&c_block[i * k..(i + 1) * k]);
                    }
                    if let Some(wd) = w {
                        winv = Some(Mat::from_vec(k, k, wd));
                    }
                    got += 1;
                }
                msg @ FromWorker::Argmax { .. } => {
                    if stash_argmax {
                        self.pending.borrow_mut().push_back(msg);
                    }
                }
                FromWorker::Failed { worker, message } => {
                    bail!("worker {worker} failed during column gather: {message}")
                }
                other => {
                    bail!("unexpected message during column gather: {other:?}")
                }
            }
        }
        let winv = winv.ok_or_else(|| anyhow!("no W⁻¹ returned by worker 0"))?;
        Ok((c, winv))
    }

    /// Send Finish to every worker and join the threads (idempotent).
    fn teardown(&mut self) {
        if self.torn_down {
            return;
        }
        self.torn_down = true;
        for h in &self.handles {
            h.send(ToWorker::Finish);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }

    /// Finish the run: gather the column blocks and W⁻¹ replica, join the
    /// workers, and return the approximation plus the run report.
    pub fn finish_run(mut self) -> Result<(NystromApprox, OasisPReport)> {
        let sw = Stopwatch::start();
        for h in &self.handles {
            if !h.send(ToWorker::Finish) {
                bail!("worker {} unavailable (finish)", h.worker);
            }
        }
        let k = self.trace.order.len();
        // terminal gather: stale Argmax replies (stashed or live) are
        // drained and discarded
        let (c, winv) = self.gather_columns(k, false)?;
        self.torn_down = true;
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        self.busy_secs += sw.secs();
        let report = OasisPReport {
            trace: self.trace.clone(),
            metrics: self.metrics.clone(),
            workers: self.p,
            wall_secs: self.busy_secs,
        };
        Ok((
            NystromApprox {
                indices: self.trace.order.clone(),
                c,
                winv,
                selection_secs: self.busy_secs,
            },
            report,
        ))
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }
}

impl SamplerSession for OasisPSession {
    fn name(&self) -> &'static str {
        "oASIS-P"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn indices(&self) -> &[usize] {
        &self.trace.order
    }

    fn trace(&self) -> &SelectionTrace {
        &self.trace
    }

    fn selection_secs(&self) -> f64 {
        self.busy_secs
    }

    /// Residual trace ratio `Σ|Δᵢ| / Σ|dᵢ|`, aggregated from the shard
    /// sums the workers piggyback on every argmax gather. `None` before
    /// the first gather round.
    fn error_estimate(&self) -> Option<f64> {
        let resid = self.resid_sum?;
        if self.d_sum <= 0.0 {
            return Some(0.0);
        }
        Some(resid / self.d_sum)
    }

    /// The leader's Z_Λ mirror (see the field docs on `z_sel`): lets
    /// callers that hold no dataset — shard-read deployments — answer
    /// extension queries and save artifacts, which only ever touch the
    /// selected points.
    fn selected_points(&self, from: usize) -> Option<Vec<Vec<f64>>> {
        Some(self.z_sel[from.min(self.z_sel.len())..].to_vec())
    }

    /// One distributed selection round: gather the shard argmaxes, reduce,
    /// fetch the winning point from its owner, broadcast it (paper: one
    /// gathered scalar + one broadcast vector per iteration).
    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.exhausted {
            return Ok(StepOutcome::Exhausted(reason));
        }
        let sw = Stopwatch::start();
        if self.trace.order.len() >= self.capacity {
            // the workers' W⁻¹ replicas are allocated at cfg.max_cols
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        // gather shard argmaxes
        let mut best: Option<(usize, f64)> = None; // (global idx, signed Δ)
        let mut round_resid = 0.0f64;
        let mut round_d_sum = 0.0f64;
        for _ in 0..self.p {
            match self.next_msg()? {
                FromWorker::Argmax {
                    best: wb,
                    d_max,
                    sum_abs_delta,
                    d_sum,
                    ..
                } => {
                    self.d_scale = self.d_scale.max(d_max);
                    round_resid += sum_abs_delta;
                    round_d_sum += d_sum;
                    if let Some((gi, dv)) = wb {
                        let replace = match best {
                            None => true,
                            Some((bg, bd)) => {
                                let (a, b) = (dv.abs(), bd.abs());
                                a > b || (a == b && gi < bg)
                            }
                        };
                        if replace {
                            best = Some((gi, dv));
                        }
                    }
                }
                FromWorker::Failed { worker, message } => {
                    bail!("worker {worker} failed: {message}")
                }
                other => bail!("unexpected message in main loop: {other:?}"),
            }
        }
        self.metrics.add_iteration();
        self.resid_sum = Some(round_resid);
        self.d_sum = round_d_sum;
        let tol = crate::sampling::effective_tol(self.cfg.tol, &[self.d_scale]);
        let (gidx, dval) = match best {
            Some(b) if b.1.abs() >= tol => b,
            Some(_) => {
                self.exhausted = Some(StopReason::ScoreBelowTol);
                self.busy_secs += sw.secs();
                return Ok(StepOutcome::Exhausted(StopReason::ScoreBelowTol));
            }
            None => {
                self.exhausted = Some(StopReason::Exhausted);
                self.busy_secs += sw.secs();
                return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
            }
        };
        // fetch the winning point from its owner
        let w = self.owner_of(gidx);
        if !self.handles[w].send(ToWorker::FetchPoint { global_idx: gidx }) {
            bail!("worker {w} unavailable (fetch)");
        }
        let point = loop {
            match self.recv()? {
                FromWorker::Point { global_idx, point } => {
                    debug_assert_eq!(global_idx, gidx);
                    break point;
                }
                FromWorker::Failed { worker, message } => {
                    bail!("worker {worker} failed: {message}")
                }
                other => bail!("unexpected message awaiting point: {other:?}"),
            }
        };
        // broadcast the selected point — the paper's one-vector-per-step
        // communication pattern; every worker replies with its next argmax
        self.z_sel.push(point.clone());
        let msg = ToWorker::Selected {
            global_idx: gidx,
            point,
            delta: dval,
        };
        for h in &self.handles {
            if !h.send(msg.clone()) {
                bail!("worker {} unavailable (broadcast)", h.worker);
            }
        }
        self.trace.order.push(gidx);
        self.trace.cum_secs.push(self.busy_secs + sw.secs());
        self.trace.deltas.push(dval.abs());
        self.busy_secs += sw.secs();
        Ok(StepOutcome::Selected { index: gidx, score: dval.abs() })
    }

    /// Mid-run snapshot via a non-terminal column gather
    /// ([`ToWorker::GatherColumns`]): every worker replies with its
    /// current C block (worker 0 also its W⁻¹ replica) and keeps running,
    /// so the session can continue stepping afterwards. Argmax replies
    /// already in flight from the last broadcast are stashed and replayed
    /// to the next `step` — per-worker channels are FIFO, so each worker
    /// has incorporated every selection before it serves the gather and
    /// the snapshot is always a consistent k-column prefix. Snapshot time
    /// is deliberately not charged to `selection_secs` (it is serving
    /// work, not selection).
    fn snapshot(&self) -> Result<NystromApprox> {
        if self.torn_down {
            bail!("oASIS-P session already torn down");
        }
        for h in &self.handles {
            if !h.send(ToWorker::GatherColumns) {
                bail!("worker {} unavailable (snapshot gather)", h.worker);
            }
        }
        let k = self.trace.order.len();
        let (c, winv) = self.gather_columns(k, true)?;
        Ok(NystromApprox {
            indices: self.trace.order.clone(),
            c,
            winv,
            selection_secs: self.busy_secs,
        })
    }

    fn finish(self: Box<Self>) -> Result<NystromApprox> {
        self.finish_run().map(|(a, _)| a)
    }
}

impl Drop for OasisPSession {
    fn drop(&mut self) {
        self.teardown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;

    #[test]
    fn single_worker_runs() {
        let ds = two_moons(60, 0.05, 1);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let cfg = OasisPConfig::new(12, 3, 1).with_seed(5);
        let (approx, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
        assert_eq!(approx.k(), 12);
        assert_eq!(report.trace.order.len(), 12);
        assert!(report.metrics.iterations() >= 9);
    }

    #[test]
    fn communication_is_one_point_per_step() {
        // Broadcast volume per iteration ≈ p × (dim×8 + 16) bytes: the
        // paper's "size of the communicated vector is the dimensionality
        // of the data point".
        let ds = two_moons(100, 0.05, 2);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let p = 4;
        let cfg = OasisPConfig::new(20, 4, p).with_seed(3);
        let (_, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
        let adaptive_steps = 16; // 20 − 4 seeds
        let per_step = (2 * 8 + 16) * p; // dim=2 point + header, per worker
        let bound = (per_step * adaptive_steps * 4) as u64; // generous ×4
        assert!(
            report.metrics.broadcast_bytes() < bound,
            "broadcast {} ≥ bound {}",
            report.metrics.broadcast_bytes(),
            bound
        );
    }

    /// Dropping a live session (external stop without finish) must not
    /// deadlock or leak worker threads.
    #[test]
    fn dropping_live_session_joins_workers() {
        let ds = two_moons(80, 0.05, 4);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let cfg = OasisPConfig::new(20, 3, 3).with_seed(2);
        let mut session = OasisPSession::start(&ds, kernel, cfg).unwrap();
        for _ in 0..4 {
            session.step().unwrap();
        }
        drop(session); // teardown must complete promptly
    }

    /// A mid-run snapshot is a consistent prefix of the run — and taking
    /// it does not disturb subsequent selection: the finished run is
    /// bit-identical to an uninterrupted one.
    #[test]
    fn mid_run_snapshot_matches_prefix_and_run_continues() {
        let ds = two_moons(100, 0.05, 3);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let cfg = OasisPConfig::new(24, 4, 3).with_seed(9);
        let (reference, _) =
            run_oasis_p(&ds, kernel.clone(), &cfg.clone()).unwrap();

        let mut session = OasisPSession::start(&ds, kernel, cfg).unwrap();
        for _ in 0..6 {
            session.step().unwrap();
        }
        let snap = session.snapshot().unwrap();
        assert_eq!(snap.k(), session.k());
        assert_eq!(snap.indices, &reference.indices[..snap.k()]);
        // the gathered factors are a real Nyström state: W·W⁻¹ ≈ I
        let w = snap.c.select_rows(&snap.indices);
        let prod = w.matmul(&snap.winv);
        assert!(
            prod.fro_dist(&Mat::eye(snap.k())) < 1e-6,
            "‖W·W⁻¹−I‖ = {}",
            prod.fro_dist(&Mat::eye(snap.k()))
        );
        // snapshot C columns are the reference's prefix, bit for bit
        for i in 0..snap.n() {
            for t in 0..snap.k() {
                assert_eq!(snap.c.at(i, t), reference.c.at(i, t));
            }
        }
        // continue to the budget: identical to the uninterrupted run
        run_to_completion(&mut session, &StoppingRule::budget(24)).unwrap();
        let (fin, _) = session.finish_run().unwrap();
        assert_eq!(fin.indices, reference.indices);
        assert_eq!(fin.c.data, reference.c.data);
        assert_eq!(fin.winv.data, reference.winv.data);
    }

    /// The distributed error estimate is populated after the first round
    /// and decreases as columns accumulate.
    #[test]
    fn distributed_error_estimate_progresses() {
        let ds = two_moons(120, 0.05, 8);
        let kernel: Arc<dyn Kernel + Send + Sync> =
            Arc::new(Gaussian::with_sigma_fraction(&ds, 0.1));
        let cfg = OasisPConfig::new(30, 4, 3).with_seed(6);
        let mut session = OasisPSession::start(&ds, kernel, cfg).unwrap();
        assert!(session.error_estimate().is_none());
        session.step().unwrap();
        let e0 = session.error_estimate().unwrap();
        run_to_completion(&mut session, &StoppingRule::budget(30)).unwrap();
        let e1 = session.error_estimate().unwrap();
        assert!(e1 < e0, "estimate did not decrease: {e0} → {e1}");
        let (approx, _) = session.finish_run().unwrap();
        assert_eq!(approx.k(), 30);
    }
}
