//! The oASIS-P leader: seeds the run, reduces gathered shard argmaxes,
//! broadcasts selected points, detects worker failure (recovering when it
//! can — see below), and assembles the final Nyström approximation from
//! the gathered column blocks.
//!
//! The leader is itself a [`SamplerSession`]: [`OasisPSession::start`]
//! spawns the worker fleet over a [`Transport`] and seeds it, each
//! [`step`](SamplerSession::step) applies one selection (the paper's
//! one-vector-per-iteration communication pattern, batched SQUEAK-style
//! when `merge_batch > 1`), and
//! [`finish_run`](OasisPSession::finish_run) gathers the column blocks
//! and joins the workers. [`run_oasis_p`] is the one-shot adapter driving
//! a session under a column-budget [`StoppingRule`]; callers can instead
//! drive a session with any stopping rule — the workers ship shard-local
//! `Σ|Δ|` piggybacked on every argmax, so even the error-target criterion
//! works distributed with zero extra messages.
//!
//! # Failure semantics
//!
//! Node *death* ([`FromWorker::Gone`]: TCP reader EOF, heartbeat
//! staleness past `cfg.timeout`, or the in-process fault injector) during
//! the selection loop is recoverable whenever the fleet shard-reads a
//! dataset file ([`ShardPlan::File`] — both transports): the leader bumps
//! its epoch, re-shards the dead worker's row ranges onto the survivors
//! via [`ToWorker::Adopt`], discards in-flight argmax replies from the
//! old epoch, and restarts the interrupted gather round. With an
//! in-memory plan nobody else can serve the lost rows, so death is fatal.
//! Deterministic worker errors ([`FromWorker::Failed`] — bad file,
//! vanished batch Δ, protocol breach) are always fatal: the same input
//! would kill the adopters too, and the diagnostic must reach the caller.
//! Death during seeding or during a column gather is likewise fatal —
//! recovery is scoped to the selection loop, where all state needed to
//! rebuild a shard (Z_Λ and W⁻¹ replicas) is fully replicated.
//!
//! Re-sharded runs complete with *valid* factors (the adopters rebuild
//! C and R = W⁻¹Cᵀ exactly), but are not bit-identical to an undisturbed
//! run: recomputed R replaces incrementally-updated R, whose floating-
//! point rounding differs.

use super::comm::{FromWorker, ToWorker, WorkerHandle};
use super::config::OasisPConfig;
use super::metrics::Metrics;
use super::transport::{ChannelTransport, Transport, TransportCtx};
use crate::data::{shard, Dataset, LoadLimits, Shard};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::obs::trace::{OwnedEvent, TraceTrack};
use crate::sampling::{
    run_to_completion, SamplerSession, SelectionTrace, StepOutcome, StopReason,
    StoppingRule,
};
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::{anyhow, bail};
use crate::Result;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Where the workers' shards come from.
///
/// `Memory` is the in-process setting: the caller (usually the
/// [`engine`](crate::engine)) splits a materialized dataset and each
/// worker thread receives its block. `File` is the paper's
/// distributed-data setting (Alg. 2: "load separate n/p column blocks of
/// Z into each node"): every worker opens the binary dataset file itself
/// and reads only its own byte range via
/// [`loader::load_shard`](crate::data::loader::load_shard) — the leader
/// never materializes the dataset, only the `n` its caller read from the
/// file header
/// ([`loader::peek_matrix_dims`](crate::data::loader::peek_matrix_dims)).
/// Only `File`
/// fleets can re-shard around a dead worker (survivors shard-read the
/// adopted rows), and only `File` works over TCP.
pub enum ShardPlan {
    Memory(Vec<Shard>),
    File { path: std::path::PathBuf, n: usize, limits: LoadLimits },
}

impl ShardPlan {
    /// Total points across all shards.
    pub fn n(&self) -> usize {
        match self {
            ShardPlan::Memory(shards) => shards.iter().map(Shard::len).sum(),
            ShardPlan::File { n, .. } => *n,
        }
    }
}

/// Outcome report of a distributed run.
#[derive(Debug)]
pub struct OasisPReport {
    pub trace: SelectionTrace,
    pub metrics: Arc<Metrics>,
    pub workers: usize,
    pub wall_secs: f64,
    /// Per-worker span tracks shipped leader-ward as
    /// [`FromWorker::TraceChunk`]s (TCP fleets with tracing enabled;
    /// empty otherwise). Merge with the leader's own drained trace via
    /// [`crate::obs::trace::merged_chrome_json`] for one fleet-wide
    /// Chrome timeline.
    pub worker_traces: Vec<TraceTrack>,
}

/// Run oASIS-P over `cfg.workers` threads. The selection sequence is
/// identical to the sequential [`crate::sampling::oasis::Oasis`] sampler
/// with the same seed/tolerance (PaperR variant semantics).
pub fn run_oasis_p(
    ds: &Dataset,
    kernel: Arc<dyn Kernel + Send + Sync>,
    cfg: &OasisPConfig,
) -> Result<(NystromApprox, OasisPReport)> {
    let mut session = OasisPSession::start(ds, kernel, cfg.clone())?;
    run_to_completion(&mut session, &StoppingRule::budget(cfg.max_cols))?;
    session.finish_run()
}

/// Cap on absorbed trace events per worker — matches the worker-side
/// ring default, so a leader can't be ballooned by a chatty worker.
const MAX_WORKER_TRACE_EVENTS: usize = 1 << 16;

/// A selection the leader has arbitrated but not yet applied (queued
/// batch pick). `fresh` marks the gather round's argmax winner, whose
/// sweep Δ is still exact and ships with the broadcast.
struct Pick {
    g: usize,
    delta: f64,
    fresh: bool,
}

/// A live distributed oASIS-P run: worker fleet started and seeded, one
/// selection per [`step`](SamplerSession::step).
///
/// Unlike the sequential sessions this one holds no oracle borrow (the
/// workers own their shards), so it is `'static`; its per-run capacity is
/// fixed at `cfg.max_cols` because every worker pre-allocates its W⁻¹
/// replica at that stride — stepping past it reports
/// [`StopReason::Exhausted`]. Mid-run
/// [`snapshot`](SamplerSession::snapshot) performs a non-terminal column
/// gather ([`ToWorker::GatherColumns`]): the workers ship their current C
/// blocks and keep running, so a serving caller can hand out the current
/// factors and continue the run; [`finish_run`](OasisPSession::finish_run)
/// remains the terminal gather that also joins the workers.
pub struct OasisPSession {
    cfg: OasisPConfig,
    n: usize,
    /// hard capacity: min(cfg.max_cols, n).
    capacity: usize,
    p: usize,
    /// global row ranges (start, len) each worker currently serves;
    /// drained for dead workers, grown for adopters
    owned: Vec<Vec<(usize, usize)>>,
    alive: Vec<bool>,
    /// bumped on every re-shard; argmax replies from older epochs are
    /// discarded
    epoch: u64,
    /// arbitrated-but-unapplied batch picks (empty at merge_batch == 1
    /// between steps)
    queue: VecDeque<Pick>,
    handles: Vec<WorkerHandle>,
    joins: Vec<std::thread::JoinHandle<()>>,
    inbox: mpsc::Receiver<FromWorker>,
    /// Argmax replies pulled off the inbox while a mid-run snapshot was
    /// draining its `Columns` messages; consumed by the next `step`.
    /// (`RefCell` because `snapshot` is a `&self` trait method.)
    pending: RefCell<VecDeque<FromWorker>>,
    /// Per-worker trace events absorbed from [`FromWorker::TraceChunk`]s
    /// (events, ring-drops), bounded by [`MAX_WORKER_TRACE_EVENTS`].
    /// (`RefCell` for the same reason as `pending`: chunks arrive
    /// through `recv_live`, a `&self` path.)
    worker_traces: RefCell<Vec<(Vec<OwnedEvent>, u64)>>,
    /// whether a dead worker's rows can be re-sharded onto survivors
    recoverable: bool,
    /// whether heartbeat staleness applies (TCP fleets)
    tcp: bool,
    metrics: Arc<Metrics>,
    trace: SelectionTrace,
    /// Leader-side mirror of the selected points Z_Λ (selection order).
    /// The leader sees every selected point anyway — seeds are fetched
    /// during init, winners fetched before each broadcast — so the
    /// mirror costs no extra communication. It is what
    /// [`SamplerSession::selected_points`] serves, letting shard-read
    /// deployments (whose caller holds no dataset) answer queries and
    /// save artifacts from Λ's points alone.
    z_sel: Vec<Vec<f64>>,
    d_scale: f64,
    /// Σ|Δ| / Σ|d| from the most recent gather round.
    resid_sum: Option<f64>,
    d_sum: f64,
    exhausted: Option<StopReason>,
    torn_down: bool,
    busy_secs: f64,
}

impl OasisPSession {
    /// Spawn the workers over an in-memory dataset split (the
    /// single-process setting). See [`start_with_plan`] for the
    /// plan-driven entry the engine uses — including per-worker file
    /// reads — and [`start_with_transport`] for TCP fleets.
    ///
    /// [`start_with_plan`]: OasisPSession::start_with_plan
    /// [`start_with_transport`]: OasisPSession::start_with_transport
    pub fn start(
        ds: &Dataset,
        kernel: Arc<dyn Kernel + Send + Sync>,
        cfg: OasisPConfig,
    ) -> Result<OasisPSession> {
        // start_with_transport validates against the plan's n
        let p = cfg.workers.min(ds.n()).max(1);
        Self::start_with_plan(ShardPlan::Memory(shard::split(ds, p)), kernel, cfg)
    }

    /// Start over the in-process channel transport from a [`ShardPlan`].
    ///
    /// With [`ShardPlan::File`], each worker thread reads only its own
    /// byte range of the binary dataset file
    /// ([`loader::load_shard`](crate::data::loader::load_shard))
    /// before entering its message loop; a failed read surfaces through
    /// the normal worker-failure path during seeding. Worker state
    /// construction (including the kernel-diagonal pass) happens on the
    /// worker threads for both plans, so per-shard init runs in
    /// parallel.
    pub fn start_with_plan(
        plan: ShardPlan,
        kernel: Arc<dyn Kernel + Send + Sync>,
        cfg: OasisPConfig,
    ) -> Result<OasisPSession> {
        Self::start_with_transport(Box::new(ChannelTransport), plan, kernel, cfg)
    }

    /// Start the fleet over any [`Transport`] (in-process channels or
    /// TCP worker processes), replicate the seed state (identical RNG
    /// stream and rejection rule to the sequential sampler), and
    /// broadcast Init. Workers reply with their first shard argmaxes,
    /// which the first `step` will gather.
    pub fn start_with_transport(
        transport: Box<dyn Transport>,
        plan: ShardPlan,
        kernel: Arc<dyn Kernel + Send + Sync>,
        cfg: OasisPConfig,
    ) -> Result<OasisPSession> {
        let sw = Stopwatch::start();
        let n = plan.n();
        cfg.validate(n)?;
        let metrics = Arc::new(Metrics::default());
        let fleet = transport.start(TransportCtx {
            plan,
            kernel: kernel.clone(),
            cfg: cfg.clone(),
            metrics: metrics.clone(),
        })?;
        let p = fleet.p;
        metrics.register_workers(p);
        let capacity = cfg.max_cols.min(n);
        let mut session = OasisPSession {
            cfg,
            n,
            capacity,
            p,
            owned: shard::shard_ranges(n, p)
                .into_iter()
                .map(|r| vec![(r.start, r.end - r.start)])
                .collect(),
            alive: vec![true; p],
            epoch: 0,
            queue: VecDeque::new(),
            handles: fleet.handles,
            joins: fleet.joins,
            inbox: fleet.inbox,
            pending: RefCell::new(VecDeque::new()),
            worker_traces: RefCell::new(vec![(Vec::new(), 0); p]),
            recoverable: fleet.recoverable,
            tcp: fleet.tcp,
            metrics,
            trace: SelectionTrace::default(),
            z_sel: Vec::new(),
            d_scale: 0.0,
            resid_sum: None,
            d_sum: 0.0,
            exhausted: None,
            torn_down: false,
            busy_secs: 0.0,
        };
        if let Err(e) = session.init_seed(&kernel, &sw) {
            session.teardown();
            return Err(e);
        }
        session.busy_secs = sw.secs();
        Ok(session)
    }

    /// Seed selection, replicating the sequential sampler exactly, then
    /// the Init broadcast.
    fn init_seed(
        &mut self,
        kernel: &Arc<dyn Kernel + Send + Sync>,
        sw: &Stopwatch,
    ) -> Result<()> {
        let n = self.n;
        let l = self.capacity;
        let k0 = self.cfg.init_cols.min(l);
        let mut rng = Pcg64::new(self.cfg.seed);
        let seed_indices: Vec<usize>;
        let seed_points: Vec<Vec<f64>>;
        let winv0: Mat;
        loop {
            let cand = rng.sample_without_replacement(n, k0);
            // fetch candidate points from their owners
            let mut pts: Vec<Option<Vec<f64>>> = vec![None; k0];
            for (slot, &g) in cand.iter().enumerate() {
                let w = self.owner_of(g);
                if !self.handles[w].send(&ToWorker::FetchPoint { global_idx: g })
                {
                    bail!("worker {w} unavailable during seeding");
                }
                match self.recv_live()? {
                    FromWorker::Point { global_idx, point } => {
                        debug_assert_eq!(global_idx, g);
                        self.metrics.add_worker_columns(w);
                        pts[slot] = Some(point);
                    }
                    FromWorker::Failed { worker, message } => {
                        bail!("worker {worker} failed during seeding: {message}")
                    }
                    FromWorker::Gone { worker } => {
                        bail!("worker {worker} died during seeding")
                    }
                    other => bail!("unexpected message during seeding: {other:?}"),
                }
            }
            let pts: Vec<Vec<f64>> = pts.into_iter().map(Option::unwrap).collect();
            // W₀ from kernel evaluations on the gathered points — identical
            // values to the sequential sampler's fetched-column entries.
            let mut w = Mat::zeros(k0, k0);
            for i in 0..k0 {
                for j in 0..k0 {
                    *w.at_mut(i, j) = kernel.eval(&pts[i], &pts[j]);
                }
            }
            if let Some(inv) = crate::linalg::inverse(&w) {
                let cond = inv.max_abs() * w.max_abs();
                if cond.is_finite() && cond <= 1e12 {
                    seed_indices = cand;
                    seed_points = pts;
                    winv0 = inv;
                    break;
                }
            }
        }

        // broadcast Init — every worker replies with its first argmax
        self.z_sel = seed_points.clone();
        let init = ToWorker::Init {
            seed_indices: seed_indices.clone(),
            seed_points,
            winv0: winv0.data.clone(),
        };
        for h in &self.handles {
            if !h.send(&init) {
                bail!("worker {} unavailable at init", h.worker);
            }
        }
        for &g in &seed_indices {
            self.trace.order.push(g);
            self.trace.cum_secs.push(sw.secs());
            self.trace.deltas.push(f64::NAN);
        }
        Ok(())
    }

    fn owner_of(&self, g: usize) -> usize {
        self.owned
            .iter()
            .position(|rs| rs.iter().any(|&(s, l)| g >= s && g < s + l))
            .expect("index in range")
    }

    /// Read the live inbox: swallows heartbeats (refreshing last-seen
    /// ages), meters gather traffic, and — on TCP fleets — synthesizes
    /// [`FromWorker::Gone`] for any live worker whose heartbeats went
    /// stale past `cfg.timeout`. Errors if nothing at all arrives within
    /// the timeout.
    fn recv_live(&self) -> Result<FromWorker> {
        let deadline = Instant::now() + self.cfg.timeout;
        let tick = Duration::from_millis(200).min(self.cfg.timeout);
        loop {
            match self.inbox.recv_timeout(tick) {
                Ok(FromWorker::Heartbeat { worker }) => {
                    self.metrics.note_alive(worker);
                }
                Ok(msg @ FromWorker::TraceChunk { .. }) => {
                    // absorbed here, never surfaced to the selection
                    // loop — every caller keeps seeing only the message
                    // kinds it expects
                    let bytes = msg.payload_bytes();
                    self.metrics.add_gather(bytes);
                    if let FromWorker::TraceChunk { worker, events } = msg {
                        self.metrics.note_alive(worker);
                        self.metrics.add_worker_wire(worker, bytes);
                        self.metrics.add_worker_trace_chunk(worker);
                        self.absorb_trace_chunk(worker, events);
                    }
                }
                Ok(msg) => {
                    let bytes = msg.payload_bytes();
                    self.metrics.add_gather(bytes);
                    if let Some(w) = msg.worker_id() {
                        self.metrics.note_alive(w);
                        self.metrics.add_worker_wire(w, bytes);
                    }
                    return Ok(msg);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if self.tcp {
                        for w in 0..self.p {
                            if !self.alive[w] {
                                continue;
                            }
                            if let Some(age) = self.metrics.last_seen_age(w) {
                                if age > self.cfg.timeout {
                                    return Ok(FromWorker::Gone { worker: w });
                                }
                            }
                        }
                    }
                    if Instant::now() >= deadline {
                        bail!(
                            "leader recv: timed out after {:?} (worker died \
                             or deadlock)",
                            self.cfg.timeout
                        );
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    bail!(
                        "leader recv: channel disconnected (worker died or \
                         deadlock)"
                    )
                }
            }
        }
    }

    /// Store one worker's shipped trace events, bounded per worker by
    /// [`MAX_WORKER_TRACE_EVENTS`] (overflow counts as drops).
    fn absorb_trace_chunk(&self, worker: usize, events: Vec<OwnedEvent>) {
        let mut traces = self.worker_traces.borrow_mut();
        let Some((stored, dropped)) = traces.get_mut(worker) else {
            return; // unknown worker id on the wire — ignore
        };
        let room = MAX_WORKER_TRACE_EVENTS.saturating_sub(stored.len());
        if events.len() > room {
            *dropped += (events.len() - room) as u64;
        }
        stored.extend(events.into_iter().take(room));
    }

    /// Next message for the selection loop: messages stashed by a mid-run
    /// snapshot are replayed before the live inbox is read.
    fn next_msg(&self) -> Result<FromWorker> {
        if let Some(m) = self.pending.borrow_mut().pop_front() {
            return Ok(m);
        }
        self.recv_live()
    }

    /// Re-shard a dead worker's rows onto the survivors (no-op if the
    /// worker was already recovered). Splits each lost range near-evenly
    /// across the survivors, bumps the epoch, and broadcasts
    /// [`ToWorker::Adopt`] — with `want_argmax` — to every survivor so
    /// the whole fleet advances together and restarts the interrupted
    /// gather round. Returns true if a recovery actually happened.
    fn recover(&mut self, dead: usize) -> Result<bool> {
        if !self.alive[dead] {
            return Ok(false);
        }
        self.alive[dead] = false;
        self.metrics.mark_dead(dead);
        let ranges = std::mem::take(&mut self.owned[dead]);
        let survivors: Vec<usize> =
            (0..self.p).filter(|&w| self.alive[w]).collect();
        if survivors.is_empty() {
            bail!("worker {dead} died and no workers survive");
        }
        let _span = crate::obs::span("reshard", "coordinator");
        self.metrics.add_reshard();
        // split each lost range into near-equal chunks, dealt round-robin
        let mut parts: Vec<(usize, usize)> = Vec::new();
        for (start, len) in ranges {
            let m = survivors.len().min(len.max(1));
            let (base, extra) = (len / m, len % m);
            let mut s = start;
            for i in 0..m {
                let l = base + usize::from(i < extra);
                if l > 0 {
                    parts.push((s, l));
                    s += l;
                }
            }
        }
        let mut gained: Vec<Vec<(usize, usize)>> = vec![Vec::new(); self.p];
        for (i, part) in parts.into_iter().enumerate() {
            let w = survivors[i % survivors.len()];
            gained[w].push(part);
            self.owned[w].push(part);
            self.metrics.add_worker_reshard(w);
        }
        self.epoch += 1;
        for &w in &survivors {
            let msg = ToWorker::Adopt {
                epoch: self.epoch,
                ranges: std::mem::take(&mut gained[w]),
                selected: self.trace.order.clone(),
                want_argmax: true,
            };
            if !self.handles[w].send(&msg) {
                bail!("worker {w} became unavailable during re-shard");
            }
        }
        Ok(true)
    }

    /// Death signal in the selection loop: recover if possible, else bail
    /// with the in-memory-plan diagnostic. Returns true if the fleet was
    /// actually re-sharded (→ the caller restarts its round).
    fn on_death(&mut self, worker: usize) -> Result<bool> {
        if !self.recoverable {
            bail!(
                "worker {worker} died mid-run (in-memory shards cannot be \
                 re-assigned — only file-backed runs recover)"
            );
        }
        self.recover(worker)
    }

    /// One gather round: collect an epoch-current argmax from every live
    /// worker (restarting after any mid-round death/recovery), merge the
    /// candidate lists, and queue up to `merge_batch` picks. Returns the
    /// stop reason when the merged best falls below tolerance or every
    /// shard is exhausted.
    fn argmax_round(&mut self) -> Result<Option<StopReason>> {
        'round: loop {
            let gather_span = crate::obs::span("gather", "coordinator");
            let mut got = vec![false; self.p];
            let mut need = self.alive.iter().filter(|&&a| a).count();
            let mut cands: Vec<(usize, f64)> = Vec::new();
            let mut round_resid = 0.0f64;
            let mut round_d_sum = 0.0f64;
            while need > 0 {
                match self.next_msg()? {
                    FromWorker::Argmax {
                        worker,
                        epoch,
                        candidates,
                        d_max,
                        sum_abs_delta,
                        d_sum,
                    } => {
                        if epoch != self.epoch
                            || !self.alive[worker]
                            || got[worker]
                        {
                            continue; // pre-re-shard stragglers
                        }
                        got[worker] = true;
                        need -= 1;
                        self.d_scale = self.d_scale.max(d_max);
                        round_resid += sum_abs_delta;
                        round_d_sum += d_sum;
                        cands.extend(candidates);
                        self.metrics.add_worker_argmax(worker);
                    }
                    FromWorker::Failed { worker, message } => {
                        bail!("worker {worker} failed: {message}")
                    }
                    FromWorker::Gone { worker } => {
                        if self.on_death(worker)? {
                            continue 'round; // fresh argmaxes are coming
                        }
                    }
                    FromWorker::Point { .. } => {
                        // stale fetch reply from a round a re-shard cut
                        // short — drop it
                    }
                    other => {
                        bail!("unexpected message in argmax round: {other:?}")
                    }
                }
            }
            drop(gather_span);
            let _arbitrate = crate::obs::span("arbitrate", "coordinator");
            self.metrics.add_iteration();
            self.resid_sum = Some(round_resid);
            self.d_sum = round_d_sum;
            // merge: |Δ| descending, global index ascending on ties —
            // the same total order the sequential sampler induces
            cands.sort_by(|a, b| {
                b.1.abs()
                    .partial_cmp(&a.1.abs())
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| a.0.cmp(&b.0))
            });
            let tol =
                crate::sampling::effective_tol(self.cfg.tol, &[self.d_scale]);
            if cands.is_empty() {
                return Ok(Some(StopReason::Exhausted));
            }
            if cands[0].1.abs() < tol {
                return Ok(Some(StopReason::ScoreBelowTol));
            }
            let room = self.capacity - self.trace.order.len();
            let take = self.cfg.merge_batch.min(room);
            for (i, &(g, dv)) in cands.iter().take(take).enumerate() {
                if dv.abs() < tol {
                    break;
                }
                self.queue.push_back(Pick { g, delta: dv, fresh: i == 0 });
            }
            return Ok(None);
        }
    }

    /// Apply one queued pick: fetch the winning point from its owner and
    /// broadcast it. Returns false when a death forced a re-shard that
    /// invalidated the (cleared) queue before the pick could be applied —
    /// the caller re-gathers. A death detected *after* the survivors
    /// already incorporated the pick keeps the pick (and still clears the
    /// rest of the queue).
    fn apply_pick(&mut self, pick: &Pick, want_argmax: bool) -> Result<bool> {
        let w = self.owner_of(pick.g);
        if !self.handles[w].send(&ToWorker::FetchPoint { global_idx: pick.g }) {
            if !self.recoverable {
                bail!("worker {w} unavailable (fetch)");
            }
            self.on_death(w)?;
            self.queue.clear();
            return Ok(false);
        }
        let mut point: Option<Vec<f64>> = None;
        loop {
            match self.next_msg()? {
                FromWorker::Point { global_idx, point: pt } => {
                    debug_assert_eq!(global_idx, pick.g);
                    self.metrics.add_worker_columns(w);
                    point = Some(pt);
                    break;
                }
                FromWorker::Failed { worker, message } => {
                    bail!("worker {worker} failed: {message}")
                }
                FromWorker::Gone { worker } => {
                    let owner_died = worker == w;
                    if !self.on_death(worker)? {
                        continue;
                    }
                    if !owner_died {
                        // the owner is alive: its Point reply may still
                        // be in flight ahead of its post-Adopt argmax —
                        // drain up to it, stashing current-epoch argmaxes
                        // for the re-gather
                        loop {
                            match self.recv_live()? {
                                FromWorker::Point { .. } => break,
                                msg @ FromWorker::Argmax { .. } => {
                                    let current = matches!(
                                        &msg,
                                        FromWorker::Argmax { epoch, .. }
                                            if *epoch == self.epoch
                                    );
                                    if current {
                                        self.pending
                                            .borrow_mut()
                                            .push_back(msg);
                                    }
                                }
                                FromWorker::Failed { worker, message } => bail!(
                                    "worker {worker} failed: {message}"
                                ),
                                FromWorker::Gone { worker: w2 } => {
                                    self.on_death(w2)?;
                                    if w2 == w {
                                        break; // owner gone, no Point coming
                                    }
                                }
                                other => bail!(
                                    "unexpected message draining a stale \
                                     fetch: {other:?}"
                                ),
                            }
                        }
                    }
                    self.queue.clear();
                    return Ok(false);
                }
                other => bail!("unexpected message awaiting point: {other:?}"),
            }
        }
        let point = point.expect("loop breaks only with a point");
        // broadcast the selected point — the paper's one-vector-per-step
        // communication pattern; the batch's last pick also requests the
        // next argmax sweep
        self.z_sel.push(point.clone());
        let msg = ToWorker::Selected {
            global_idx: pick.g,
            point,
            delta: pick.fresh.then_some(pick.delta),
            epoch: self.epoch,
            want_argmax,
        };
        let mut dead: Vec<usize> = Vec::new();
        for h in &self.handles {
            if !self.alive[h.worker] {
                continue;
            }
            if !h.send(&msg) {
                dead.push(h.worker);
            }
        }
        if !dead.is_empty() {
            if !self.recoverable {
                bail!("worker {} unavailable (broadcast)", dead[0]);
            }
            // every survivor already incorporated the pick (sends to them
            // succeeded), so the pick stands; the rest of the queue is
            // re-arbitrated after the re-shard
            for d in dead {
                self.on_death(d)?;
            }
            self.queue.clear();
        }
        Ok(true)
    }

    /// Gather the k-column blocks (and the directed worker's W⁻¹) from
    /// every live worker. `terminal` sends Finish and consumes stashed
    /// argmax replies as stale; the mid-run mode sends GatherColumns,
    /// reads the live inbox only, and stashes in-flight argmaxes for the
    /// next `step`. Completion is row-coverage-based (`Σ local_n == n`),
    /// so post-re-shard fleets — where a worker answers with several
    /// segment blocks — gather exactly like pristine ones.
    fn gather_columns(&self, k: usize, terminal: bool) -> Result<(Mat, Mat)> {
        let _span = crate::obs::span("column_gather", "coordinator");
        let winv_from = (0..self.p)
            .find(|&w| self.alive[w])
            .ok_or_else(|| anyhow!("no live workers to gather from"))?;
        for h in &self.handles {
            if !self.alive[h.worker] {
                continue;
            }
            let msg = if terminal {
                ToWorker::Finish { winv: h.worker == winv_from }
            } else {
                ToWorker::GatherColumns { winv: h.worker == winv_from }
            };
            if !h.send(&msg) {
                bail!(
                    "worker {} unavailable ({})",
                    h.worker,
                    if terminal { "finish" } else { "snapshot gather" }
                );
            }
        }
        let n = self.n;
        let mut c = Mat::zeros(n, k);
        let mut winv: Option<Mat> = None;
        let mut rows = 0usize;
        while rows < n || winv.is_none() {
            let msg = if terminal { self.next_msg()? } else { self.recv_live()? };
            match msg {
                FromWorker::Columns {
                    worker,
                    start,
                    local_n,
                    c_block,
                    winv: w,
                } => {
                    for i in 0..local_n {
                        c.data[(start + i) * k..(start + i + 1) * k]
                            .copy_from_slice(&c_block[i * k..(i + 1) * k]);
                    }
                    if let Some(wd) = w {
                        winv = Some(Mat::from_vec(k, k, wd));
                    }
                    rows += local_n;
                    self.metrics.add_worker_columns(worker);
                }
                msg @ FromWorker::Argmax { .. } => {
                    if !terminal {
                        self.pending.borrow_mut().push_back(msg);
                    }
                }
                FromWorker::Point { .. } => {
                    // stale fetch reply from a round a re-shard cut short
                }
                FromWorker::Failed { worker, message } => {
                    bail!("worker {worker} failed during column gather: {message}")
                }
                FromWorker::Gone { worker } => {
                    bail!("worker {worker} died during column gather")
                }
                FromWorker::Heartbeat { .. } => {}
                // unreachable: recv_live absorbs chunks before they
                // surface — kept for match exhaustiveness
                FromWorker::TraceChunk { .. } => {}
            }
        }
        let winv = winv.ok_or_else(|| anyhow!("no W⁻¹ returned"))?;
        Ok((c, winv))
    }

    /// Send Finish to every live worker and join the threads (idempotent).
    fn teardown(&mut self) {
        if self.torn_down {
            return;
        }
        self.torn_down = true;
        for h in &self.handles {
            if self.alive[h.worker] {
                h.send(&ToWorker::Finish { winv: false });
            }
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }

    /// Finish the run: gather the column blocks and W⁻¹ replica, join the
    /// workers, and return the approximation plus the run report.
    pub fn finish_run(mut self) -> Result<(NystromApprox, OasisPReport)> {
        let sw = Stopwatch::start();
        let k = self.trace.order.len();
        // terminal gather: stale Argmax replies (stashed or live) are
        // drained and discarded
        let (c, winv) = self.gather_columns(k, true)?;
        self.torn_down = true;
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        // joined reader threads have forwarded everything the workers
        // sent before exiting — absorb the final trace chunks (workers
        // flush once more right after their terminal Columns)
        while let Ok(msg) = self.inbox.try_recv() {
            if let FromWorker::TraceChunk { worker, events } = msg {
                self.metrics.add_worker_trace_chunk(worker);
                self.absorb_trace_chunk(worker, events);
            }
        }
        let worker_traces: Vec<TraceTrack> = self
            .worker_traces
            .borrow_mut()
            .drain(..)
            .enumerate()
            .map(|(w, (events, dropped))| TraceTrack {
                // pid 1 is the leader's own track by convention
                pid: w as u64 + 2,
                label: format!("worker-{w}"),
                events,
                dropped,
            })
            .filter(|t| !t.events.is_empty() || t.dropped > 0)
            .collect();
        self.busy_secs += sw.secs();
        let report = OasisPReport {
            trace: self.trace.clone(),
            metrics: self.metrics.clone(),
            workers: self.p,
            wall_secs: self.busy_secs,
            worker_traces,
        };
        Ok((
            NystromApprox {
                indices: self.trace.order.clone(),
                c,
                winv,
                selection_secs: self.busy_secs,
            },
            report,
        ))
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }
}

impl SamplerSession for OasisPSession {
    fn name(&self) -> &'static str {
        "oASIS-P"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn indices(&self) -> &[usize] {
        &self.trace.order
    }

    fn trace(&self) -> &SelectionTrace {
        &self.trace
    }

    fn selection_secs(&self) -> f64 {
        self.busy_secs
    }

    /// Residual trace ratio `Σ|Δᵢ| / Σ|dᵢ|`, aggregated from the shard
    /// sums the workers piggyback on every argmax gather. `None` before
    /// the first gather round.
    fn error_estimate(&self) -> Option<f64> {
        let resid = self.resid_sum?;
        if self.d_sum <= 0.0 {
            return Some(0.0);
        }
        Some(resid / self.d_sum)
    }

    /// The leader's Z_Λ mirror (see the field docs on `z_sel`): lets
    /// callers that hold no dataset — shard-read deployments — answer
    /// extension queries and save artifacts, which only ever touch the
    /// selected points.
    fn selected_points(&self, from: usize) -> Option<Vec<Vec<f64>>> {
        Some(self.z_sel[from.min(self.z_sel.len())..].to_vec())
    }

    /// Per-worker coordinator counters for the serving stack's
    /// `/metrics` endpoint.
    fn worker_stats(&self) -> Option<crate::util::json::Json> {
        Some(self.metrics.worker_stats_json())
    }

    /// One distributed selection: pop the next arbitrated pick (running a
    /// gather → merge round first if the queue is empty), fetch the
    /// winning point from its owner, broadcast it. At `merge_batch == 1`
    /// this is exactly the paper's one-gathered-scalar + one-broadcast-
    /// vector round per iteration; larger batches apply several picks per
    /// gather round (`trace.deltas` records the gathered Δ, which for
    /// queued picks is the pre-batch value — the workers recompute the
    /// exact Δ' locally).
    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.exhausted {
            return Ok(StepOutcome::Exhausted(reason));
        }
        let sw = Stopwatch::start();
        if self.trace.order.len() >= self.capacity {
            // the workers' W⁻¹ replicas are allocated at cfg.max_cols
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        loop {
            if self.queue.is_empty() {
                if let Some(reason) = self.argmax_round()? {
                    self.exhausted = Some(reason);
                    self.busy_secs += sw.secs();
                    return Ok(StepOutcome::Exhausted(reason));
                }
            }
            let want_argmax = self.queue.len() == 1;
            let pick = self.queue.pop_front().expect("round queued picks");
            if self.apply_pick(&pick, want_argmax)? {
                self.trace.order.push(pick.g);
                self.trace.cum_secs.push(self.busy_secs + sw.secs());
                self.trace.deltas.push(pick.delta.abs());
                self.busy_secs += sw.secs();
                return Ok(StepOutcome::Selected {
                    index: pick.g,
                    score: pick.delta.abs(),
                });
            }
            // a re-shard invalidated the queue before the pick applied —
            // re-gather under the new epoch
        }
    }

    /// Mid-run snapshot via a non-terminal column gather
    /// ([`ToWorker::GatherColumns`]): every live worker replies with its
    /// current C block(s) (the directed worker also its W⁻¹ replica) and
    /// keeps running, so the session can continue stepping afterwards.
    /// Argmax replies already in flight from the last broadcast are
    /// stashed and replayed to the next `step` — per-worker links are
    /// FIFO, so each worker has incorporated every selection before it
    /// serves the gather and the snapshot is always a consistent
    /// k-column prefix. Snapshot time is deliberately not charged to
    /// `selection_secs` (it is serving work, not selection).
    fn snapshot(&self) -> Result<NystromApprox> {
        if self.torn_down {
            bail!("oASIS-P session already torn down");
        }
        let k = self.trace.order.len();
        let (c, winv) = self.gather_columns(k, false)?;
        Ok(NystromApprox {
            indices: self.trace.order.clone(),
            c,
            winv,
            selection_secs: self.busy_secs,
        })
    }

    fn finish(self: Box<Self>) -> Result<NystromApprox> {
        self.finish_run().map(|(a, _)| a)
    }
}

impl Drop for OasisPSession {
    fn drop(&mut self) {
        self.teardown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;

    #[test]
    fn single_worker_runs() {
        let ds = two_moons(60, 0.05, 1);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let cfg = OasisPConfig::new(12, 3, 1).with_seed(5);
        let (approx, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
        assert_eq!(approx.k(), 12);
        assert_eq!(report.trace.order.len(), 12);
        assert!(report.metrics.iterations() >= 9);
    }

    #[test]
    fn communication_is_one_point_per_step() {
        // Broadcast volume per iteration ≈ p × (dim×8 + header) bytes:
        // the paper's "size of the communicated vector is the
        // dimensionality of the data point".
        let ds = two_moons(100, 0.05, 2);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let p = 4;
        let cfg = OasisPConfig::new(20, 4, p).with_seed(3);
        let (_, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
        let adaptive_steps = 16; // 20 − 4 seeds
        let per_step = (2 * 8 + 26) * p; // dim=2 point + header, per worker
        let bound = (per_step * adaptive_steps * 4) as u64; // generous ×4
        assert!(
            report.metrics.broadcast_bytes() < bound,
            "broadcast {} ≥ bound {}",
            report.metrics.broadcast_bytes(),
            bound
        );
    }

    /// Dropping a live session (external stop without finish) must not
    /// deadlock or leak worker threads.
    #[test]
    fn dropping_live_session_joins_workers() {
        let ds = two_moons(80, 0.05, 4);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let cfg = OasisPConfig::new(20, 3, 3).with_seed(2);
        let mut session = OasisPSession::start(&ds, kernel, cfg).unwrap();
        for _ in 0..4 {
            session.step().unwrap();
        }
        drop(session); // teardown must complete promptly
    }

    /// A mid-run snapshot is a consistent prefix of the run — and taking
    /// it does not disturb subsequent selection: the finished run is
    /// bit-identical to an uninterrupted one.
    #[test]
    fn mid_run_snapshot_matches_prefix_and_run_continues() {
        let ds = two_moons(100, 0.05, 3);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let cfg = OasisPConfig::new(24, 4, 3).with_seed(9);
        let (reference, _) =
            run_oasis_p(&ds, kernel.clone(), &cfg.clone()).unwrap();

        let mut session = OasisPSession::start(&ds, kernel, cfg).unwrap();
        for _ in 0..6 {
            session.step().unwrap();
        }
        let snap = session.snapshot().unwrap();
        assert_eq!(snap.k(), session.k());
        assert_eq!(snap.indices, &reference.indices[..snap.k()]);
        // the gathered factors are a real Nyström state: W·W⁻¹ ≈ I
        let w = snap.c.select_rows(&snap.indices);
        let prod = w.matmul(&snap.winv);
        assert!(
            prod.fro_dist(&Mat::eye(snap.k())) < 1e-6,
            "‖W·W⁻¹−I‖ = {}",
            prod.fro_dist(&Mat::eye(snap.k()))
        );
        // snapshot C columns are the reference's prefix, bit for bit
        for i in 0..snap.n() {
            for t in 0..snap.k() {
                assert_eq!(snap.c.at(i, t), reference.c.at(i, t));
            }
        }
        // continue to the budget: identical to the uninterrupted run
        run_to_completion(&mut session, &StoppingRule::budget(24)).unwrap();
        let (fin, _) = session.finish_run().unwrap();
        assert_eq!(fin.indices, reference.indices);
        assert_eq!(fin.c.data, reference.c.data);
        assert_eq!(fin.winv.data, reference.winv.data);
    }

    /// The distributed error estimate is populated after the first round
    /// and decreases as columns accumulate.
    #[test]
    fn distributed_error_estimate_progresses() {
        let ds = two_moons(120, 0.05, 8);
        let kernel: Arc<dyn Kernel + Send + Sync> =
            Arc::new(Gaussian::with_sigma_fraction(&ds, 0.1));
        let cfg = OasisPConfig::new(30, 4, 3).with_seed(6);
        let mut session = OasisPSession::start(&ds, kernel, cfg).unwrap();
        assert!(session.error_estimate().is_none());
        session.step().unwrap();
        let e0 = session.error_estimate().unwrap();
        run_to_completion(&mut session, &StoppingRule::budget(30)).unwrap();
        let e1 = session.error_estimate().unwrap();
        assert!(e1 < e0, "estimate did not decrease: {e0} → {e1}");
        let (approx, _) = session.finish_run().unwrap();
        assert_eq!(approx.k(), 30);
    }

    /// SQUEAK-style merge batching: a B>1 run reaches the same budget
    /// with fewer argmax rounds (one per batch instead of one per
    /// column), and its factors are a valid Nyström state.
    #[test]
    fn merge_batch_cuts_argmax_rounds() {
        let ds = two_moons(120, 0.05, 5);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let cfg = OasisPConfig::new(24, 4, 3).with_seed(11).with_merge_batch(4);
        let (approx, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
        assert_eq!(approx.k(), 24);
        // 20 adaptive picks in batches of ≤4 → ≥5 and well under 20 rounds
        assert!(
            report.metrics.iterations() < 20,
            "expected batched rounds, got {}",
            report.metrics.iterations()
        );
        let w = approx.c.select_rows(&approx.indices);
        let prod = w.matmul(&approx.winv);
        assert!(
            prod.fro_dist(&Mat::eye(approx.k())) < 1e-6,
            "‖W·W⁻¹−I‖ = {}",
            prod.fro_dist(&Mat::eye(approx.k()))
        );
        // selected indices are distinct
        let mut sorted = approx.indices.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 24);
    }

    /// merge_batch == 1 (the default) must stay bit-identical to the
    /// protocol without batching — guarded against the reference run.
    #[test]
    fn merge_batch_one_matches_reference() {
        let ds = two_moons(90, 0.05, 7);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let base = OasisPConfig::new(18, 3, 3).with_seed(4);
        let (a, _) = run_oasis_p(&ds, kernel.clone(), &base).unwrap();
        let (b, _) =
            run_oasis_p(&ds, kernel, &base.with_merge_batch(1)).unwrap();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.c.data, b.c.data);
        assert_eq!(a.winv.data, b.winv.data);
    }
}
