//! The oASIS-P leader: seeds the run, reduces gathered shard argmaxes,
//! broadcasts selected points, detects worker failure, and assembles the
//! final Nyström approximation from the gathered column blocks.

use super::comm::{FromWorker, LeaderHandle, ToWorker, WorkerHandle};
use super::config::OasisPConfig;
use super::metrics::Metrics;
use super::worker::Worker;
use crate::data::{shard, Dataset};
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::sampling::SelectionTrace;
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::Result;
use anyhow::{anyhow, bail};
use std::sync::mpsc;
use std::sync::Arc;

/// Outcome report of a distributed run.
#[derive(Debug)]
pub struct OasisPReport {
    pub trace: SelectionTrace,
    pub metrics: Arc<Metrics>,
    pub workers: usize,
    pub wall_secs: f64,
}

/// Run oASIS-P over `cfg.workers` threads. The selection sequence is
/// identical to the sequential [`crate::sampling::oasis::Oasis`] sampler
/// with the same seed/tolerance (PaperR variant semantics).
pub fn run_oasis_p(
    ds: &Dataset,
    kernel: Arc<dyn Kernel + Send + Sync>,
    cfg: &OasisPConfig,
) -> Result<(NystromApprox, OasisPReport)> {
    let sw = Stopwatch::start();
    let n = ds.n();
    cfg.validate(n)?;
    let p = cfg.workers.min(n);
    let metrics = Arc::new(Metrics::default());

    // --- spawn workers ---
    let (to_leader_tx, leader_inbox) = mpsc::channel::<FromWorker>();
    let mut handles = Vec::with_capacity(p);
    let mut joins = Vec::with_capacity(p);
    for s in shard::split(ds, p) {
        let (tx, rx) = mpsc::channel::<ToWorker>();
        handles.push(WorkerHandle::new(s.worker, tx, metrics.clone()));
        let worker = Worker::new(
            s.worker,
            s,
            kernel.clone(),
            LeaderHandle::new(to_leader_tx.clone(), metrics.clone()),
            metrics.clone(),
            cfg.max_cols,
            cfg.failure,
        );
        joins.push(std::thread::spawn(move || worker.run(rx)));
    }
    drop(to_leader_tx);

    let run = leader_loop(ds, &kernel, cfg, p, &metrics, &handles, &leader_inbox, &sw);

    // tear down: on error paths make sure workers exit
    if run.is_err() {
        for h in &handles {
            h.send(ToWorker::Finish);
        }
    }
    for j in joins {
        let _ = j.join();
    }
    let (approx, trace) = run?;
    let report = OasisPReport {
        trace,
        metrics,
        workers: p,
        wall_secs: sw.secs(),
    };
    Ok((approx, report))
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    ds: &Dataset,
    kernel: &Arc<dyn Kernel + Send + Sync>,
    cfg: &OasisPConfig,
    p: usize,
    metrics: &Arc<Metrics>,
    handles: &[WorkerHandle],
    inbox: &mpsc::Receiver<FromWorker>,
    sw: &Stopwatch,
) -> Result<(NystromApprox, SelectionTrace)> {
    let n = ds.n();
    let l = cfg.max_cols.min(n);
    let k0 = cfg.init_cols.min(l);
    let owner_of = |g: usize| -> usize {
        shard::shard_ranges(n, p)
            .iter()
            .position(|r| r.contains(&g))
            .expect("index in range")
    };

    // --- seed selection (replicates the sequential sampler exactly) ---
    let mut rng = Pcg64::new(cfg.seed);
    let seed_indices: Vec<usize>;
    let seed_points: Vec<Vec<f64>>;
    let winv0: Mat;
    loop {
        let cand = rng.sample_without_replacement(n, k0);
        // fetch candidate points from their owners
        let mut pts: Vec<Option<Vec<f64>>> = vec![None; k0];
        for (slot, &g) in cand.iter().enumerate() {
            let w = owner_of(g);
            if !handles[w].send(ToWorker::FetchPoint { global_idx: g }) {
                bail!("worker {w} unavailable during seeding");
            }
            let msg = recv(inbox, cfg)?;
            match msg {
                FromWorker::Point { global_idx, point } => {
                    debug_assert_eq!(global_idx, g);
                    pts[slot] = Some(point);
                }
                FromWorker::Failed { worker, message } => {
                    bail!("worker {worker} failed during seeding: {message}")
                }
                other => bail!("unexpected message during seeding: {other:?}"),
            }
        }
        let pts: Vec<Vec<f64>> = pts.into_iter().map(Option::unwrap).collect();
        // W₀ from kernel evaluations on the gathered points — identical
        // values to the sequential sampler's fetched-column entries.
        let mut w = Mat::zeros(k0, k0);
        for i in 0..k0 {
            for j in 0..k0 {
                *w.at_mut(i, j) = kernel.eval(&pts[i], &pts[j]);
            }
        }
        if let Some(inv) = crate::linalg::inverse(&w) {
            let cond = inv.max_abs() * w.max_abs();
            if cond.is_finite() && cond <= 1e12 {
                seed_indices = cand;
                seed_points = pts;
                winv0 = inv;
                break;
            }
        }
    }

    // broadcast Init
    let init = ToWorker::Init {
        seed_indices: seed_indices.clone(),
        seed_points: seed_points.clone(),
        winv0: winv0.data.clone(),
    };
    for h in handles {
        if !h.send(init.clone()) {
            bail!("worker {} unavailable at init", h.worker);
        }
    }

    let mut trace = SelectionTrace::default();
    let mut lambda = seed_indices.clone();
    let mut z_sel = seed_points;
    for &g in &lambda {
        trace.order.push(g);
        trace.cum_secs.push(sw.secs());
        trace.deltas.push(f64::NAN);
    }

    // --- main selection loop ---
    let mut d_scale = 0.0f64;
    while lambda.len() < l {
        // gather shard argmaxes
        let mut best: Option<(usize, f64)> = None; // (global idx, signed Δ)
        for _ in 0..p {
            match recv(inbox, cfg)? {
                FromWorker::Argmax { best: wb, d_max, .. } => {
                    d_scale = d_scale.max(d_max);
                    if let Some((gi, dv)) = wb {
                        let replace = match best {
                            None => true,
                            Some((bg, bd)) => {
                                let (a, b) = (dv.abs(), bd.abs());
                                a > b || (a == b && gi < bg)
                            }
                        };
                        if replace {
                            best = Some((gi, dv));
                        }
                    }
                }
                FromWorker::Failed { worker, message } => {
                    bail!("worker {worker} failed: {message}")
                }
                other => bail!("unexpected message in main loop: {other:?}"),
            }
        }
        metrics.add_iteration();
        let tol = crate::sampling::effective_tol(cfg.tol, &[d_scale]);
        let (gidx, dval) = match best {
            Some(b) if b.1.abs() >= tol => b,
            _ => break, // tolerance reached or all shards exhausted
        };
        // fetch the winning point from its owner
        let w = owner_of(gidx);
        if !handles[w].send(ToWorker::FetchPoint { global_idx: gidx }) {
            bail!("worker {w} unavailable (fetch)");
        }
        let point = loop {
            match recv(inbox, cfg)? {
                FromWorker::Point { global_idx, point } => {
                    debug_assert_eq!(global_idx, gidx);
                    break point;
                }
                FromWorker::Failed { worker, message } => {
                    bail!("worker {worker} failed: {message}")
                }
                other => bail!("unexpected message awaiting point: {other:?}"),
            }
        };
        // broadcast the selected point — the paper's one-vector-per-step
        // communication pattern
        let msg = ToWorker::Selected {
            global_idx: gidx,
            point: point.clone(),
            delta: dval,
        };
        for h in handles {
            if !h.send(msg.clone()) {
                bail!("worker {} unavailable (broadcast)", h.worker);
            }
        }
        lambda.push(gidx);
        z_sel.push(point);
        trace.order.push(gidx);
        trace.cum_secs.push(sw.secs());
        trace.deltas.push(dval.abs());
    }

    // --- finish: gather C blocks and the W⁻¹ replica ---
    for h in handles {
        if !h.send(ToWorker::Finish) {
            bail!("worker {} unavailable (finish)", h.worker);
        }
    }
    let k = lambda.len();
    let mut c = Mat::zeros(n, k);
    let mut winv: Option<Mat> = None;
    let mut got = 0;
    // drain remaining Argmax replies interleaved with Columns
    while got < p {
        match recv(inbox, cfg)? {
            FromWorker::Columns { start, local_n, c_block, winv: w, .. } => {
                for i in 0..local_n {
                    let dst = &mut c.data[(start + i) * k..(start + i + 1) * k];
                    dst.copy_from_slice(&c_block[i * k..(i + 1) * k]);
                }
                if let Some(wd) = w {
                    winv = Some(Mat::from_vec(k, k, wd));
                }
                got += 1;
            }
            FromWorker::Argmax { .. } => {} // stale replies from last round
            FromWorker::Failed { worker, message } => {
                bail!("worker {worker} failed at finish: {message}")
            }
            other => bail!("unexpected message at finish: {other:?}"),
        }
    }
    let winv = winv.ok_or_else(|| anyhow!("no W⁻¹ returned by worker 0"))?;
    Ok((
        NystromApprox {
            indices: lambda,
            c,
            winv,
            selection_secs: sw.secs(),
        },
        trace,
    ))
}

fn recv(
    inbox: &mpsc::Receiver<FromWorker>,
    cfg: &OasisPConfig,
) -> Result<FromWorker> {
    inbox
        .recv_timeout(cfg.timeout)
        .map_err(|e| anyhow!("leader recv: {e} (worker died or deadlock)"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;

    #[test]
    fn single_worker_runs() {
        let ds = two_moons(60, 0.05, 1);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let cfg = OasisPConfig::new(12, 3, 1).with_seed(5);
        let (approx, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
        assert_eq!(approx.k(), 12);
        assert_eq!(report.trace.order.len(), 12);
        assert!(report.metrics.iterations() >= 9);
    }

    #[test]
    fn communication_is_one_point_per_step() {
        // Broadcast volume per iteration ≈ p × (dim×8 + 16) bytes: the
        // paper's "size of the communicated vector is the dimensionality
        // of the data point".
        let ds = two_moons(100, 0.05, 2);
        let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
        let p = 4;
        let cfg = OasisPConfig::new(20, 4, p).with_seed(3);
        let (_, report) = run_oasis_p(&ds, kernel, &cfg).unwrap();
        let adaptive_steps = 16; // 20 − 4 seeds
        let per_step = (2 * 8 + 16) * p; // dim=2 point + header, per worker
        let bound = (per_step * adaptive_steps * 4) as u64; // generous ×4
        assert!(
            report.metrics.broadcast_bytes() < bound,
            "broadcast {} ≥ bound {}",
            report.metrics.broadcast_bytes(),
            bound
        );
    }
}
