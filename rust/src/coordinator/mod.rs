//! oASIS-P — the distributed leader/worker runtime (paper Alg. 2, §III-C).
//!
//! The paper runs oASIS over p MPI nodes: the dataset is sharded
//! column-wise, every node keeps its slice of C and R plus a replica of
//! W⁻¹ and Z_Λ, and each iteration exchanges exactly one gathered Δ-argmax
//! and one broadcast data point — the low-communication property that makes
//! the method practical at millions of points.
//!
//! Two deployments share one coordinator, through the [`transport`] seam:
//!
//! * **In-process** ([`transport::ChannelTransport`]): each "node" is an
//!   OS thread with private state; MPI's `Broadcast`/`Gather` become
//!   explicit message channels ([`comm`]) whose payload bytes are metered
//!   ([`metrics`]).
//! * **Multi-process** ([`net::TcpTransport`]): each node is a separate
//!   `oasis worker --join HOST:PORT` process that shard-reads its own
//!   byte range of the dataset file and speaks the TCP wire protocol
//!   below.
//!
//! Either way the selection sequence is bit-identical to the sequential
//! sampler for every worker count (tested in
//! rust/tests/coordinator_dist.rs), and Table III's communication-bound
//! behaviour is preserved and measurable.
//!
//! # Wire protocol (TCP transport)
//!
//! Every message is one length-framed, FNV-1a-64-checksummed frame
//! ([`crate::util::framing::write_frame`]):
//!
//! ```text
//! [u64 LE payload length][u64 LE fnv1a64(payload)][payload]
//! ```
//!
//! The payload is a tag byte plus little-endian fields; f64s travel as
//! raw bits (`to_bits`), which is what keeps TCP runs bit-identical to
//! in-process runs. Handshake: the worker connects, the leader sends
//! `Assign` (shard index, worker count, n, dataset path, load limits,
//! column budget, merge batch, kernel parameters as JSON, heartbeat
//! period, trace flag, and fleet run id), the worker shard-reads its
//! rows and answers `Joined` (the
//! row range it actually covers, verified against the plan), and the
//! selection loop begins with `Init`. See [`net`] for the full frame
//! catalogue and [`comm`] for message semantics.
//!
//! # Fault tolerance
//!
//! TCP workers send heartbeats from a timer thread; the leader tracks
//! per-worker last-seen ages ([`metrics`]) and treats reader-thread EOF,
//! socket/frame errors, or heartbeat staleness past the configured
//! timeout as a node death. A death during the selection loop on a
//! file-backed run triggers *re-sharding*: the leader bumps its epoch,
//! splits the dead worker's row ranges across the survivors
//! ([`comm::ToWorker::Adopt`]), discards stale in-flight replies by
//! epoch, and the run completes on the remaining workers. Deterministic
//! worker errors ([`comm::FromWorker::Failed`]) are always fatal — see
//! [`leader`] for the full semantics.

pub mod comm;
pub mod config;
pub mod leader;
pub mod metrics;
pub mod net;
pub mod transport;
pub mod worker;

pub use config::{FailureSpec, OasisPConfig};
pub use leader::{run_oasis_p, OasisPReport, OasisPSession, ShardPlan};
pub use metrics::Metrics;
pub use net::{run_worker, TcpTransport, WorkerRunOpts};
pub use transport::{ChannelTransport, Fleet, Transport, TransportCtx};
