//! oASIS-P — the distributed leader/worker runtime (paper Alg. 2, §III-C).
//!
//! The paper runs oASIS over p MPI nodes: the dataset is sharded
//! column-wise, every node keeps its slice of C and R plus a replica of
//! W⁻¹ and Z_Λ, and each iteration exchanges exactly one gathered Δ-argmax
//! and one broadcast data point — the low-communication property that makes
//! the method practical at millions of points.
//!
//! Here each "node" is an OS thread with private state; MPI's
//! `Broadcast`/`Gather` become explicit message channels ([`comm`]) whose
//! payload bytes are metered ([`metrics`]), so Table III's
//! communication-bound behaviour is preserved and measurable. The selection
//! sequence is bit-identical to the sequential sampler for every worker
//! count (tested in rust/tests/coordinator_dist.rs).

pub mod comm;
pub mod config;
pub mod leader;
pub mod metrics;
pub mod worker;

pub use config::{FailureSpec, OasisPConfig};
pub use leader::{run_oasis_p, OasisPReport, OasisPSession, ShardPlan};
pub use metrics::Metrics;
