//! The transport seam between the oASIS-P leader and its workers.
//!
//! A [`Transport`] turns a [`ShardPlan`] into a running worker fleet and
//! hands the leader a uniform view of it: per-worker outbound handles
//! plus one merged inbound channel. Two implementations exist:
//!
//! * [`ChannelTransport`] — the classic in-process setting: one thread
//!   per worker, mpsc channels both ways. Supports both shard plans.
//! * [`net::TcpTransport`](super::net::TcpTransport) — real worker
//!   *processes* (`oasis worker --join HOST:PORT`) on the far end of
//!   length-framed, FNV-checksummed TCP connections. Requires
//!   [`ShardPlan::File`] (each process shard-reads its own byte range)
//!   and a parameterized kernel (shipped in the `Assign` handshake).
//!
//! Because both transports produce the same [`Fleet`] shape, the leader's
//! entire selection/recovery logic — and every coordinator test — runs
//! unchanged against either.

use super::comm::{FromWorker, LeaderHandle, LeaderInbox, WorkerHandle};
use super::config::OasisPConfig;
use super::leader::ShardPlan;
use super::metrics::Metrics;
use super::worker::{Worker, WorkerOpts};
use crate::data::{loader, shard, Shard};
use crate::kernels::Kernel;
use crate::{anyhow, Result};
use std::sync::{mpsc, Arc};

/// Everything a transport needs to start the fleet.
pub struct TransportCtx {
    pub plan: ShardPlan,
    pub kernel: Arc<dyn Kernel + Send + Sync>,
    pub cfg: OasisPConfig,
    pub metrics: Arc<Metrics>,
}

/// A started worker fleet, as the leader sees it.
pub struct Fleet {
    /// worker count actually started (≤ cfg.workers for tiny datasets)
    pub p: usize,
    /// outbound handles, indexed by worker id
    pub handles: Vec<WorkerHandle>,
    /// merged inbound channel (both transports bridge into mpsc)
    pub inbox: LeaderInbox,
    /// threads to join at teardown (worker threads, or TCP reader
    /// threads whose sockets close when the workers exit)
    pub joins: Vec<std::thread::JoinHandle<()>>,
    /// whether a dead worker's rows can be re-sharded onto survivors
    /// (true exactly when workers can shard-read a dataset file)
    pub recoverable: bool,
    /// whether heartbeat staleness applies (TCP fleets only — thread
    /// workers share the process and send no heartbeats)
    pub tcp: bool,
}

/// One-shot fleet starter; see the module docs.
pub trait Transport {
    fn start(self: Box<Self>, ctx: TransportCtx) -> Result<Fleet>;
}

/// Worker count a plan yields under `cfg` (never more workers than rows,
/// never zero).
pub fn plan_workers(plan: &ShardPlan, cfg: &OasisPConfig) -> usize {
    match plan {
        ShardPlan::Memory(shards) => shards.len(),
        ShardPlan::File { n, .. } => cfg.workers.min(*n).max(1),
    }
}

/// In-process transport: one thread per worker, channels both ways.
pub struct ChannelTransport;

impl Transport for ChannelTransport {
    fn start(self: Box<Self>, ctx: TransportCtx) -> Result<Fleet> {
        let TransportCtx { plan, kernel, cfg, metrics } = ctx;
        let n = plan.n();
        let p = plan_workers(&plan, &cfg);
        let recoverable = matches!(plan, ShardPlan::File { .. });
        let (to_leader_tx, inbox) = mpsc::channel::<FromWorker>();
        let mut handles = Vec::new();
        let mut joins = Vec::new();
        // one spawn path for both plans: the worker thread obtains its
        // shard (already-split block, or its own byte-range read of the
        // file), constructs its state — including the kernel-diagonal
        // pass, so per-shard init runs in parallel — and enters its
        // message loop; an Err from the source surfaces at the leader's
        // next recv as a worker failure
        let mut spawn = |w: usize,
                         source: Box<dyn FnOnce() -> Result<Shard> + Send>,
                         opts: WorkerOpts| {
            let (tx, rx) = mpsc::channel();
            handles.push(WorkerHandle::channel(w, tx, metrics.clone()));
            let worker_kernel = kernel.clone();
            let leader = LeaderHandle::channel(to_leader_tx.clone());
            let worker_metrics = metrics.clone();
            joins.push(std::thread::spawn(move || match source() {
                Ok(s) => {
                    // in-process workers share the leader's trace ring;
                    // the returned kept-trace is always empty (both
                    // trace opts are off — see mk_opts below)
                    let _ = Worker::new(
                        w,
                        s,
                        worker_kernel,
                        leader,
                        worker_metrics,
                        opts,
                    )
                    .run(rx);
                }
                Err(e) => {
                    leader.send(&FromWorker::Failed {
                        worker: w,
                        message: format!("{e}"),
                    });
                }
            }));
        };
        let mk_opts = |file_source| WorkerOpts {
            max_cols: cfg.max_cols,
            merge_batch: cfg.merge_batch,
            failure: cfg.failure,
            file_source,
            throttle: None,
            // thread workers record straight into the shared
            // process-global ring — they must neither drain it nor ship
            // chunks to themselves
            ship_trace: false,
            keep_trace: false,
        };
        match plan {
            ShardPlan::Memory(shards) => {
                for s in shards {
                    let w = s.worker;
                    spawn(w, Box::new(move || Ok(s)), mk_opts(None));
                }
            }
            ShardPlan::File { path, n: _, limits } => {
                // the leader's ownership ranges come from the plan's n;
                // each worker re-derives its range from the file's
                // *actual* header, so cross-check the two — a stale plan
                // (file replaced since it was peeked) or a
                // caller-supplied wrong n must fail loudly at seeding,
                // not misroute FetchPoints or silently select over
                // mismatched blocks. If total rows differ, at least one
                // worker's range differs.
                let expected = shard::shard_ranges(n, p);
                for w in 0..p {
                    let wpath = path.clone();
                    let want = expected[w].clone();
                    spawn(
                        w,
                        Box::new(move || {
                            let s = loader::load_shard(&wpath, w, p, &limits)?;
                            if s.start != want.start || s.len() != want.len() {
                                return Err(anyhow!(
                                    "shard {w} of {} covers rows {}..{} but \
                                     this run expects {}..{} — the file \
                                     changed since the run was planned",
                                    wpath.display(),
                                    s.start,
                                    s.start + s.len(),
                                    want.start,
                                    want.end
                                ));
                            }
                            Ok(s)
                        }),
                        mk_opts(Some((path.clone(), limits))),
                    );
                }
            }
        }
        drop(to_leader_tx);
        Ok(Fleet { p, handles, inbox, joins, recoverable, tcp: false })
    }
}
