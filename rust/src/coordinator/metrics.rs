//! Runtime metrics for the oASIS-P coordinator: communication volume,
//! iteration counts, phase timings, and per-worker health counters.
//! Lock-free (atomics) so workers and transport reader threads can
//! record without contention on the hot path.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Sentinel for "no message seen yet" in [`WorkerCounters::last_seen_ms`].
const NEVER: u64 = u64::MAX;

/// Per-worker counters surfaced through the server's `/metrics` endpoint
/// and used by the leader's heartbeat-staleness check. `last_seen_ms` is
/// milliseconds since [`Metrics`] creation of the most recent message
/// (including heartbeats) from that worker.
#[derive(Debug)]
pub struct WorkerCounters {
    columns_served: AtomicU64,
    argmax_rounds: AtomicU64,
    wire_bytes: AtomicU64,
    /// Row ranges this worker adopted from dead peers.
    reshards_absorbed: AtomicU64,
    /// Trace chunks this worker shipped leader-ward (TCP fleets with
    /// tracing enabled; 0 otherwise).
    trace_chunks: AtomicU64,
    last_seen_ms: AtomicU64,
    dead: AtomicU64,
}

impl Default for WorkerCounters {
    fn default() -> Self {
        WorkerCounters {
            columns_served: AtomicU64::new(0),
            argmax_rounds: AtomicU64::new(0),
            wire_bytes: AtomicU64::new(0),
            reshards_absorbed: AtomicU64::new(0),
            trace_chunks: AtomicU64::new(0),
            last_seen_ms: AtomicU64::new(NEVER),
            dead: AtomicU64::new(0),
        }
    }
}

impl WorkerCounters {
    pub fn columns_served(&self) -> u64 {
        self.columns_served.load(Ordering::Relaxed)
    }

    pub fn argmax_rounds(&self) -> u64 {
        self.argmax_rounds.load(Ordering::Relaxed)
    }

    pub fn wire_bytes(&self) -> u64 {
        self.wire_bytes.load(Ordering::Relaxed)
    }

    pub fn reshards_absorbed(&self) -> u64 {
        self.reshards_absorbed.load(Ordering::Relaxed)
    }

    pub fn trace_chunks(&self) -> u64 {
        self.trace_chunks.load(Ordering::Relaxed)
    }

    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Relaxed) != 0
    }
}

/// Shared coordinator metrics.
#[derive(Debug)]
pub struct Metrics {
    broadcast_bytes: AtomicU64,
    gather_bytes: AtomicU64,
    broadcast_msgs: AtomicU64,
    gather_msgs: AtomicU64,
    iterations: AtomicU64,
    /// nanoseconds workers spent in local compute
    worker_compute_ns: AtomicU64,
    /// re-shard events: a dead worker's rows adopted by survivors
    reshards: AtomicU64,
    /// clock origin for `last_seen_ms`
    created: Instant,
    /// one slot per worker, registered at fleet start
    workers: Mutex<Vec<std::sync::Arc<WorkerCounters>>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            broadcast_bytes: AtomicU64::new(0),
            gather_bytes: AtomicU64::new(0),
            broadcast_msgs: AtomicU64::new(0),
            gather_msgs: AtomicU64::new(0),
            iterations: AtomicU64::new(0),
            worker_compute_ns: AtomicU64::new(0),
            reshards: AtomicU64::new(0),
            created: Instant::now(),
            workers: Mutex::new(Vec::new()),
        }
    }
}

impl Metrics {
    pub fn add_broadcast(&self, bytes: u64) {
        self.broadcast_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.broadcast_msgs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_gather(&self, bytes: u64) {
        self.gather_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.gather_msgs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_iteration(&self) {
        self.iterations.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_worker_compute(&self, dur: std::time::Duration) {
        self.worker_compute_ns
            .fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn add_reshard(&self) {
        self.reshards.fetch_add(1, Ordering::Relaxed);
    }

    /// Survivor worker `w` adopted a row range during a re-shard.
    pub fn add_worker_reshard(&self, w: usize) {
        if let Some(c) = self.worker(w) {
            c.reshards_absorbed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Ensure per-worker counter slots `0..p` exist (idempotent; called
    /// once by the transport when the fleet starts).
    pub fn register_workers(&self, p: usize) {
        let mut ws = lock(&self.workers);
        while ws.len() < p {
            ws.push(std::sync::Arc::new(WorkerCounters::default()));
        }
    }

    /// Counter slot for worker `w`, if registered.
    pub fn worker(&self, w: usize) -> Option<std::sync::Arc<WorkerCounters>> {
        lock(&self.workers).get(w).cloned()
    }

    fn now_ms(&self) -> u64 {
        self.created.elapsed().as_millis() as u64
    }

    /// Record a sign of life from worker `w` (any message, including a
    /// heartbeat that is otherwise swallowed by the transport).
    pub fn note_alive(&self, w: usize) {
        if let Some(c) = self.worker(w) {
            c.last_seen_ms.store(self.now_ms(), Ordering::Relaxed);
        }
    }

    /// Record `bytes` of wire traffic attributed to worker `w` (either
    /// direction — the per-worker ledger tracks link volume, while the
    /// broadcast/gather totals keep the paper's directional accounting).
    pub fn add_worker_wire(&self, w: usize, bytes: u64) {
        if let Some(c) = self.worker(w) {
            c.wire_bytes.fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// Worker `w` answered a column request (a `Point` fetch or one
    /// `Columns` gather block).
    pub fn add_worker_columns(&self, w: usize) {
        if let Some(c) = self.worker(w) {
            c.columns_served.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Worker `w` completed one Δ-argmax sweep.
    pub fn add_worker_argmax(&self, w: usize) {
        if let Some(c) = self.worker(w) {
            c.argmax_rounds.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Worker `w` shipped one leader-ward trace chunk.
    pub fn add_worker_trace_chunk(&self, w: usize) {
        if let Some(c) = self.worker(w) {
            c.trace_chunks.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Mark worker `w` dead (it stays in the stats with its final
    /// counters; the re-shard gave its rows away).
    pub fn mark_dead(&self, w: usize) {
        if let Some(c) = self.worker(w) {
            c.dead.store(1, Ordering::Relaxed);
        }
    }

    /// Age of the most recent message from worker `w`; `None` if the
    /// worker never spoke or is unregistered.
    pub fn last_seen_age(&self, w: usize) -> Option<Duration> {
        let c = self.worker(w)?;
        let seen = c.last_seen_ms.load(Ordering::Relaxed);
        if seen == NEVER {
            return None;
        }
        Some(Duration::from_millis(self.now_ms().saturating_sub(seen)))
    }

    pub fn broadcast_bytes(&self) -> u64 {
        self.broadcast_bytes.load(Ordering::Relaxed)
    }

    pub fn gather_bytes(&self) -> u64 {
        self.gather_bytes.load(Ordering::Relaxed)
    }

    pub fn broadcast_msgs(&self) -> u64 {
        self.broadcast_msgs.load(Ordering::Relaxed)
    }

    pub fn gather_msgs(&self) -> u64 {
        self.gather_msgs.load(Ordering::Relaxed)
    }

    pub fn iterations(&self) -> u64 {
        self.iterations.load(Ordering::Relaxed)
    }

    pub fn reshards(&self) -> u64 {
        self.reshards.load(Ordering::Relaxed)
    }

    pub fn worker_compute_secs(&self) -> f64 {
        self.worker_compute_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Per-worker counters as JSON, for the server's `/metrics` endpoint
    /// (one object per worker, in worker-id order).
    pub fn worker_stats_json(&self) -> Json {
        let now = self.now_ms();
        let ws = lock(&self.workers);
        Json::Arr(
            ws.iter()
                .enumerate()
                .map(|(w, c)| {
                    let seen = c.last_seen_ms.load(Ordering::Relaxed);
                    let age = if seen == NEVER {
                        Json::Null
                    } else {
                        Json::Num(now.saturating_sub(seen) as f64)
                    };
                    Json::obj(vec![
                        ("worker", Json::Num(w as f64)),
                        ("columns_served", Json::Num(c.columns_served() as f64)),
                        ("argmax_rounds", Json::Num(c.argmax_rounds() as f64)),
                        ("wire_bytes", Json::Num(c.wire_bytes() as f64)),
                        (
                            "reshards_absorbed",
                            Json::Num(c.reshards_absorbed() as f64),
                        ),
                        ("trace_chunks", Json::Num(c.trace_chunks() as f64)),
                        ("last_heartbeat_age_ms", age),
                        ("dead", Json::Bool(c.is_dead())),
                    ])
                })
                .collect(),
        )
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "iters={} bcast={} ({} msgs) gather={} ({} msgs) worker_compute={:.2}s",
            self.iterations(),
            crate::util::timing::fmt_bytes(self.broadcast_bytes()),
            self.broadcast_msgs(),
            crate::util::timing::fmt_bytes(self.gather_bytes()),
            self.gather_msgs(),
            self.worker_compute_secs(),
        );
        let r = self.reshards();
        if r > 0 {
            s.push_str(&format!(" reshards={r}"));
        }
        s
    }
}

/// Non-poisoning lock (a panicked recorder must not take metrics down).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::default();
        m.add_broadcast(100);
        m.add_broadcast(50);
        m.add_gather(8);
        m.add_iteration();
        assert_eq!(m.broadcast_bytes(), 150);
        assert_eq!(m.broadcast_msgs(), 2);
        assert_eq!(m.gather_bytes(), 8);
        assert_eq!(m.iterations(), 1);
        assert!(m.summary().contains("iters=1"));
        // no reshards → the summary omits the field
        assert!(!m.summary().contains("reshards"));
        m.add_reshard();
        assert!(m.summary().contains("reshards=1"));
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::default());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.add_gather(3);
                    }
                });
            }
        });
        assert_eq!(m.gather_bytes(), 24_000);
        assert_eq!(m.gather_msgs(), 8_000);
    }

    #[test]
    fn per_worker_counters() {
        let m = Metrics::default();
        // unregistered workers are silently ignored (defensive: a late
        // message after teardown must not panic)
        m.note_alive(3);
        assert!(m.last_seen_age(3).is_none());
        m.register_workers(2);
        m.add_worker_wire(0, 48);
        m.add_worker_wire(0, 16);
        m.add_worker_columns(0);
        m.add_worker_argmax(1);
        m.add_worker_trace_chunk(1);
        assert_eq!(m.worker(1).unwrap().trace_chunks(), 1);
        assert_eq!(m.worker(0).unwrap().wire_bytes(), 64);
        assert_eq!(m.worker(0).unwrap().columns_served(), 1);
        assert_eq!(m.worker(1).unwrap().argmax_rounds(), 1);
        // never-seen workers report no age; seen ones report a small one
        assert!(m.last_seen_age(0).is_none());
        m.note_alive(0);
        assert!(m.last_seen_age(0).unwrap() < Duration::from_secs(5));
        let js = m.worker_stats_json().to_string();
        assert!(js.contains("\"columns_served\":1"), "{js}");
        assert!(js.contains("\"wire_bytes\":64"), "{js}");
        assert!(js.contains("\"last_heartbeat_age_ms\":null"), "{js}");
        m.mark_dead(1);
        assert!(m.worker(1).unwrap().is_dead());
        assert!(m.worker_stats_json().to_string().contains("\"dead\":true"));
    }
}
