//! Runtime metrics for the oASIS-P coordinator: communication volume,
//! iteration counts, and phase timings. Lock-free (atomics) so workers can
//! record without contention on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    broadcast_bytes: AtomicU64,
    gather_bytes: AtomicU64,
    broadcast_msgs: AtomicU64,
    gather_msgs: AtomicU64,
    iterations: AtomicU64,
    /// nanoseconds workers spent in local compute
    worker_compute_ns: AtomicU64,
}

impl Metrics {
    pub fn add_broadcast(&self, bytes: u64) {
        self.broadcast_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.broadcast_msgs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_gather(&self, bytes: u64) {
        self.gather_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.gather_msgs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_iteration(&self) {
        self.iterations.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_worker_compute(&self, dur: std::time::Duration) {
        self.worker_compute_ns
            .fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn broadcast_bytes(&self) -> u64 {
        self.broadcast_bytes.load(Ordering::Relaxed)
    }

    pub fn gather_bytes(&self) -> u64 {
        self.gather_bytes.load(Ordering::Relaxed)
    }

    pub fn broadcast_msgs(&self) -> u64 {
        self.broadcast_msgs.load(Ordering::Relaxed)
    }

    pub fn gather_msgs(&self) -> u64 {
        self.gather_msgs.load(Ordering::Relaxed)
    }

    pub fn iterations(&self) -> u64 {
        self.iterations.load(Ordering::Relaxed)
    }

    pub fn worker_compute_secs(&self) -> f64 {
        self.worker_compute_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "iters={} bcast={} ({} msgs) gather={} ({} msgs) worker_compute={:.2}s",
            self.iterations(),
            crate::util::timing::fmt_bytes(self.broadcast_bytes()),
            self.broadcast_msgs(),
            crate::util::timing::fmt_bytes(self.gather_bytes()),
            self.gather_msgs(),
            self.worker_compute_secs(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::default();
        m.add_broadcast(100);
        m.add_broadcast(50);
        m.add_gather(8);
        m.add_iteration();
        assert_eq!(m.broadcast_bytes(), 150);
        assert_eq!(m.broadcast_msgs(), 2);
        assert_eq!(m.gather_bytes(), 8);
        assert_eq!(m.iterations(), 1);
        assert!(m.summary().contains("iters=1"));
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::default());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.add_gather(3);
                    }
                });
            }
        });
        assert_eq!(m.gather_bytes(), 24_000);
        assert_eq!(m.gather_msgs(), 8_000);
    }
}
