//! TCP transport for oASIS-P: the leader and its workers as separate
//! *processes* on opposite ends of real sockets.
//!
//! # Wire protocol
//!
//! Every message is one checksummed stream frame
//! ([`framing::write_frame`]/[`framing::read_frame`]):
//!
//! ```text
//! [u64 LE payload length][u64 LE FNV-1a 64 of payload][payload]
//! ```
//!
//! The payload is a tag byte followed by little-endian fields (codec
//! below; f64s travel as `to_bits` so distributed runs stay bit-identical
//! to in-process ones). Frames are bounded by [`MAX_FRAME_BYTES`]; a
//! corrupt or truncated frame is a clean error that tears the link down
//! (the leader sees the dead link as a worker death and re-shards).
//!
//! # Handshake
//!
//! ```text
//! worker                                  leader
//!   ── connect ──────────────────────────▶
//!   ◀── Assign{worker, workers, n, path,──
//!        limits, max_cols, merge_batch,
//!        kernel JSON, heartbeat_ms}
//!   (shard-reads rows worker·n/p ..)
//!   ── Joined{worker, start, len} ───────▶  (verified against the plan)
//!   ◀── Init{seeds…} ─────────────────────  (selection loop begins)
//! ```
//!
//! After the handshake the worker speaks [`FromWorker`] frames (plus
//! periodic `Heartbeat`s from a timer thread) and the leader speaks
//! [`ToWorker`] frames. The leader-side reader thread forwards decoded
//! messages into the shared [`LeaderInbox`](super::comm::LeaderInbox) —
//! swallowing heartbeats, which only refresh the worker's last-seen age —
//! and turns EOF or any socket/frame error into a local
//! [`FromWorker::Gone`], the death signal that triggers re-sharding.
//!
//! Workers never see each other; all traffic is leader ⇄ worker, matching
//! the paper's star topology (Fig. 4).

use super::comm::{
    FromWorker, LeaderHandle, LeaderSink, ToWorker, WorkerHandle, WorkerSink,
    WorkerSource,
};
use super::leader::ShardPlan;
use super::transport::{plan_workers, Fleet, Transport, TransportCtx};
use super::worker::{Worker, WorkerOpts};
use crate::data::{loader, shard, LoadLimits};
use crate::kernels::{Kernel, KernelParams};
use crate::nystrom::store::{kernel_from_json, kernel_to_json};
use crate::util::{framing, json::Json};
use crate::{anyhow, bail, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on a single wire frame. The largest legitimate frame is a
/// terminal `Columns` block (shard rows × k f64s); 8 GiB leaves room for
/// any realistic run while refusing hostile length prefixes outright.
pub const MAX_FRAME_BYTES: u64 = 1 << 33;

// ---- payload codec -------------------------------------------------------
//
// tag bytes: ToWorker 1..=6, FromWorker 32..=37, handshake 64..=65.
// `Gone` is local-only and has no encoding on purpose. A leader that
// sees a worker→leader tag above the range it understands logs and
// skips the frame instead of killing the link (newer workers may speak
// newer message kinds; see the reader thread below).

const TAG_INIT: u8 = 1;
const TAG_FETCH_POINT: u8 = 2;
const TAG_SELECTED: u8 = 3;
const TAG_GATHER_COLUMNS: u8 = 4;
const TAG_ADOPT: u8 = 5;
const TAG_FINISH: u8 = 6;
const TAG_ARGMAX: u8 = 32;
const TAG_POINT: u8 = 33;
const TAG_COLUMNS: u8 = 34;
const TAG_FAILED: u8 = 35;
const TAG_HEARTBEAT: u8 = 36;
const TAG_TRACE_CHUNK: u8 = 37;
const TAG_ASSIGN: u8 = 64;
const TAG_JOINED: u8 = 65;

/// First worker→leader tag byte this protocol revision understands.
const FROM_WORKER_TAG_MIN: u8 = TAG_ARGMAX;
/// Last worker→leader tag byte this protocol revision understands.
const FROM_WORKER_TAG_MAX: u8 = TAG_TRACE_CHUNK;

struct Enc {
    b: Vec<u8>,
}

impl Enc {
    fn new(tag: u8) -> Enc {
        Enc { b: vec![tag] }
    }

    fn u64v(&mut self, v: u64) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn uz(&mut self, v: usize) {
        self.u64v(v as u64);
    }

    /// f64 as raw bits — the wire must be bit-exact, not shortest-decimal.
    fn f64v(&mut self, v: f64) {
        self.u64v(v.to_bits());
    }

    fn boolean(&mut self, v: bool) {
        self.b.push(u8::from(v));
    }

    fn str(&mut self, s: &str) {
        self.uz(s.len());
        self.b.extend_from_slice(s.as_bytes());
    }

    fn f64s(&mut self, xs: &[f64]) {
        self.uz(xs.len());
        for &x in xs {
            self.f64v(x);
        }
    }

    fn uzs(&mut self, xs: &[usize]) {
        self.uz(xs.len());
        for &x in xs {
            self.uz(x);
        }
    }
}

struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated message: {what} needs {n} bytes, {} left",
                self.remaining()
            );
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8v(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u64v(&mut self, what: &str) -> Result<u64> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn uz(&mut self, what: &str) -> Result<usize> {
        let v = self.u64v(what)?;
        usize::try_from(v).map_err(|_| anyhow!("{what}: {v} overflows usize"))
    }

    fn f64v(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64v(what)?))
    }

    fn boolean(&mut self, what: &str) -> Result<bool> {
        match self.u8v(what)? {
            0 => Ok(false),
            1 => Ok(true),
            v => bail!("{what}: {v} is not a bool"),
        }
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let n = self.uz(what)?;
        let s = self.take(n, what)?;
        String::from_utf8(s.to_vec()).map_err(|_| anyhow!("{what}: not UTF-8"))
    }

    /// Length-checked element count: `count × width` must fit in the
    /// bytes actually present, so a crafted count can't trigger a huge
    /// allocation.
    fn count(&mut self, width: usize, what: &str) -> Result<usize> {
        let n = self.uz(what)?;
        let bytes = n
            .checked_mul(width)
            .ok_or_else(|| anyhow!("{what}: count {n} overflows"))?;
        if bytes > self.remaining() {
            bail!(
                "truncated message: {what} claims {n} elements ({bytes} \
                 bytes) but {} remain",
                self.remaining()
            );
        }
        Ok(n)
    }

    fn f64s(&mut self, what: &str) -> Result<Vec<f64>> {
        let n = self.count(8, what)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64v(what)?);
        }
        Ok(out)
    }

    fn uzs(&mut self, what: &str) -> Result<Vec<usize>> {
        let n = self.count(8, what)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.uz(what)?);
        }
        Ok(out)
    }

    fn done(self, what: &str) -> Result<()> {
        if self.remaining() != 0 {
            bail!("{what}: {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

/// Encode a leader → worker message.
pub fn encode_to_worker(m: &ToWorker) -> Vec<u8> {
    match m {
        ToWorker::Init { seed_indices, seed_points, winv0 } => {
            let mut e = Enc::new(TAG_INIT);
            e.uzs(seed_indices);
            e.uz(seed_points.len());
            for p in seed_points {
                e.f64s(p);
            }
            e.f64s(winv0);
            e.b
        }
        ToWorker::FetchPoint { global_idx } => {
            let mut e = Enc::new(TAG_FETCH_POINT);
            e.uz(*global_idx);
            e.b
        }
        ToWorker::Selected { global_idx, point, delta, epoch, want_argmax } => {
            let mut e = Enc::new(TAG_SELECTED);
            e.uz(*global_idx);
            e.f64s(point);
            match delta {
                Some(d) => {
                    e.boolean(true);
                    e.f64v(*d);
                }
                None => e.boolean(false),
            }
            e.u64v(*epoch);
            e.boolean(*want_argmax);
            e.b
        }
        ToWorker::GatherColumns { winv } => {
            let mut e = Enc::new(TAG_GATHER_COLUMNS);
            e.boolean(*winv);
            e.b
        }
        ToWorker::Adopt { epoch, ranges, selected, want_argmax } => {
            let mut e = Enc::new(TAG_ADOPT);
            e.u64v(*epoch);
            e.uz(ranges.len());
            for &(s, l) in ranges {
                e.uz(s);
                e.uz(l);
            }
            e.uzs(selected);
            e.boolean(*want_argmax);
            e.b
        }
        ToWorker::Finish { winv } => {
            let mut e = Enc::new(TAG_FINISH);
            e.boolean(*winv);
            e.b
        }
    }
}

/// Decode a leader → worker message.
pub fn decode_to_worker(b: &[u8]) -> Result<ToWorker> {
    let mut d = Dec::new(b);
    let tag = d.u8v("tag")?;
    let m = match tag {
        TAG_INIT => {
            let seed_indices = d.uzs("Init.seed_indices")?;
            let np = d.count(8, "Init.seed_points")?;
            let mut seed_points = Vec::with_capacity(np);
            for _ in 0..np {
                seed_points.push(d.f64s("Init.seed_point")?);
            }
            let winv0 = d.f64s("Init.winv0")?;
            ToWorker::Init { seed_indices, seed_points, winv0 }
        }
        TAG_FETCH_POINT => {
            ToWorker::FetchPoint { global_idx: d.uz("FetchPoint.global_idx")? }
        }
        TAG_SELECTED => {
            let global_idx = d.uz("Selected.global_idx")?;
            let point = d.f64s("Selected.point")?;
            let delta = if d.boolean("Selected.has_delta")? {
                Some(d.f64v("Selected.delta")?)
            } else {
                None
            };
            let epoch = d.u64v("Selected.epoch")?;
            let want_argmax = d.boolean("Selected.want_argmax")?;
            ToWorker::Selected { global_idx, point, delta, epoch, want_argmax }
        }
        TAG_GATHER_COLUMNS => {
            ToWorker::GatherColumns { winv: d.boolean("GatherColumns.winv")? }
        }
        TAG_ADOPT => {
            let epoch = d.u64v("Adopt.epoch")?;
            let nr = d.count(16, "Adopt.ranges")?;
            let mut ranges = Vec::with_capacity(nr);
            for _ in 0..nr {
                ranges.push((d.uz("Adopt.range.start")?, d.uz("Adopt.range.len")?));
            }
            let selected = d.uzs("Adopt.selected")?;
            let want_argmax = d.boolean("Adopt.want_argmax")?;
            ToWorker::Adopt { epoch, ranges, selected, want_argmax }
        }
        TAG_FINISH => ToWorker::Finish { winv: d.boolean("Finish.winv")? },
        t => bail!("unknown leader→worker message tag {t}"),
    };
    d.done("leader→worker message")?;
    Ok(m)
}

/// Encode a worker → leader message. `Gone` is a local-only signal and
/// has no wire form — encoding it is an error.
pub fn encode_from_worker(m: &FromWorker) -> Result<Vec<u8>> {
    Ok(match m {
        FromWorker::Argmax {
            worker,
            epoch,
            candidates,
            d_max,
            sum_abs_delta,
            d_sum,
        } => {
            let mut e = Enc::new(TAG_ARGMAX);
            e.uz(*worker);
            e.u64v(*epoch);
            e.uz(candidates.len());
            for &(g, dv) in candidates {
                e.uz(g);
                e.f64v(dv);
            }
            e.f64v(*d_max);
            e.f64v(*sum_abs_delta);
            e.f64v(*d_sum);
            e.b
        }
        FromWorker::Point { global_idx, point } => {
            let mut e = Enc::new(TAG_POINT);
            e.uz(*global_idx);
            e.f64s(point);
            e.b
        }
        FromWorker::Columns { worker, start, local_n, c_block, winv } => {
            let mut e = Enc::new(TAG_COLUMNS);
            e.uz(*worker);
            e.uz(*start);
            e.uz(*local_n);
            e.f64s(c_block);
            match winv {
                Some(w) => {
                    e.boolean(true);
                    e.f64s(w);
                }
                None => e.boolean(false),
            }
            e.b
        }
        FromWorker::Failed { worker, message } => {
            let mut e = Enc::new(TAG_FAILED);
            e.uz(*worker);
            e.str(message);
            e.b
        }
        FromWorker::Heartbeat { worker } => {
            let mut e = Enc::new(TAG_HEARTBEAT);
            e.uz(*worker);
            e.b
        }
        FromWorker::TraceChunk { worker, events } => {
            let mut e = Enc::new(TAG_TRACE_CHUNK);
            e.uz(*worker);
            e.uz(events.len());
            for ev in events {
                e.str(&ev.name);
                e.str(&ev.cat);
                e.u64v(ev.ts_us);
                e.u64v(ev.dur_us);
                e.u64v(ev.tid);
                e.u64v(u64::from(ev.depth));
                match ev.value {
                    Some(v) => {
                        e.boolean(true);
                        e.f64v(v);
                    }
                    None => e.boolean(false),
                }
            }
            e.b
        }
        FromWorker::Gone { .. } => {
            bail!("Gone is a leader-local signal, never sent on the wire")
        }
    })
}

/// Classify an undecodable worker→leader payload. `Some(tag)` means the
/// frame itself arrived intact (length + checksum passed) but carries a
/// tag byte outside the [`FromWorker`] range this build understands —
/// i.e. a message kind from a newer protocol revision. The link is
/// still healthy, so the leader's reader logs and skips it rather than
/// declaring the worker dead. `None` means the payload is empty or a
/// *known* tag with a malformed body: the stream is corrupt and the
/// link must come down.
pub(crate) fn unknown_from_worker_tag(payload: &[u8]) -> Option<u8> {
    match payload.first() {
        Some(&t) if !(FROM_WORKER_TAG_MIN..=FROM_WORKER_TAG_MAX).contains(&t) => {
            Some(t)
        }
        _ => None,
    }
}

/// Decode a worker → leader message.
pub fn decode_from_worker(b: &[u8]) -> Result<FromWorker> {
    let mut d = Dec::new(b);
    let tag = d.u8v("tag")?;
    let m = match tag {
        TAG_ARGMAX => {
            let worker = d.uz("Argmax.worker")?;
            let epoch = d.u64v("Argmax.epoch")?;
            let nc = d.count(16, "Argmax.candidates")?;
            let mut candidates = Vec::with_capacity(nc);
            for _ in 0..nc {
                candidates
                    .push((d.uz("Argmax.cand.idx")?, d.f64v("Argmax.cand.delta")?));
            }
            let d_max = d.f64v("Argmax.d_max")?;
            let sum_abs_delta = d.f64v("Argmax.sum_abs_delta")?;
            let d_sum = d.f64v("Argmax.d_sum")?;
            FromWorker::Argmax {
                worker,
                epoch,
                candidates,
                d_max,
                sum_abs_delta,
                d_sum,
            }
        }
        TAG_POINT => FromWorker::Point {
            global_idx: d.uz("Point.global_idx")?,
            point: d.f64s("Point.point")?,
        },
        TAG_COLUMNS => {
            let worker = d.uz("Columns.worker")?;
            let start = d.uz("Columns.start")?;
            let local_n = d.uz("Columns.local_n")?;
            let c_block = d.f64s("Columns.c_block")?;
            let winv = if d.boolean("Columns.has_winv")? {
                Some(d.f64s("Columns.winv")?)
            } else {
                None
            };
            FromWorker::Columns { worker, start, local_n, c_block, winv }
        }
        TAG_FAILED => FromWorker::Failed {
            worker: d.uz("Failed.worker")?,
            message: d.str("Failed.message")?,
        },
        TAG_HEARTBEAT => {
            FromWorker::Heartbeat { worker: d.uz("Heartbeat.worker")? }
        }
        TAG_TRACE_CHUNK => {
            let worker = d.uz("TraceChunk.worker")?;
            // minimum bytes per event: two empty strings (8+8) + four
            // u64 fields (32) + the value flag (1) = 49
            let ne = d.count(49, "TraceChunk.events")?;
            let mut events = Vec::with_capacity(ne);
            for _ in 0..ne {
                let name = d.str("TraceChunk.event.name")?;
                let cat = d.str("TraceChunk.event.cat")?;
                let ts_us = d.u64v("TraceChunk.event.ts_us")?;
                let dur_us = d.u64v("TraceChunk.event.dur_us")?;
                let tid = d.u64v("TraceChunk.event.tid")?;
                let depth = d.u64v("TraceChunk.event.depth")?;
                let depth = u32::try_from(depth).map_err(|_| {
                    anyhow!("TraceChunk.event.depth: {depth} overflows u32")
                })?;
                let value = if d.boolean("TraceChunk.event.has_value")? {
                    Some(d.f64v("TraceChunk.event.value")?)
                } else {
                    None
                };
                events.push(crate::obs::trace::OwnedEvent {
                    name,
                    cat,
                    ts_us,
                    dur_us,
                    tid,
                    depth,
                    value,
                });
            }
            FromWorker::TraceChunk { worker, events }
        }
        t => bail!("unknown worker→leader message tag {t}"),
    };
    d.done("worker→leader message")?;
    Ok(m)
}

/// The leader's half of the handshake: everything a joining worker needs
/// to become shard `worker` of `workers`.
#[derive(Debug, Clone, PartialEq)]
pub struct Assign {
    pub worker: usize,
    pub workers: usize,
    pub n: usize,
    /// dataset path as the *leader* sees it; `oasis worker --data`
    /// overrides it for workers with a different mount point
    pub path: String,
    pub limits: LoadLimits,
    pub max_cols: usize,
    pub merge_batch: usize,
    /// kernel as its canonical JSON spec (see
    /// [`kernel_to_json`]/[`kernel_from_json`]); [`KernelParams::build`]
    /// reproduces the kernel bit-exactly on the worker
    pub kernel: KernelParams,
    pub heartbeat_ms: u64,
    /// The leader is tracing: record local spans and ship them
    /// leader-ward as [`FromWorker::TraceChunk`]s.
    pub trace: bool,
    /// Fleet-wide run identifier, stamped on every worker's structured
    /// log lines so one run's lines correlate across processes.
    pub run_id: u64,
}

/// Encode the `Assign` handshake frame.
pub fn encode_assign(a: &Assign) -> Vec<u8> {
    let mut e = Enc::new(TAG_ASSIGN);
    e.uz(a.worker);
    e.uz(a.workers);
    e.uz(a.n);
    e.str(&a.path);
    e.uz(a.limits.max_n);
    e.uz(a.limits.max_dim);
    // u128 cap travels saturated to u64 — nobody limits above 2^64 elems
    e.u64v(u64::try_from(a.limits.max_elems).unwrap_or(u64::MAX));
    e.uz(a.max_cols);
    e.uz(a.merge_batch);
    e.str(&kernel_to_json(&a.kernel).to_string());
    e.u64v(a.heartbeat_ms);
    // appended after the original fields so older peers (which stop
    // reading here) and newer peers interop; see decode_assign
    e.boolean(a.trace);
    e.u64v(a.run_id);
    e.b
}

/// Decode the `Assign` handshake frame.
pub fn decode_assign(b: &[u8]) -> Result<Assign> {
    let mut d = Dec::new(b);
    if d.u8v("tag")? != TAG_ASSIGN {
        bail!("expected an Assign handshake frame");
    }
    let worker = d.uz("Assign.worker")?;
    let workers = d.uz("Assign.workers")?;
    let n = d.uz("Assign.n")?;
    let path = d.str("Assign.path")?;
    let limits = LoadLimits {
        max_n: d.uz("Assign.limits.max_n")?,
        max_dim: d.uz("Assign.limits.max_dim")?,
        max_elems: d.u64v("Assign.limits.max_elems")? as u128,
    };
    let max_cols = d.uz("Assign.max_cols")?;
    let merge_batch = d.uz("Assign.merge_batch")?;
    let kjson = d.str("Assign.kernel")?;
    let kernel = kernel_from_json(
        &Json::parse(&kjson).map_err(|e| anyhow!("Assign.kernel: {e}"))?,
    )?;
    let heartbeat_ms = d.u64v("Assign.heartbeat_ms")?;
    // version tolerance: an older leader's Assign ends here — default
    // the trailing observability fields instead of rejecting the frame
    let (trace, run_id) = if d.remaining() > 0 {
        (d.boolean("Assign.trace")?, d.u64v("Assign.run_id")?)
    } else {
        (false, 0)
    };
    d.done("Assign")?;
    Ok(Assign {
        worker,
        workers,
        n,
        path,
        limits,
        max_cols,
        merge_batch,
        kernel,
        heartbeat_ms,
        trace,
        run_id,
    })
}

/// The worker's half of the handshake: which rows its shard read
/// actually covers (the leader verifies this against the plan).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Joined {
    pub worker: usize,
    pub start: usize,
    pub len: usize,
}

/// Encode the `Joined` handshake frame.
pub fn encode_joined(j: &Joined) -> Vec<u8> {
    let mut e = Enc::new(TAG_JOINED);
    e.uz(j.worker);
    e.uz(j.start);
    e.uz(j.len);
    e.b
}

/// Decode the `Joined` handshake frame.
pub fn decode_joined(b: &[u8]) -> Result<Joined> {
    let mut d = Dec::new(b);
    if d.u8v("tag")? != TAG_JOINED {
        bail!("expected a Joined handshake frame");
    }
    let j = Joined {
        worker: d.uz("Joined.worker")?,
        start: d.uz("Joined.start")?,
        len: d.uz("Joined.len")?,
    };
    d.done("Joined")?;
    Ok(j)
}

// ---- socket endpoints ----------------------------------------------------

/// Mutex-serialized frame writer over one socket. Shared between a TCP
/// worker's compute loop and its heartbeat thread (and usable from the
/// leader's single send path); each frame is written atomically under the
/// lock so frames never interleave.
struct FrameWriter {
    stream: Mutex<TcpStream>,
}

impl FrameWriter {
    fn new(stream: TcpStream) -> FrameWriter {
        FrameWriter { stream: Mutex::new(stream) }
    }

    fn send_payload(&self, payload: &[u8]) -> bool {
        crate::obs::trace::event("wire_send", "net", payload.len() as f64);
        let mut s = match self.stream.lock() {
            Ok(s) => s,
            Err(_) => return false,
        };
        framing::write_frame(&mut *s, payload).is_ok() && s.flush().is_ok()
    }
}

/// Leader-side outbound link to one TCP worker.
struct TcpWorkerSink(Arc<FrameWriter>);

impl WorkerSink for TcpWorkerSink {
    fn send(&self, msg: &ToWorker) -> bool {
        self.0.send_payload(&encode_to_worker(msg))
    }
}

/// Worker-side outbound link to the leader.
struct TcpLeaderSink(Arc<FrameWriter>);

impl LeaderSink for TcpLeaderSink {
    fn send(&self, msg: &FromWorker) -> bool {
        match encode_from_worker(msg) {
            Ok(p) => self.0.send_payload(&p),
            Err(_) => false, // Gone is never wire-encoded
        }
    }
}

/// Worker-side inbound link: blocking frame reads off the socket. EOF,
/// socket errors, and undecodable frames all end the message loop (the
/// worker exits; the leader's reader sees the close as a death).
struct TcpWorkerSource {
    stream: TcpStream,
}

impl WorkerSource for TcpWorkerSource {
    fn recv(&mut self) -> Option<ToWorker> {
        match framing::read_frame(&mut self.stream, MAX_FRAME_BYTES) {
            Ok(Some(payload)) => {
                crate::obs::trace::event(
                    "wire_recv",
                    "net",
                    payload.len() as f64,
                );
                decode_to_worker(&payload).ok()
            }
            _ => None,
        }
    }
}

// ---- leader side: the transport ------------------------------------------

/// TCP transport: workers are separate `oasis worker --join HOST:PORT`
/// processes. Requires [`ShardPlan::File`] (each process shard-reads its
/// own byte range of the dataset file) and a parameterized kernel (it
/// ships in the `Assign` handshake). Produced fleets are recoverable: a
/// worker process dying mid-selection triggers re-sharding onto the
/// survivors.
pub struct TcpTransport {
    listener: TcpListener,
}

impl TcpTransport {
    /// Bind the listening socket (e.g. `"127.0.0.1:0"` for an ephemeral
    /// port). Binding is separate from [`Transport::start`] so a caller
    /// can print the bound address for workers to join before blocking
    /// in the accept loop.
    pub fn bind(addr: &str) -> Result<TcpTransport> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("binding leader socket {addr}: {e}"))?;
        Ok(TcpTransport { listener })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        self.listener
            .local_addr()
            .map_err(|e| anyhow!("leader socket address: {e}"))
    }
}

impl Transport for TcpTransport {
    fn start(self: Box<Self>, ctx: TransportCtx) -> Result<Fleet> {
        let TransportCtx { plan, kernel, cfg, metrics } = ctx;
        let (path, n, limits) = match &plan {
            ShardPlan::File { path, n, limits } => (path.clone(), *n, *limits),
            ShardPlan::Memory(_) => bail!(
                "TCP workers shard-read the dataset themselves — run with a \
                 file-backed dataset (ShardPlan::File)"
            ),
        };
        let params = kernel.params().ok_or_else(|| {
            anyhow!(
                "TCP workers rebuild the kernel from its parameters — this \
                 kernel has none (custom closure kernels are in-process only)"
            )
        })?;
        let path_str = path.to_str().ok_or_else(|| {
            anyhow!("dataset path {} is not UTF-8", path.display())
        })?;
        let p = plan_workers(&plan, &cfg);
        let expected = shard::shard_ranges(n, p);
        let trace = crate::obs::trace::enabled();
        // wall-clock µs ⊕ shifted pid: unique enough to correlate one
        // run's log lines across leader and worker processes
        let run_id = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
            ^ (u64::from(std::process::id()) << 48);
        crate::obs::log::info(
            "coordinator",
            "fleet starting",
            &[
                ("run_id", format!("{run_id:016x}")),
                ("workers", p.to_string()),
                ("trace", trace.to_string()),
            ],
        );
        let (tx, inbox) = mpsc::channel::<FromWorker>();
        let mut handles: Vec<WorkerHandle> = Vec::with_capacity(p);
        let mut joins = Vec::with_capacity(p);
        // accept under a deadline: a fleet that never fills is a clean
        // startup error, not a hang
        self.listener
            .set_nonblocking(true)
            .map_err(|e| anyhow!("leader socket: {e}"))?;
        let deadline = Instant::now() + cfg.timeout;
        metrics.register_workers(p);
        for w in 0..p {
            let stream = loop {
                match self.listener.accept() {
                    Ok((s, _)) => break s,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if Instant::now() >= deadline {
                            bail!(
                                "only {w} of {p} workers joined within \
                                 {:?} — start the missing `oasis worker \
                                 --join` processes",
                                cfg.timeout
                            );
                        }
                        std::thread::sleep(Duration::from_millis(25));
                    }
                    Err(e) => bail!("accepting worker connection: {e}"),
                }
            };
            stream
                .set_nonblocking(false)
                .and_then(|()| stream.set_nodelay(true))
                .map_err(|e| anyhow!("worker {w} socket: {e}"))?;
            // bound the handshake (the worker shard-reads before Joined)
            stream
                .set_read_timeout(Some(cfg.timeout))
                .map_err(|e| anyhow!("worker {w} socket: {e}"))?;
            let writer = Arc::new(FrameWriter::new(
                stream.try_clone().map_err(|e| anyhow!("worker {w}: {e}"))?,
            ));
            let assign = Assign {
                worker: w,
                workers: p,
                n,
                path: path_str.to_string(),
                limits,
                max_cols: cfg.max_cols,
                merge_batch: cfg.merge_batch,
                kernel: params.clone(),
                heartbeat_ms: cfg.heartbeat_interval().as_millis() as u64,
                trace,
                run_id,
            };
            if !writer.send_payload(&encode_assign(&assign)) {
                bail!("worker {w} hung up during the Assign handshake");
            }
            let mut rd = stream;
            let joined = match framing::read_frame(&mut rd, MAX_FRAME_BYTES)? {
                Some(payload) => decode_joined(&payload)?,
                None => bail!("worker {w} hung up before Joined"),
            };
            let want = &expected[w];
            if joined.worker != w
                || joined.start != want.start
                || joined.len != want.end - want.start
            {
                bail!(
                    "worker {w} joined covering rows {}..{} but this run \
                     expects {}..{} — its copy of the dataset differs from \
                     the leader's",
                    joined.start,
                    joined.start + joined.len,
                    want.start,
                    want.end
                );
            }
            metrics.note_alive(w);
            // steady state: reads block, liveness is the heartbeat's job
            // (a stuck-open socket is caught by the leader's staleness
            // check; twice the timeout bounds the reader thread itself)
            rd.set_read_timeout(Some(cfg.timeout * 2))
                .map_err(|e| anyhow!("worker {w} socket: {e}"))?;
            handles.push(WorkerHandle::new(
                w,
                Box::new(TcpWorkerSink(writer)),
                metrics.clone(),
            ));
            // reader thread: decode and forward into the shared inbox.
            // No metering here — gather accounting happens when the
            // leader dequeues, identically for both transports.
            let reader_tx = tx.clone();
            let reader_metrics = metrics.clone();
            joins.push(std::thread::spawn(move || {
                let mut rd = rd;
                loop {
                    match framing::read_frame(&mut rd, MAX_FRAME_BYTES) {
                        Ok(Some(payload)) => {
                            crate::obs::trace::event(
                                "wire_recv",
                                "net",
                                payload.len() as f64,
                            );
                            match decode_from_worker(&payload) {
                                Ok(FromWorker::Heartbeat { worker }) => {
                                    reader_metrics.note_alive(worker);
                                }
                                Ok(msg) => {
                                    if reader_tx.send(msg).is_err() {
                                        return; // leader gone
                                    }
                                }
                                Err(_) => {
                                    // a checksummed frame carrying a tag
                                    // from a newer protocol revision is
                                    // skippable; a malformed known
                                    // message means the stream is
                                    // corrupt — report the death
                                    if let Some(t) =
                                        unknown_from_worker_tag(&payload)
                                    {
                                        crate::obs::log::warn(
                                            "net",
                                            "skipping unknown frame tag",
                                            &[
                                                ("worker", w.to_string()),
                                                ("tag", t.to_string()),
                                            ],
                                        );
                                        continue;
                                    }
                                    let _ = reader_tx
                                        .send(FromWorker::Gone { worker: w });
                                    return;
                                }
                            }
                        }
                        Ok(None) | Err(_) => {
                            let _ =
                                reader_tx.send(FromWorker::Gone { worker: w });
                            return;
                        }
                    }
                }
            }));
        }
        drop(tx);
        Ok(Fleet { p, handles, inbox, joins, recoverable: true, tcp: true })
    }
}

// ---- worker side: the process entry --------------------------------------

/// Options for [`run_worker`], beyond the leader address.
#[derive(Debug, Clone, Default)]
pub struct WorkerRunOpts {
    /// Replace the leader's dataset path (workers mounted differently).
    pub data_override: Option<PathBuf>,
    /// Artificially delay each update (the CI kill-recovery smoke job
    /// uses it to die mid-run deterministically).
    pub throttle: Option<Duration>,
    /// Write this process's own local trace here (Chrome JSON) when the
    /// loop ends — `oasis worker --trace FILE`. Forces local tracing on
    /// even when the leader didn't request leader-ward shipping.
    pub trace_file: Option<PathBuf>,
}

/// Run one worker process: connect to the leader, receive the `Assign`
/// handshake, shard-read the assigned rows, reply `Joined`, then serve
/// the selection loop until `Finish` (or the link drops). A timer thread
/// sends heartbeats at the leader-assigned period for the whole life of
/// the loop. This is the body of `oasis worker --join HOST:PORT`.
///
/// When the `Assign` requested tracing, the worker records local spans
/// (shard load, diag pass, score scans, column serves, heartbeats) and
/// ships them leader-ward as [`FromWorker::TraceChunk`]s on gather
/// boundaries; `opts.trace_file` additionally (or independently) keeps
/// a local copy and writes it on exit.
pub fn run_worker(join_addr: &str, opts: WorkerRunOpts) -> Result<()> {
    let stream = TcpStream::connect(join_addr)
        .map_err(|e| anyhow!("connecting to leader {join_addr}: {e}"))?;
    stream
        .set_nodelay(true)
        .map_err(|e| anyhow!("worker socket: {e}"))?;
    let mut rd = stream.try_clone().map_err(|e| anyhow!("worker socket: {e}"))?;
    let assign = match framing::read_frame(&mut rd, MAX_FRAME_BYTES)? {
        Some(payload) => decode_assign(&payload)?,
        None => bail!("leader {join_addr} hung up before Assign"),
    };
    let tracing = assign.trace || opts.trace_file.is_some();
    if tracing && !crate::obs::trace::enabled() {
        crate::obs::trace::enable_with_capacity(
            crate::obs::trace::DEFAULT_CAPACITY,
        );
    }
    crate::obs::log::info(
        "worker",
        "assigned",
        &[
            ("worker", assign.worker.to_string()),
            ("workers", assign.workers.to_string()),
            ("run_id", format!("{:016x}", assign.run_id)),
            ("trace", tracing.to_string()),
        ],
    );
    let path =
        opts.data_override.unwrap_or_else(|| PathBuf::from(&assign.path));
    let my_shard = {
        let _g = crate::obs::span("shard_load", "worker");
        loader::load_shard(
            &path,
            assign.worker,
            assign.workers,
            &assign.limits,
        )?
    };
    let writer = Arc::new(FrameWriter::new(stream));
    let joined = Joined {
        worker: assign.worker,
        start: my_shard.start,
        len: my_shard.len(),
    };
    if !writer.send_payload(&encode_joined(&joined)) {
        bail!("leader hung up during the Joined handshake");
    }

    // heartbeat timer: the worker's liveness beacon, independent of the
    // compute loop so long updates don't read as death
    let stop = Arc::new(AtomicBool::new(false));
    let hb_stop = stop.clone();
    let hb_writer = writer.clone();
    let hb_worker = assign.worker;
    let period = Duration::from_millis(assign.heartbeat_ms.max(50));
    let hb = std::thread::spawn(move || {
        let beat = encode_from_worker(&FromWorker::Heartbeat { worker: hb_worker })
            .expect("heartbeat encodes");
        while !hb_stop.load(Ordering::Relaxed) {
            std::thread::sleep(period);
            if hb_stop.load(Ordering::Relaxed) {
                return;
            }
            crate::obs::trace::event("heartbeat", "worker", 1.0);
            if !hb_writer.send_payload(&beat) {
                return; // link down — the compute loop is ending too
            }
        }
    });

    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::from(assign.kernel.build());
    let leader = LeaderHandle::new(Arc::new(TcpLeaderSink(writer)));
    let metrics = Arc::new(super::metrics::Metrics::default());
    let wopts = WorkerOpts {
        max_cols: assign.max_cols,
        merge_batch: assign.merge_batch,
        failure: None,
        file_source: Some((path, assign.limits)),
        throttle: opts.throttle,
        ship_trace: assign.trace,
        keep_trace: opts.trace_file.is_some(),
    };
    let (kept, kept_dropped) =
        Worker::new(assign.worker, my_shard, kernel, leader, metrics, wopts)
            .run(TcpWorkerSource { stream: rd });
    stop.store(true, Ordering::Relaxed);
    let _ = hb.join();
    if let Some(file) = &opts.trace_file {
        let n_events = kept.len();
        let track = crate::obs::trace::TraceTrack {
            pid: assign.worker as u64 + 2,
            label: format!("worker-{}", assign.worker),
            events: kept,
            dropped: kept_dropped,
        };
        let json = crate::obs::trace::merged_chrome_json(&[track]).to_string();
        crate::util::fsio::write_atomic(file, json.as_bytes())?;
        crate::obs::log::info(
            "worker",
            "local trace written",
            &[
                ("worker", assign.worker.to_string()),
                ("path", file.display().to_string()),
                ("events", n_events.to_string()),
            ],
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_to_worker(m: ToWorker) {
        let enc = encode_to_worker(&m);
        let back = decode_to_worker(&enc).unwrap();
        assert_eq!(format!("{m:?}"), format!("{back:?}"));
    }

    fn roundtrip_from_worker(m: FromWorker) {
        let enc = encode_from_worker(&m).unwrap();
        let back = decode_from_worker(&enc).unwrap();
        assert_eq!(format!("{m:?}"), format!("{back:?}"));
    }

    #[test]
    fn to_worker_messages_round_trip() {
        roundtrip_to_worker(ToWorker::Init {
            seed_indices: vec![3, 9, 1],
            seed_points: vec![vec![0.1, -0.2], vec![1.0 / 3.0, 5e-324]],
            winv0: vec![1.0, 0.0, 0.0, 1.0],
        });
        roundtrip_to_worker(ToWorker::FetchPoint { global_idx: 42 });
        roundtrip_to_worker(ToWorker::Selected {
            global_idx: 7,
            point: vec![f64::MAX, -0.0, 2.5],
            delta: Some(0.123456789),
            epoch: 3,
            want_argmax: true,
        });
        roundtrip_to_worker(ToWorker::Selected {
            global_idx: 8,
            point: vec![],
            delta: None,
            epoch: 0,
            want_argmax: false,
        });
        roundtrip_to_worker(ToWorker::GatherColumns { winv: true });
        roundtrip_to_worker(ToWorker::Adopt {
            epoch: 9,
            ranges: vec![(10, 5), (40, 2)],
            selected: vec![1, 2, 3],
            want_argmax: true,
        });
        roundtrip_to_worker(ToWorker::Finish { winv: false });
    }

    #[test]
    fn from_worker_messages_round_trip() {
        roundtrip_from_worker(FromWorker::Argmax {
            worker: 2,
            epoch: 5,
            candidates: vec![(11, -0.25), (3, 0.125)],
            d_max: 1.5,
            sum_abs_delta: 0.75,
            d_sum: 12.0,
        });
        roundtrip_from_worker(FromWorker::Point {
            global_idx: 6,
            point: vec![0.1, 0.2],
        });
        roundtrip_from_worker(FromWorker::Columns {
            worker: 0,
            start: 25,
            local_n: 2,
            c_block: vec![1.0, 2.0, 3.0, 4.0],
            winv: Some(vec![1.0, 0.0, 0.0, 1.0]),
        });
        roundtrip_from_worker(FromWorker::Failed {
            worker: 1,
            message: "shard went bad: Δ vanished".to_string(),
        });
        roundtrip_from_worker(FromWorker::Heartbeat { worker: 3 });
        roundtrip_from_worker(FromWorker::TraceChunk {
            worker: 2,
            events: vec![
                crate::obs::trace::OwnedEvent {
                    name: "score_scan".to_string(),
                    cat: "worker".to_string(),
                    ts_us: 1_000,
                    dur_us: 250,
                    tid: 1,
                    depth: 0,
                    value: None,
                },
                crate::obs::trace::OwnedEvent {
                    name: "heartbeat".to_string(),
                    cat: "worker".to_string(),
                    ts_us: 2_000,
                    dur_us: 0,
                    tid: 2,
                    depth: 1,
                    value: Some(1.0),
                },
            ],
        });
        roundtrip_from_worker(FromWorker::TraceChunk {
            worker: 0,
            events: vec![],
        });
    }

    #[test]
    fn f64_wire_encoding_is_bit_exact() {
        // bit parity over the wire is the whole point: NaN payloads,
        // signed zeros, and subnormals must survive unchanged
        let tricky =
            vec![f64::NAN, -0.0, 5e-324, f64::INFINITY, -f64::MIN_POSITIVE];
        let enc = encode_from_worker(&FromWorker::Point {
            global_idx: 0,
            point: tricky.clone(),
        })
        .unwrap();
        match decode_from_worker(&enc).unwrap() {
            FromWorker::Point { point, .. } => {
                for (a, b) in tricky.iter().zip(&point) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn gone_never_encodes() {
        assert!(encode_from_worker(&FromWorker::Gone { worker: 0 }).is_err());
    }

    #[test]
    fn handshake_frames_round_trip() {
        let a = Assign {
            worker: 1,
            workers: 3,
            n: 500,
            path: "/tmp/data.mat".to_string(),
            limits: LoadLimits {
                max_n: 10_000,
                max_dim: 64,
                max_elems: 1 << 40,
            },
            max_cols: 50,
            merge_batch: 4,
            kernel: KernelParams::Gaussian { inv_sigma_sq: 0.73 },
            heartbeat_ms: 250,
            trace: true,
            run_id: 0xDEAD_BEEF_0042,
        };
        let back = decode_assign(&encode_assign(&a)).unwrap();
        assert_eq!(a, back);
        let j = Joined { worker: 1, start: 167, len: 167 };
        assert_eq!(decode_joined(&encode_joined(&j)).unwrap(), j);
    }

    #[test]
    fn assign_decode_tolerates_older_leaders() {
        // an older leader's Assign stops after heartbeat_ms; slicing the
        // appended trace (1 byte) + run_id (8 bytes) off a new encoding
        // reproduces that wire format exactly
        let a = Assign {
            worker: 0,
            workers: 2,
            n: 100,
            path: "/tmp/data.mat".to_string(),
            limits: LoadLimits { max_n: 1_000, max_dim: 8, max_elems: 1 << 30 },
            max_cols: 10,
            merge_batch: 1,
            kernel: KernelParams::Gaussian { inv_sigma_sq: 1.0 },
            heartbeat_ms: 100,
            trace: true,
            run_id: 7,
        };
        let enc = encode_assign(&a);
        let old = &enc[..enc.len() - 9];
        let back = decode_assign(old).unwrap();
        assert!(!back.trace, "older frames default to tracing off");
        assert_eq!(back.run_id, 0);
        assert_eq!(back.heartbeat_ms, a.heartbeat_ms);
        assert_eq!(back.path, a.path);
    }

    #[test]
    fn corrupt_payloads_error_not_panic() {
        // unknown tag
        assert!(decode_to_worker(&[200]).is_err());
        assert!(decode_from_worker(&[200]).is_err());
        // empty payload
        assert!(decode_to_worker(&[]).is_err());
        // truncated mid-message
        let enc = encode_to_worker(&ToWorker::Selected {
            global_idx: 7,
            point: vec![1.0, 2.0],
            delta: Some(0.5),
            epoch: 1,
            want_argmax: true,
        });
        for cut in 1..enc.len() {
            assert!(decode_to_worker(&enc[..cut]).is_err(), "cut={cut}");
        }
        // trailing bytes
        let mut padded = enc;
        padded.push(0);
        assert!(decode_to_worker(&padded).is_err());
        // hostile element count: claims 2^60 f64s in a tiny buffer —
        // must refuse before allocating
        let mut evil = vec![TAG_POINT];
        evil.extend_from_slice(&0u64.to_le_bytes());
        evil.extend_from_slice(&(1u64 << 60).to_le_bytes());
        assert!(decode_from_worker(&evil).is_err());
        // hostile TraceChunk: claims 2^50 events in a tiny buffer
        let mut evil = vec![TAG_TRACE_CHUNK];
        evil.extend_from_slice(&0u64.to_le_bytes());
        evil.extend_from_slice(&(1u64 << 50).to_le_bytes());
        assert!(decode_from_worker(&evil).is_err());
    }

    #[test]
    fn unknown_tags_are_skippable_but_corrupt_known_frames_are_not() {
        // a future protocol revision's message kind: intact frame, tag
        // above this build's range — classified skippable, not fatal
        assert!(decode_from_worker(&[38, 1, 2, 3]).is_err());
        assert_eq!(unknown_from_worker_tag(&[38, 1, 2, 3]), Some(38));
        assert_eq!(unknown_from_worker_tag(&[200]), Some(200));
        // handshake tags arriving mid-stream are also not FromWorker
        assert_eq!(unknown_from_worker_tag(&[TAG_ASSIGN]), Some(TAG_ASSIGN));
        // a *known* tag with a mangled body is stream corruption: the
        // reader must tear the link down, not skip
        assert_eq!(unknown_from_worker_tag(&[TAG_HEARTBEAT, 0xFF]), None);
        assert_eq!(unknown_from_worker_tag(&[TAG_TRACE_CHUNK]), None);
        // an empty payload is corruption too
        assert_eq!(unknown_from_worker_tag(&[]), None);
    }

    /// A miniature in-process "network": leader and worker endpoints over
    /// a real localhost socket pair, exercising FrameWriter / the sinks /
    /// the source without a full fleet.
    #[test]
    fn sink_and_source_speak_frames_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut src = TcpWorkerSource { stream: s.try_clone().unwrap() };
            let got = src.recv().unwrap();
            let writer = Arc::new(FrameWriter::new(s));
            let sink = TcpLeaderSink(writer);
            match got {
                ToWorker::FetchPoint { global_idx } => {
                    assert!(sink.send(&FromWorker::Point {
                        global_idx,
                        point: vec![1.5, -2.5],
                    }));
                }
                other => panic!("unexpected {other:?}"),
            }
            // link closes when the writer drops → leader side sees EOF
        });
        let stream = TcpStream::connect(addr).unwrap();
        let writer = Arc::new(FrameWriter::new(stream.try_clone().unwrap()));
        let sink = TcpWorkerSink(writer);
        assert!(sink.send(&ToWorker::FetchPoint { global_idx: 12 }));
        let mut rd = stream;
        let reply = framing::read_frame(&mut rd, MAX_FRAME_BYTES)
            .unwrap()
            .expect("a reply frame");
        match decode_from_worker(&reply).unwrap() {
            FromWorker::Point { global_idx, point } => {
                assert_eq!(global_idx, 12);
                assert_eq!(point, vec![1.5, -2.5]);
            }
            other => panic!("unexpected {other:?}"),
        }
        t.join().unwrap();
        // EOF at a frame boundary reads as a clean end of stream
        assert!(framing::read_frame(&mut rd, MAX_FRAME_BYTES).unwrap().is_none());
    }
}
