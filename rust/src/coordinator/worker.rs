//! The oASIS-P worker node (paper Alg. 2, "On each node (i)" blocks).
//!
//! Each worker owns a contiguous shard Z_(i) of the dataset and maintains:
//! * `d_(i)`  — local kernel diagonal,
//! * `C_(i)`  — local rows of the sampled columns (stored column-major),
//! * `R_(i)`  — local columns of R = W⁻¹Cᵀ,
//! * a replica of `W⁻¹` and of the selected points Z_Λ.
//!
//! Per `Selected` broadcast the worker performs the paper's node-local
//! updates: kernel column over its shard, Eq. 5 on the W⁻¹ replica, Eq. 6
//! on R_(i), then computes its local Δ block and replies with the shard
//! argmax — exactly one small message each way per iteration.

use super::comm::{FromWorker, LeaderHandle, ToWorker, WorkerInbox};
use super::config::FailureSpec;
use super::metrics::Metrics;
use crate::data::Shard;
use crate::kernels::Kernel;
use std::sync::Arc;

/// Long-lived state of one worker thread.
pub struct Worker {
    pub id: usize,
    shard: Shard,
    kernel: Arc<dyn Kernel + Send + Sync>,
    leader: LeaderHandle,
    metrics: Arc<Metrics>,
    max_cols: usize,
    failure: Option<FailureSpec>,

    // --- algorithm state ---
    d: Vec<f64>,
    /// local C, column-major: column t at c[t*ln .. (t+1)*ln]
    c: Vec<f64>,
    /// local R, row-major rows of length ln
    r: Vec<f64>,
    /// W⁻¹ replica, strided by max_cols
    winv: Vec<f64>,
    /// replica of the selected points (in selection order)
    z_sel: Vec<Vec<f64>>,
    k: usize,
    /// which local indices are already selected
    selected_local: Vec<bool>,
    /// iteration counter for fault injection
    iteration: usize,
    /// scratch
    diff: Vec<f64>,
    delta: Vec<f64>,
}

impl Worker {
    pub fn new(
        id: usize,
        shard: Shard,
        kernel: Arc<dyn Kernel + Send + Sync>,
        leader: LeaderHandle,
        metrics: Arc<Metrics>,
        max_cols: usize,
        failure: Option<FailureSpec>,
    ) -> Worker {
        let ln = shard.len();
        let d = (0..ln)
            .map(|i| kernel.diag_value(shard.points.point(i)))
            .collect();
        Worker {
            id,
            shard,
            kernel,
            leader,
            metrics,
            max_cols,
            failure,
            d,
            c: Vec::new(),
            r: Vec::new(),
            winv: vec![0.0; max_cols * max_cols],
            z_sel: Vec::new(),
            k: 0,
            selected_local: vec![false; ln],
            iteration: 0,
            diff: vec![0.0; ln],
            delta: vec![0.0; ln],
        }
    }

    /// The worker thread body: process leader messages until Finish.
    pub fn run(mut self, inbox: WorkerInbox) {
        while let Ok(msg) = inbox.recv() {
            let t0 = std::time::Instant::now();
            match msg {
                ToWorker::FetchPoint { global_idx } => {
                    let local = self.shard.local(global_idx);
                    let point = self.shard.points.point(local).to_vec();
                    self.leader.send(FromWorker::Point { global_idx, point });
                }
                ToWorker::Init { seed_indices, seed_points, winv0 } => {
                    self.handle_init(&seed_indices, &seed_points, &winv0);
                    self.send_argmax();
                }
                ToWorker::Selected { global_idx, point, delta } => {
                    self.iteration += 1;
                    if let Some(f) = self.failure {
                        if f.worker == self.id && self.iteration >= f.at_iteration {
                            self.leader.send(FromWorker::Failed {
                                worker: self.id,
                                message: "injected fault".into(),
                            });
                            return; // simulate a crashed node
                        }
                    }
                    self.handle_selected(global_idx, &point, delta);
                    self.send_argmax();
                }
                ToWorker::GatherColumns => {
                    // mid-run snapshot: same gather as Finish, but the
                    // worker stays alive for further selection rounds
                    self.send_columns();
                }
                ToWorker::Finish => {
                    self.send_columns();
                    return;
                }
            }
            self.metrics.add_worker_compute(t0.elapsed());
        }
    }

    /// Paper Alg. 2 init block: local C, R from the seed state.
    fn handle_init(
        &mut self,
        seed_indices: &[usize],
        seed_points: &[Vec<f64>],
        winv0: &[f64],
    ) {
        let ln = self.shard.len();
        let k0 = seed_indices.len();
        self.k = k0;
        self.z_sel = seed_points.to_vec();
        // C_(i): one batched cross-kernel pull of every seed column's
        // local slice (threads = 1: this worker is one thread of p)
        self.c.resize(k0 * ln, 0.0);
        crate::kernels::kernel_cross_columns_into(
            &self.shard.points,
            &*self.kernel,
            seed_points,
            1,
            &mut self.c,
        );
        // W⁻¹ replica
        let l = self.max_cols;
        for i in 0..k0 {
            for j in 0..k0 {
                self.winv[i * l + j] = winv0[i * k0 + j];
            }
        }
        // R_(i) = W⁻¹ C_(i)ᵀ
        self.r.resize(k0 * ln, 0.0);
        for t in 0..k0 {
            for i in 0..ln {
                let mut acc = 0.0;
                for u in 0..k0 {
                    acc += self.winv[t * l + u] * self.c[u * ln + i];
                }
                self.r[t * ln + i] = acc;
            }
        }
        // mark locally-owned seed columns
        for &g in seed_indices {
            if self.shard.owns(g) {
                let li = self.shard.local(g);
                self.selected_local[li] = true;
            }
        }
    }

    /// Paper Alg. 2 per-iteration block: incorporate the broadcast point.
    fn handle_selected(&mut self, global_idx: usize, point: &[f64], delta: f64) {
        let ln = self.shard.len();
        let k = self.k;
        let l = self.max_cols;
        let s = 1.0 / delta;
        // b = g(Z_Λ, z_new) — computable from the replica, no comms
        let b: Vec<f64> = self.z_sel.iter().map(|zp| self.kernel.eval(zp, point)).collect();
        // q = W⁻¹ b — uses the same unrolled dot kernel as the sequential
        // sampler so rounding (and thus near-threshold selections) agree
        // bit-for-bit
        let mut q = vec![0.0; k];
        for t in 0..k {
            let row = &self.winv[t * l..t * l + k];
            q[t] = crate::linalg::matrix::dot(row, &b);
        }
        // local new column c_new = g(Z_(i), z_new) — the per-step column
        // pull, through the same batched fill as the seed phase
        let mut c_new = vec![0.0; ln];
        crate::kernels::kernel_cross_columns_into(
            &self.shard.points,
            &*self.kernel,
            std::slice::from_ref(&point),
            1,
            &mut c_new,
        );
        // diff = C_(i) q − c_new  (local slice of Cq − c_new; t-outer
        // streaming, see EXPERIMENTS.md §Perf)
        for (o, &cv) in self.diff.iter_mut().zip(&c_new) {
            *o = -cv;
        }
        for (t, &qt) in q.iter().enumerate() {
            if qt == 0.0 {
                continue;
            }
            let ct = &self.c[t * ln..(t + 1) * ln];
            for (o, &cv) in self.diff.iter_mut().zip(ct) {
                *o += qt * cv;
            }
        }
        // Eq. 5 on the W⁻¹ replica
        for i in 0..k {
            for j in 0..k {
                self.winv[i * l + j] += s * q[i] * q[j];
            }
            self.winv[i * l + k] = -s * q[i];
            self.winv[k * l + i] = -s * q[i];
        }
        self.winv[k * l + k] = s;
        // Eq. 6 on R_(i)
        for t in 0..k {
            let f = s * q[t];
            let row = &mut self.r[t * ln..(t + 1) * ln];
            for (o, &dv) in row.iter_mut().zip(&self.diff) {
                *o += f * dv;
            }
        }
        self.r.resize((k + 1) * ln, 0.0);
        for i in 0..ln {
            self.r[k * ln + i] = -s * self.diff[i];
        }
        // append column, replica bookkeeping
        self.c.extend_from_slice(&c_new);
        self.z_sel.push(point.to_vec());
        self.k = k + 1;
        if self.shard.owns(global_idx) {
            self.selected_local[self.shard.local(global_idx)] = true;
        }
    }

    /// Local Δ = d − colsum(C∘R) and shard argmax → leader.
    fn send_argmax(&mut self) {
        let ln = self.shard.len();
        let k = self.k;
        // t-outer streaming sweep (EXPERIMENTS.md §Perf)
        self.delta.copy_from_slice(&self.d);
        for t in 0..k {
            let ct = &self.c[t * ln..(t + 1) * ln];
            let rt = &self.r[t * ln..(t + 1) * ln];
            for ((o, &cv), &rv) in self.delta.iter_mut().zip(ct).zip(rt) {
                *o -= cv * rv;
            }
        }
        let mut best: Option<(usize, f64)> = None;
        let mut sum_abs_delta = 0.0f64;
        for i in 0..ln {
            if self.selected_local[i] {
                continue;
            }
            let a = self.delta[i].abs();
            sum_abs_delta += a;
            match best {
                Some((_, bd)) if self.delta_abs(bd) >= a => {}
                _ => best = Some((self.shard.start + i, self.delta[i])),
            }
        }
        let d_max = self.d.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        let d_sum = self.d.iter().map(|x| x.abs()).sum();
        self.leader.send(FromWorker::Argmax {
            worker: self.id,
            best,
            d_max,
            sum_abs_delta,
            d_sum,
        });
    }

    #[inline]
    fn delta_abs(&self, d: f64) -> f64 {
        d.abs()
    }

    /// Final gather: the local C block (row-major local_n × k).
    fn send_columns(&mut self) {
        let ln = self.shard.len();
        let k = self.k;
        let mut block = vec![0.0; ln * k];
        for i in 0..ln {
            for t in 0..k {
                block[i * k + t] = self.c[t * ln + i];
            }
        }
        let winv = if self.id == 0 {
            let l = self.max_cols;
            let mut w = vec![0.0; k * k];
            for i in 0..k {
                for j in 0..k {
                    w[i * k + j] = self.winv[i * l + j];
                }
            }
            Some(w)
        } else {
            None
        };
        self.leader.send(FromWorker::Columns {
            worker: self.id,
            start: self.shard.start,
            local_n: ln,
            c_block: block,
            winv,
        });
    }
}
