//! The oASIS-P worker node (paper Alg. 2, "On each node (i)" blocks).
//!
//! Each worker owns one or more contiguous row [`Segment`]s of the
//! dataset — exactly one until a re-shard makes it adopt a dead peer's
//! rows — and maintains per segment:
//! * `d_(i)`  — local kernel diagonal,
//! * `C_(i)`  — local rows of the sampled columns (stored column-major),
//! * `R_(i)`  — local columns of R = W⁻¹Cᵀ,
//!
//! plus worker-global replicas of `W⁻¹` and of the selected points Z_Λ.
//!
//! Per `Selected` broadcast the worker performs the paper's node-local
//! updates: kernel column over its rows, Eq. 5 on the W⁻¹ replica, Eq. 6
//! on each R_(i), then — when the leader asked (`want_argmax`) — computes
//! its local Δ block and replies with its top-B unselected candidates
//! (B = `merge_batch`; the SQUEAK-style merge input). At B = 1 this is
//! exactly one small message each way per iteration, bit-identical to the
//! sequential sampler.
//!
//! On `Adopt` (re-shard after a peer died) the worker shard-reads the
//! adopted global row ranges from the dataset file, rebuilds their C from
//! its Z_Λ replica and their R from its W⁻¹ replica, and marks
//! already-selected rows — so the run completes with the survivors
//! serving the whole dataset.

use super::comm::{FromWorker, LeaderHandle, ToWorker, WorkerSource};
use super::config::FailureSpec;
use super::metrics::Metrics;
use crate::data::{loader, Dataset, LoadLimits, Shard};
use crate::kernels::Kernel;
use crate::obs::trace::OwnedEvent;
use crate::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// Per-worker knobs beyond the shard itself, shared by both transports
/// (the channel transport fills it from `OasisPConfig`, the TCP worker
/// process from the leader's `Assign` handshake).
pub struct WorkerOpts {
    /// ℓ — the W⁻¹ replica stride / column capacity.
    pub max_cols: usize,
    /// B — candidates per argmax reply (SQUEAK merge width).
    pub merge_batch: usize,
    /// optional injected fault (tests): the worker "crashes" (signals
    /// `Gone` and stops) right before its `at_iteration`-th update.
    pub failure: Option<FailureSpec>,
    /// Where to shard-read adopted rows from after a re-shard. `None`
    /// for in-memory plans — such workers cannot adopt.
    pub file_source: Option<(PathBuf, LoadLimits)>,
    /// Artificial per-update delay (CLI `--throttle-ms`; lets the CI
    /// smoke job kill a worker mid-run deterministically).
    pub throttle: Option<std::time::Duration>,
    /// Ship drained trace events leader-ward as
    /// [`FromWorker::TraceChunk`]s on gather boundaries. Only TCP worker
    /// processes set this (from `Assign.trace`); in-process workers
    /// share the leader's ring and must never drain it.
    pub ship_trace: bool,
    /// Keep a local copy of drained events; [`Worker::run`] returns them
    /// so `oasis worker --trace FILE` can write its own trace.
    pub keep_trace: bool,
}

impl WorkerOpts {
    pub fn new(max_cols: usize) -> WorkerOpts {
        WorkerOpts {
            max_cols,
            merge_batch: 1,
            failure: None,
            file_source: None,
            throttle: None,
            ship_trace: false,
            keep_trace: false,
        }
    }
}

/// One contiguous run of globally-indexed rows this worker serves, with
/// its slice of the algorithm state.
struct Segment {
    /// global index of the first row
    start: usize,
    points: Dataset,
    /// local kernel diagonal
    d: Vec<f64>,
    /// local C, column-major: column t at c[t*ln .. (t+1)*ln]
    c: Vec<f64>,
    /// local R, row-major rows of length ln
    r: Vec<f64>,
    /// which local rows are already selected
    selected: Vec<bool>,
    /// scratch
    diff: Vec<f64>,
    delta: Vec<f64>,
}

impl Segment {
    fn new(start: usize, points: Dataset, kernel: &dyn Kernel) -> Segment {
        let _g = crate::obs::span("diag_pass", "worker");
        let ln = points.n();
        let d = (0..ln).map(|i| kernel.diag_value(points.point(i))).collect();
        Segment {
            start,
            points,
            d,
            c: Vec::new(),
            r: Vec::new(),
            selected: vec![false; ln],
            diff: vec![0.0; ln],
            delta: vec![0.0; ln],
        }
    }

    fn len(&self) -> usize {
        self.points.n()
    }

    fn owns(&self, g: usize) -> bool {
        g >= self.start && g < self.start + self.len()
    }
}

/// Long-lived state of one worker node (thread or process).
pub struct Worker {
    pub id: usize,
    /// owned row segments, kept sorted by `start` so the candidate scan
    /// walks global indices in ascending order (the tie-break the
    /// sequential sampler uses)
    segments: Vec<Segment>,
    kernel: Arc<dyn Kernel + Send + Sync>,
    leader: LeaderHandle,
    metrics: Arc<Metrics>,
    opts: WorkerOpts,

    // --- worker-global algorithm state ---
    /// W⁻¹ replica, strided by max_cols
    winv: Vec<f64>,
    /// replica of the selected points (in selection order)
    z_sel: Vec<Vec<f64>>,
    k: usize,
    /// leader epoch of the last Init/Selected/Adopt processed; stamped
    /// on outgoing argmaxes
    epoch: u64,
    /// iteration counter for fault injection
    iteration: usize,
    /// local copy of drained trace events (only when `opts.keep_trace`)
    kept_trace: Vec<OwnedEvent>,
    /// ring overflow count accumulated across drains
    kept_dropped: u64,
}

impl Worker {
    pub fn new(
        id: usize,
        shard: Shard,
        kernel: Arc<dyn Kernel + Send + Sync>,
        leader: LeaderHandle,
        metrics: Arc<Metrics>,
        opts: WorkerOpts,
    ) -> Worker {
        let seg = Segment::new(shard.start, shard.points, &*kernel);
        let max_cols = opts.max_cols;
        Worker {
            id,
            segments: vec![seg],
            kernel,
            leader,
            metrics,
            opts,
            winv: vec![0.0; max_cols * max_cols],
            z_sel: Vec::new(),
            k: 0,
            epoch: 0,
            iteration: 0,
            kept_trace: Vec::new(),
            kept_dropped: 0,
        }
    }

    /// Drain the process-global trace ring and fan the events out to the
    /// configured sinks: leader-ward as a [`FromWorker::TraceChunk`]
    /// (`ship_trace`) and/or the local accumulator (`keep_trace`). A
    /// worker with neither sink never touches the ring — in-process
    /// workers share it with the leader, whose CLI drains it itself.
    fn flush_trace(&mut self) {
        if !self.opts.ship_trace && !self.opts.keep_trace {
            return;
        }
        let t = crate::obs::trace::drain();
        self.kept_dropped += t.dropped;
        if t.events.is_empty() {
            return;
        }
        let events: Vec<OwnedEvent> =
            t.events.iter().map(|e| e.to_owned_event()).collect();
        if self.opts.keep_trace {
            self.kept_trace.extend(events.iter().cloned());
        }
        if self.opts.ship_trace {
            self.leader.send(&FromWorker::TraceChunk {
                worker: self.id,
                events,
            });
        }
    }

    /// Terminal trace flush: whatever is still in the ring, then the
    /// kept local copy (plus drop count) for the caller to persist.
    fn into_trace(mut self) -> (Vec<OwnedEvent>, u64) {
        self.flush_trace();
        (std::mem::take(&mut self.kept_trace), self.kept_dropped)
    }

    /// The worker body: process leader messages until Finish (or link
    /// loss). Generic over the inbound side so thread workers run off an
    /// mpsc receiver and TCP worker processes off a frame-decoding
    /// socket reader. Returns the locally kept trace events (empty
    /// unless `opts.keep_trace`) and the ring-overflow count.
    pub fn run(mut self, mut inbox: impl WorkerSource) -> (Vec<OwnedEvent>, u64) {
        while let Some(msg) = inbox.recv() {
            let t0 = std::time::Instant::now();
            match msg {
                ToWorker::FetchPoint { global_idx } => {
                    match self.point_of(global_idx) {
                        Some(point) => {
                            self.leader
                                .send(&FromWorker::Point { global_idx, point });
                        }
                        None => {
                            self.leader.send(&FromWorker::Failed {
                                worker: self.id,
                                message: format!(
                                    "asked for point {global_idx} outside the \
                                     rows this worker owns"
                                ),
                            });
                            return self.into_trace();
                        }
                    }
                }
                ToWorker::Init { seed_indices, seed_points, winv0 } => {
                    self.handle_init(&seed_indices, &seed_points, &winv0);
                    self.send_argmax();
                }
                ToWorker::Selected {
                    global_idx,
                    point,
                    delta,
                    epoch,
                    want_argmax,
                } => {
                    self.iteration += 1;
                    self.epoch = epoch;
                    if let Some(f) = self.opts.failure {
                        if f.worker == self.id && self.iteration >= f.at_iteration
                        {
                            // simulate a crashed node: signal death the
                            // way a TCP reader would (EOF → Gone) and stop
                            self.leader
                                .send(&FromWorker::Gone { worker: self.id });
                            return self.into_trace();
                        }
                    }
                    if let Some(t) = self.opts.throttle {
                        std::thread::sleep(t);
                    }
                    if let Err(m) = self.handle_selected(global_idx, &point, delta)
                    {
                        self.leader.send(&FromWorker::Failed {
                            worker: self.id,
                            message: m,
                        });
                        return self.into_trace();
                    }
                    if want_argmax {
                        self.send_argmax();
                    }
                }
                ToWorker::Adopt { epoch, ranges, selected, want_argmax } => {
                    self.epoch = epoch;
                    if let Err(e) = self.handle_adopt(&ranges, &selected) {
                        self.leader.send(&FromWorker::Failed {
                            worker: self.id,
                            message: format!("adopting re-sharded rows: {e}"),
                        });
                        return self.into_trace();
                    }
                    if want_argmax {
                        self.send_argmax();
                    }
                }
                ToWorker::GatherColumns { winv } => {
                    // mid-run snapshot: same gather as Finish, but the
                    // worker stays alive for further selection rounds.
                    // Flush first: the FIFO link guarantees the chunk
                    // lands before the Columns the leader is waiting on.
                    self.flush_trace();
                    self.send_columns(winv);
                }
                ToWorker::Finish { winv } => {
                    self.flush_trace();
                    self.send_columns(winv);
                    return self.into_trace();
                }
            }
            self.metrics.add_worker_compute(t0.elapsed());
        }
        self.into_trace()
    }

    fn point_of(&self, g: usize) -> Option<Vec<f64>> {
        self.segments
            .iter()
            .find(|s| s.owns(g))
            .map(|s| s.points.point(g - s.start).to_vec())
    }

    /// Paper Alg. 2 init block: local C, R from the seed state.
    fn handle_init(
        &mut self,
        seed_indices: &[usize],
        seed_points: &[Vec<f64>],
        winv0: &[f64],
    ) {
        let k0 = seed_indices.len();
        self.k = k0;
        self.z_sel = seed_points.to_vec();
        // W⁻¹ replica
        let l = self.opts.max_cols;
        for i in 0..k0 {
            for j in 0..k0 {
                self.winv[i * l + j] = winv0[i * k0 + j];
            }
        }
        for seg in &mut self.segments {
            let ln = seg.len();
            // C_(i): one batched cross-kernel pull of every seed column's
            // local slice (threads = 1: this worker is one node of p)
            seg.c.resize(k0 * ln, 0.0);
            crate::kernels::kernel_cross_columns_into(
                &seg.points,
                &*self.kernel,
                seed_points,
                1,
                &mut seg.c,
            );
            // R_(i) = W⁻¹ C_(i)ᵀ
            seg.r.resize(k0 * ln, 0.0);
            for t in 0..k0 {
                for i in 0..ln {
                    let mut acc = 0.0;
                    for u in 0..k0 {
                        acc += self.winv[t * l + u] * seg.c[u * ln + i];
                    }
                    seg.r[t * ln + i] = acc;
                }
            }
            // mark locally-owned seed columns
            for &g in seed_indices {
                if seg.owns(g) {
                    seg.selected[g - seg.start] = true;
                }
            }
        }
    }

    /// Paper Alg. 2 per-iteration block: incorporate the broadcast point.
    /// `delta` is `None` for a queued batch candidate — then Δ' is
    /// recomputed from the replicas (see [`ToWorker::Selected`]); the
    /// error return is the vanished-Δ diagnostic.
    fn handle_selected(
        &mut self,
        global_idx: usize,
        point: &[f64],
        delta: Option<f64>,
    ) -> std::result::Result<(), String> {
        let _g = crate::obs::span("shard_update", "worker");
        let k = self.k;
        let l = self.opts.max_cols;
        // b = g(Z_Λ, z_new) — computable from the replica, no comms
        let b: Vec<f64> =
            self.z_sel.iter().map(|zp| self.kernel.eval(zp, point)).collect();
        // q = W⁻¹ b — uses the same unrolled dot kernel as the sequential
        // sampler so rounding (and thus near-threshold selections) agree
        // bit-for-bit
        let mut q = vec![0.0; k];
        for t in 0..k {
            let row = &self.winv[t * l..t * l + k];
            q[t] = crate::linalg::matrix::dot(row, &b);
        }
        let delta = match delta {
            // the fresh argmax winner ships its sweep Δ (always at B=1)
            Some(d) => d,
            // queued batch candidate: Δ' = k(z,z) − bᵀq against the
            // *current* replicas — identical on every worker, and exact,
            // so Eq. 5/6 below stay exact Schur-complement updates
            None => {
                self.kernel.diag_value(point)
                    - crate::linalg::matrix::dot(&b, &q)
            }
        };
        let s = 1.0 / delta;
        if !s.is_finite() {
            return Err(format!(
                "batch candidate Δ vanished (Δ' = {delta:e}) — rerun with \
                 --merge-batch 1"
            ));
        }
        for seg in &mut self.segments {
            let ln = seg.len();
            // local new column c_new = g(Z_(i), z_new) — the per-step
            // column pull, through the same batched fill as the seed phase
            let mut c_new = vec![0.0; ln];
            crate::kernels::kernel_cross_columns_into(
                &seg.points,
                &*self.kernel,
                std::slice::from_ref(&point),
                1,
                &mut c_new,
            );
            // diff = C_(i) q − c_new  (local slice of Cq − c_new; t-outer
            // streaming, see EXPERIMENTS.md §Perf)
            for (o, &cv) in seg.diff.iter_mut().zip(&c_new) {
                *o = -cv;
            }
            for (t, &qt) in q.iter().enumerate() {
                if qt == 0.0 {
                    continue;
                }
                let ct = &seg.c[t * ln..(t + 1) * ln];
                for (o, &cv) in seg.diff.iter_mut().zip(ct) {
                    *o += qt * cv;
                }
            }
            // Eq. 6 on R_(i)
            for t in 0..k {
                let f = s * q[t];
                let row = &mut seg.r[t * ln..(t + 1) * ln];
                for (o, &dv) in row.iter_mut().zip(&seg.diff) {
                    *o += f * dv;
                }
            }
            seg.r.resize((k + 1) * ln, 0.0);
            for i in 0..ln {
                seg.r[k * ln + i] = -s * seg.diff[i];
            }
            seg.c.extend_from_slice(&c_new);
            if seg.owns(global_idx) {
                seg.selected[global_idx - seg.start] = true;
            }
        }
        // Eq. 5 on the W⁻¹ replica
        for i in 0..k {
            for j in 0..k {
                self.winv[i * l + j] += s * q[i] * q[j];
            }
            self.winv[i * l + k] = -s * q[i];
            self.winv[k * l + i] = -s * q[i];
        }
        self.winv[k * l + k] = s;
        self.z_sel.push(point.to_vec());
        self.k = k + 1;
        Ok(())
    }

    /// Re-shard: shard-read the adopted global ranges from the dataset
    /// file and rebuild their slice of the algorithm state — C from the
    /// Z_Λ replica, R = W⁻¹Cᵀ from the W⁻¹ replica (mathematically equal
    /// to the incremental state; recomputation is the price of taking
    /// over mid-run).
    fn handle_adopt(
        &mut self,
        ranges: &[(usize, usize)],
        selected: &[usize],
    ) -> Result<()> {
        if ranges.is_empty() {
            return Ok(()); // epoch-only broadcast
        }
        let _g = crate::obs::span("adopt", "worker");
        let (path, limits) = self
            .opts
            .file_source
            .as_ref()
            .ok_or_else(|| {
                crate::anyhow!(
                    "this worker has no dataset file to shard-read adopted \
                     rows from (in-memory plan)"
                )
            })?
            .clone();
        let l = self.opts.max_cols;
        let k = self.k;
        for &(start, len) in ranges {
            if len == 0 {
                continue;
            }
            let points = loader::load_rows(&path, start, len, &limits)?;
            let mut seg = Segment::new(start, points, &*self.kernel);
            seg.c.resize(k * len, 0.0);
            crate::kernels::kernel_cross_columns_into(
                &seg.points,
                &*self.kernel,
                &self.z_sel,
                1,
                &mut seg.c,
            );
            seg.r.resize(k * len, 0.0);
            for t in 0..k {
                for i in 0..len {
                    let mut acc = 0.0;
                    for u in 0..k {
                        acc += self.winv[t * l + u] * seg.c[u * len + i];
                    }
                    seg.r[t * len + i] = acc;
                }
            }
            for &g in selected {
                if seg.owns(g) {
                    seg.selected[g - seg.start] = true;
                }
            }
            let pos = self
                .segments
                .iter()
                .position(|s| s.start > start)
                .unwrap_or(self.segments.len());
            self.segments.insert(pos, seg);
        }
        Ok(())
    }

    /// Local Δ = d − colsum(C∘R) over every owned segment, then the
    /// top-B unselected candidates (global-ascending scan; ties keep the
    /// lower index, matching the sequential sampler) → leader.
    fn send_argmax(&mut self) {
        let _g = crate::obs::span("score_scan", "worker");
        let k = self.k;
        let bcap = self.opts.merge_batch.max(1);
        let mut cands: Vec<(usize, f64)> = Vec::with_capacity(bcap);
        let mut sum_abs_delta = 0.0f64;
        let mut d_max = 0.0f64;
        let mut d_sum = 0.0f64;
        for seg in &mut self.segments {
            let ln = seg.len();
            // t-outer streaming sweep (EXPERIMENTS.md §Perf)
            seg.delta.copy_from_slice(&seg.d);
            for t in 0..k {
                let ct = &seg.c[t * ln..(t + 1) * ln];
                let rt = &seg.r[t * ln..(t + 1) * ln];
                for ((o, &cv), &rv) in seg.delta.iter_mut().zip(ct).zip(rt) {
                    *o -= cv * rv;
                }
            }
            for i in 0..ln {
                if seg.selected[i] {
                    continue;
                }
                let a = seg.delta[i].abs();
                sum_abs_delta += a;
                // keep `cands` sorted (|Δ| desc, global idx asc): replace
                // only on strictly greater |Δ| — at B=1 this reduces to
                // the sequential sampler's comparison exactly
                if cands.len() == bcap && cands[bcap - 1].1.abs() >= a {
                    continue;
                }
                let pos = cands
                    .iter()
                    .position(|c| c.1.abs() < a)
                    .unwrap_or(cands.len());
                cands.insert(pos, (seg.start + i, seg.delta[i]));
                cands.truncate(bcap);
            }
            d_max = seg.d.iter().fold(d_max, |m, &x| m.max(x.abs()));
            d_sum += seg.d.iter().map(|x| x.abs()).sum::<f64>();
        }
        self.leader.send(&FromWorker::Argmax {
            worker: self.id,
            epoch: self.epoch,
            candidates: cands,
            d_max,
            sum_abs_delta,
            d_sum,
        });
    }

    /// Column gather: one C block per owned segment (row-major
    /// local_n × k); the directed worker attaches its compacted W⁻¹
    /// replica to the first block.
    fn send_columns(&mut self, with_winv: bool) {
        let _g = crate::obs::span("column_serve", "worker");
        let k = self.k;
        let l = self.opts.max_cols;
        let mut winv = if with_winv {
            let mut w = vec![0.0; k * k];
            for i in 0..k {
                for j in 0..k {
                    w[i * k + j] = self.winv[i * l + j];
                }
            }
            Some(w)
        } else {
            None
        };
        for seg in &self.segments {
            let ln = seg.len();
            let mut block = vec![0.0; ln * k];
            for i in 0..ln {
                for t in 0..k {
                    block[i * k + t] = seg.c[t * ln + i];
                }
            }
            self.leader.send(&FromWorker::Columns {
                worker: self.id,
                start: seg.start,
                local_n: ln,
                c_block: block,
                winv: winv.take(),
            });
        }
    }
}
