//! Message types and metered channels — the crate's stand-in for MPI
//! `Broadcast(data)` / `Gather(variable)` (paper Fig. 4).

use super::metrics::Metrics;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Leader → worker messages.
#[derive(Debug, Clone)]
pub enum ToWorker {
    /// Seed state: the initial selected points (Z_Λ₀), their global
    /// indices, and W₀⁻¹ (k₀×k₀ row-major).
    Init {
        seed_indices: Vec<usize>,
        seed_points: Vec<Vec<f64>>,
        winv0: Vec<f64>,
    },
    /// Request the raw data point at a global index this worker owns.
    FetchPoint { global_idx: usize },
    /// The broadcast selected point (paper: `Broadcast(Z(:,i))`): every
    /// worker updates its shard state and replies with its next local
    /// argmax.
    Selected {
        global_idx: usize,
        point: Vec<f64>,
        delta: f64,
    },
    /// Non-terminal column gather (mid-run snapshot): the worker replies
    /// with its current `Columns` block — same payload as the terminal
    /// gather — and keeps running, so the leader can assemble a
    /// [`NystromApprox`](crate::nystrom::NystromApprox) without ending
    /// the run. Serving-style callers use this to hand out the current
    /// factors between selection rounds.
    GatherColumns,
    /// Finish: send back the local C block (and worker 0 its W⁻¹).
    Finish,
}

/// Worker → leader messages.
#[derive(Debug)]
pub enum FromWorker {
    /// Local Δ argmax over this shard (paper: `Gather(Δ_(i))`, reduced).
    Argmax {
        worker: usize,
        /// global index of the best unselected local candidate; None if
        /// the shard is exhausted.
        best: Option<(usize, f64)>, // (global index, signed Δ)
        /// max |diag| over this shard (for the leader's relative
        /// tolerance floor — see `sampling::effective_tol`).
        d_max: f64,
        /// Σ|Δᵢ| over this shard's unselected candidates — lets the
        /// leader maintain the residual-trace error estimate that drives
        /// `StoppingCriterion::ErrorBelow` without extra messages.
        sum_abs_delta: f64,
        /// Σ|dᵢ| over this shard (the estimate's denominator share).
        d_sum: f64,
    },
    /// Reply to `FetchPoint`.
    Point { global_idx: usize, point: Vec<f64> },
    /// Final local C block: rows are this shard's points (local_n × k,
    /// row-major), plus the shard's global start.
    Columns {
        worker: usize,
        start: usize,
        local_n: usize,
        c_block: Vec<f64>,
        /// worker 0 also returns the replicated W⁻¹ (k×k row-major)
        winv: Option<Vec<f64>>,
    },
    /// A worker failed (injected fault or internal error).
    Failed { worker: usize, message: String },
}

impl ToWorker {
    /// Approximate serialized payload size in bytes (for the
    /// communication-volume metrics; 8 bytes per f64, 8 per index).
    pub fn payload_bytes(&self) -> u64 {
        match self {
            ToWorker::Init { seed_indices, seed_points, winv0 } => {
                (seed_indices.len() * 8
                    + seed_points.iter().map(|p| p.len() * 8).sum::<usize>()
                    + winv0.len() * 8) as u64
            }
            ToWorker::FetchPoint { .. } => 8,
            ToWorker::Selected { point, .. } => (point.len() * 8 + 16) as u64,
            ToWorker::GatherColumns => 1,
            ToWorker::Finish => 1,
        }
    }
}

impl FromWorker {
    pub fn payload_bytes(&self) -> u64 {
        match self {
            FromWorker::Argmax { .. } => 48,
            FromWorker::Point { point, .. } => (point.len() * 8 + 8) as u64,
            FromWorker::Columns { c_block, winv, .. } => {
                (c_block.len() * 8 + winv.as_ref().map_or(0, |w| w.len() * 8) + 24)
                    as u64
            }
            FromWorker::Failed { message, .. } => message.len() as u64,
        }
    }
}

/// Leader-side handle to one worker's inbox, metering broadcast bytes.
pub struct WorkerHandle {
    pub worker: usize,
    tx: Sender<ToWorker>,
    metrics: Arc<Metrics>,
}

impl WorkerHandle {
    pub fn new(worker: usize, tx: Sender<ToWorker>, metrics: Arc<Metrics>) -> Self {
        WorkerHandle { worker, tx, metrics }
    }

    /// Send (records payload bytes). Returns false if the worker is gone.
    pub fn send(&self, msg: ToWorker) -> bool {
        self.metrics.add_broadcast(msg.payload_bytes());
        self.tx.send(msg).is_ok()
    }
}

/// Worker-side handle to the leader's shared inbox, metering gather bytes.
#[derive(Clone)]
pub struct LeaderHandle {
    tx: Sender<FromWorker>,
    metrics: Arc<Metrics>,
}

impl LeaderHandle {
    pub fn new(tx: Sender<FromWorker>, metrics: Arc<Metrics>) -> Self {
        LeaderHandle { tx, metrics }
    }

    pub fn send(&self, msg: FromWorker) -> bool {
        self.metrics.add_gather(msg.payload_bytes());
        self.tx.send(msg).is_ok()
    }
}

/// The leader's receiving end.
pub type LeaderInbox = Receiver<FromWorker>;
/// A worker's receiving end.
pub type WorkerInbox = Receiver<ToWorker>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_accounting() {
        let m = ToWorker::Selected {
            global_idx: 3,
            point: vec![0.0; 10],
            delta: 0.5,
        };
        assert_eq!(m.payload_bytes(), 96);
        let g = FromWorker::Point { global_idx: 1, point: vec![0.0; 4] };
        assert_eq!(g.payload_bytes(), 40);
    }

    #[test]
    fn handles_meter_traffic() {
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = std::sync::mpsc::channel();
        let h = WorkerHandle::new(0, tx, metrics.clone());
        assert!(h.send(ToWorker::FetchPoint { global_idx: 5 }));
        assert_eq!(metrics.broadcast_bytes(), 8);
        drop(rx);
        assert!(!h.send(ToWorker::Finish));
    }
}
