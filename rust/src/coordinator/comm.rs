//! Message types and transport-agnostic endpoints — the crate's stand-in
//! for MPI `Broadcast(data)` / `Gather(variable)` (paper Fig. 4), now
//! spoken over either in-process channels or TCP sockets (see
//! [`transport`](super::transport) and [`net`](super::net)).
//!
//! The leader talks to each worker through a [`WorkerHandle`] wrapping a
//! boxed [`WorkerSink`]; workers talk back through a [`LeaderHandle`]
//! wrapping a shared [`LeaderSink`]. Both transports funnel worker →
//! leader traffic into one mpsc channel (the [`LeaderInbox`]) so the
//! leader's receive loop is transport-agnostic; per-message gather
//! accounting happens on dequeue at the leader, broadcast accounting in
//! [`WorkerHandle::send`].

use super::metrics::Metrics;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Leader → worker messages.
#[derive(Debug, Clone)]
pub enum ToWorker {
    /// Seed state: the initial selected points (Z_Λ₀), their global
    /// indices, and W₀⁻¹ (k₀×k₀ row-major).
    Init {
        seed_indices: Vec<usize>,
        seed_points: Vec<Vec<f64>>,
        winv0: Vec<f64>,
    },
    /// Request the raw data point at a global index this worker owns.
    FetchPoint { global_idx: usize },
    /// The broadcast selected point (paper: `Broadcast(Z(:,i))`): every
    /// worker updates its shard state (Eq. 5/6).
    Selected {
        global_idx: usize,
        point: Vec<f64>,
        /// The winner's sweep Δ when this pick is the fresh argmax of a
        /// gather round (always the case at `merge_batch == 1`). `None`
        /// for a queued batch candidate: its gathered Δ is stale after
        /// the earlier picks of the batch, so every worker recomputes
        /// Δ' = k(z,z) − bᵀq from its replicas — deterministic across
        /// workers and exact against the current W⁻¹, which keeps the
        /// factor updates exact even though the selection *order* is the
        /// SQUEAK-style approximation.
        delta: Option<f64>,
        /// Leader epoch (bumped on every re-shard); workers stamp their
        /// argmax replies with it so the leader can discard replies that
        /// raced a re-shard.
        epoch: u64,
        /// Reply with a local argmax after updating. True for the last
        /// pick of a batch (and always at `merge_batch == 1`, preserving
        /// the paper's one-gather-per-column message pattern);
        /// intermediate batch picks skip the Δ sweep entirely — the
        /// SQUEAK compute win.
        want_argmax: bool,
    },
    /// Non-terminal column gather (mid-run snapshot): the worker replies
    /// with one `Columns` block per owned segment — same payload as the
    /// terminal gather — and keeps running. `winv` directs exactly one
    /// live worker (the lowest-numbered) to also ship its W⁻¹ replica.
    GatherColumns { winv: bool },
    /// Re-shard after a worker death: the receiver additionally owns
    /// `ranges` (global `(start, len)` row ranges) from now on. It
    /// shard-reads those rows from the dataset file, rebuilds their C
    /// and R state from its Z_Λ and W⁻¹ replicas, and marks the rows in
    /// `selected` (the selection order so far) as taken. Broadcast to
    /// every survivor — possibly with empty `ranges` — so all workers
    /// advance to the new `epoch` together.
    Adopt {
        epoch: u64,
        ranges: Vec<(usize, usize)>,
        selected: Vec<usize>,
        /// send a fresh argmax after adopting (restarts the gather round
        /// the death interrupted)
        want_argmax: bool,
    },
    /// Finish: send back the local C block(s) (and, when `winv` is set,
    /// the W⁻¹ replica), then exit.
    Finish { winv: bool },
}

/// Worker → leader messages.
#[derive(Debug, Clone)]
pub enum FromWorker {
    /// Local Δ argmax over this worker's rows (paper: `Gather(Δ_(i))`,
    /// reduced), extended SQUEAK-style to the top-B local candidates.
    Argmax {
        worker: usize,
        /// epoch of the leader message that triggered this sweep; the
        /// leader discards replies from before the latest re-shard
        epoch: u64,
        /// up to `merge_batch` best unselected local candidates, best
        /// first: (global index, signed Δ). Empty if the worker's rows
        /// are exhausted.
        candidates: Vec<(usize, f64)>,
        /// max |diag| over this worker's rows (for the leader's relative
        /// tolerance floor — see `sampling::effective_tol`).
        d_max: f64,
        /// Σ|Δᵢ| over this worker's unselected candidates — lets the
        /// leader maintain the residual-trace error estimate that drives
        /// `StoppingCriterion::ErrorBelow` without extra messages.
        sum_abs_delta: f64,
        /// Σ|dᵢ| over this worker's rows (the estimate's denominator
        /// share).
        d_sum: f64,
    },
    /// Reply to `FetchPoint`.
    Point { global_idx: usize, point: Vec<f64> },
    /// One owned segment's C block: rows are the segment's points
    /// (local_n × k, row-major) starting at global row `start`. A worker
    /// owning several segments (post-adoption) sends one per segment.
    Columns {
        worker: usize,
        start: usize,
        local_n: usize,
        c_block: Vec<f64>,
        /// the directed worker also returns the replicated W⁻¹ (k×k
        /// row-major) with its first block
        winv: Option<Vec<f64>>,
    },
    /// A worker hit a deterministic error (bad file, protocol breach,
    /// vanished batch Δ). Always fatal to the run — node *deaths* are
    /// signalled by `Gone` instead, so a clear diagnostic is never
    /// silently "recovered" away.
    Failed { worker: usize, message: String },
    /// Periodic liveness beacon from a TCP worker process (period:
    /// [`OasisPConfig::heartbeat_interval`]). Swallowed by the leader's
    /// receive loop — it only refreshes the worker's last-seen age.
    ///
    /// [`OasisPConfig::heartbeat_interval`]: super::config::OasisPConfig::heartbeat_interval
    Heartbeat { worker: usize },
    /// A batch of this worker process's local trace events, shipped
    /// leader-ward when the `Assign` handshake requested tracing.
    /// Piggybacked on gather rounds and flushed before the terminal
    /// `Columns` block; the leader absorbs chunks into per-worker
    /// stores and merges them into the fleet trace
    /// ([`OasisPReport::worker_traces`]).
    ///
    /// [`OasisPReport::worker_traces`]: super::leader::OasisPReport::worker_traces
    TraceChunk { worker: usize, events: Vec<crate::obs::trace::OwnedEvent> },
    /// The worker is dead: synthesized locally on the leader (TCP reader
    /// EOF / socket error / heartbeat staleness) or by the in-process
    /// fault injector — never encoded on the wire. Triggers re-sharding
    /// when the plan is recoverable.
    Gone { worker: usize },
}

impl ToWorker {
    /// Approximate serialized payload size in bytes (for the
    /// communication-volume metrics; 8 bytes per f64, 8 per index).
    pub fn payload_bytes(&self) -> u64 {
        match self {
            ToWorker::Init { seed_indices, seed_points, winv0 } => {
                (seed_indices.len() * 8
                    + seed_points.iter().map(|p| p.len() * 8).sum::<usize>()
                    + winv0.len() * 8) as u64
            }
            ToWorker::FetchPoint { .. } => 8,
            ToWorker::Selected { point, .. } => (point.len() * 8 + 26) as u64,
            ToWorker::GatherColumns { .. } => 2,
            ToWorker::Adopt { ranges, selected, .. } => {
                (ranges.len() * 16 + selected.len() * 8 + 10) as u64
            }
            ToWorker::Finish { .. } => 2,
        }
    }
}

impl FromWorker {
    pub fn payload_bytes(&self) -> u64 {
        match self {
            FromWorker::Argmax { candidates, .. } => {
                (candidates.len() * 16 + 48) as u64
            }
            FromWorker::Point { point, .. } => (point.len() * 8 + 8) as u64,
            FromWorker::Columns { c_block, winv, .. } => {
                (c_block.len() * 8 + winv.as_ref().map_or(0, |w| w.len() * 8) + 24)
                    as u64
            }
            FromWorker::Failed { message, .. } => message.len() as u64,
            FromWorker::Heartbeat { .. } => 8,
            FromWorker::TraceChunk { events, .. } => {
                events
                    .iter()
                    .map(|e| e.name.len() + e.cat.len() + 45)
                    .sum::<usize>() as u64
                    + 16
            }
            FromWorker::Gone { .. } => 0,
        }
    }

    /// The sending worker, when the variant carries one (`Point` does
    /// not — the leader knows whom it asked).
    pub fn worker_id(&self) -> Option<usize> {
        match self {
            FromWorker::Argmax { worker, .. }
            | FromWorker::Columns { worker, .. }
            | FromWorker::Failed { worker, .. }
            | FromWorker::Heartbeat { worker }
            | FromWorker::TraceChunk { worker, .. }
            | FromWorker::Gone { worker } => Some(*worker),
            FromWorker::Point { .. } => None,
        }
    }
}

/// Leader-side outbound half of one worker link. Implemented by the
/// in-process channel sender and by the TCP frame writer.
pub trait WorkerSink: Send {
    /// Deliver `msg`; false if the worker is unreachable.
    fn send(&self, msg: &ToWorker) -> bool;
}

/// Worker-side outbound half of the leader link. `Sync` because a TCP
/// worker's heartbeat thread shares the stream with the compute loop.
pub trait LeaderSink: Send + Sync {
    fn send(&self, msg: &FromWorker) -> bool;
}

/// [`WorkerSink`] over an in-process channel.
pub struct ChannelWorkerSink(pub Sender<ToWorker>);

impl WorkerSink for ChannelWorkerSink {
    fn send(&self, msg: &ToWorker) -> bool {
        self.0.send(msg.clone()).is_ok()
    }
}

/// [`LeaderSink`] over an in-process channel (mutex-wrapped: `Sender` is
/// not `Sync` on every std version we target).
pub struct ChannelLeaderSink(pub Mutex<Sender<FromWorker>>);

impl LeaderSink for ChannelLeaderSink {
    fn send(&self, msg: &FromWorker) -> bool {
        match self.0.lock() {
            Ok(tx) => tx.send(msg.clone()).is_ok(),
            Err(_) => false,
        }
    }
}

/// Leader-side handle to one worker, metering broadcast bytes (totals
/// plus the per-worker wire ledger).
pub struct WorkerHandle {
    pub worker: usize,
    sink: Box<dyn WorkerSink>,
    metrics: Arc<Metrics>,
}

impl WorkerHandle {
    pub fn new(
        worker: usize,
        sink: Box<dyn WorkerSink>,
        metrics: Arc<Metrics>,
    ) -> Self {
        WorkerHandle { worker, sink, metrics }
    }

    /// Convenience constructor over an in-process channel.
    pub fn channel(
        worker: usize,
        tx: Sender<ToWorker>,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::new(worker, Box::new(ChannelWorkerSink(tx)), metrics)
    }

    /// Send (records payload bytes). Returns false if the worker is gone.
    pub fn send(&self, msg: &ToWorker) -> bool {
        let bytes = msg.payload_bytes();
        self.metrics.add_broadcast(bytes);
        self.metrics.add_worker_wire(self.worker, bytes);
        self.sink.send(msg)
    }
}

/// Worker-side handle to the leader. Gather-volume accounting happens at
/// the leader on dequeue (the only place both transports share), so this
/// handle is a plain forwarding wrapper.
#[derive(Clone)]
pub struct LeaderHandle {
    sink: Arc<dyn LeaderSink>,
}

impl LeaderHandle {
    pub fn new(sink: Arc<dyn LeaderSink>) -> Self {
        LeaderHandle { sink }
    }

    /// Convenience constructor over an in-process channel.
    pub fn channel(tx: Sender<FromWorker>) -> Self {
        Self::new(Arc::new(ChannelLeaderSink(Mutex::new(tx))))
    }

    pub fn send(&self, msg: &FromWorker) -> bool {
        self.sink.send(msg)
    }
}

/// Worker-side inbound half of the leader link: the in-process channel
/// receiver, or a frame-decoding socket reader for TCP workers.
pub trait WorkerSource {
    /// Next leader message; `None` when the link is closed.
    fn recv(&mut self) -> Option<ToWorker>;
}

impl WorkerSource for Receiver<ToWorker> {
    fn recv(&mut self) -> Option<ToWorker> {
        Receiver::recv(self).ok()
    }
}

/// The leader's receiving end — both transports bridge into this.
pub type LeaderInbox = Receiver<FromWorker>;
/// A worker's receiving end (channel transport).
pub type WorkerInbox = Receiver<ToWorker>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_accounting() {
        let m = ToWorker::Selected {
            global_idx: 3,
            point: vec![0.0; 10],
            delta: Some(0.5),
            epoch: 0,
            want_argmax: true,
        };
        assert_eq!(m.payload_bytes(), 106);
        let g = FromWorker::Point { global_idx: 1, point: vec![0.0; 4] };
        assert_eq!(g.payload_bytes(), 40);
        let a = FromWorker::Argmax {
            worker: 1,
            epoch: 0,
            candidates: vec![(4, 0.2), (9, 0.1)],
            d_max: 1.0,
            sum_abs_delta: 0.5,
            d_sum: 2.0,
        };
        assert_eq!(a.payload_bytes(), 80);
        assert_eq!(a.worker_id(), Some(1));
        assert_eq!(g.worker_id(), None);
    }

    #[test]
    fn handles_meter_traffic() {
        let metrics = Arc::new(Metrics::default());
        metrics.register_workers(1);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = WorkerHandle::channel(0, tx, metrics.clone());
        assert!(h.send(&ToWorker::FetchPoint { global_idx: 5 }));
        assert_eq!(metrics.broadcast_bytes(), 8);
        assert_eq!(metrics.worker(0).unwrap().wire_bytes(), 8);
        drop(rx);
        assert!(!h.send(&ToWorker::Finish { winv: false }));
    }

    #[test]
    fn leader_handle_forwards() {
        let (tx, rx) = std::sync::mpsc::channel();
        let h = LeaderHandle::channel(tx);
        assert!(h.send(&FromWorker::Heartbeat { worker: 2 }));
        match rx.recv().unwrap() {
            FromWorker::Heartbeat { worker } => assert_eq!(worker, 2),
            other => panic!("unexpected {other:?}"),
        }
        drop(rx);
        assert!(!h.send(&FromWorker::Gone { worker: 2 }));
    }
}
