//! oASIS-P configuration.

use std::time::Duration;

/// Fault-injection spec for resilience tests: worker `worker` dies right
/// before processing its `at_iteration`-th `Selected` message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailureSpec {
    pub worker: usize,
    pub at_iteration: usize,
}

/// Configuration for a distributed oASIS-P run.
#[derive(Debug, Clone)]
pub struct OasisPConfig {
    /// ℓ — maximum number of sampled columns.
    pub max_cols: usize,
    /// k₀ — random seed columns.
    pub init_cols: usize,
    /// ε — stopping tolerance on |Δ|.
    pub tol: f64,
    /// RNG seed (must match the sequential sampler's for equivalence).
    pub seed: u64,
    /// p — number of worker nodes (threads or TCP processes).
    pub workers: usize,
    /// leader-side timeout waiting for worker messages; also the
    /// heartbeat-staleness threshold past which a silent TCP worker is
    /// declared dead.
    pub timeout: Duration,
    /// B — SQUEAK-style merge batch: each argmax round, every worker
    /// submits its top-B local candidates and the leader arbitrates up
    /// to B selections from the merged list, so argmax rounds drop from
    /// one-per-column to one-per-batch. `1` (the default) reproduces the
    /// paper's one-round-per-column protocol bit-identically to the
    /// sequential sampler; `B > 1` trades exact greedy order for fewer
    /// synchronization rounds (the factor updates stay exact — each
    /// queued candidate's Δ is recomputed against the current W⁻¹).
    pub merge_batch: usize,
    /// optional injected fault (tests).
    pub failure: Option<FailureSpec>,
}

impl OasisPConfig {
    pub fn new(max_cols: usize, init_cols: usize, workers: usize) -> Self {
        OasisPConfig {
            max_cols,
            init_cols,
            tol: 1e-12,
            seed: 7,
            workers,
            timeout: Duration::from_secs(60),
            merge_batch: 1,
            failure: None,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_merge_batch(mut self, b: usize) -> Self {
        self.merge_batch = b;
        self
    }

    /// Worker heartbeat period: frequent enough that several beats fit
    /// inside the staleness threshold (`timeout`), capped at 500 ms.
    pub fn heartbeat_interval(&self) -> Duration {
        (self.timeout / 4).min(Duration::from_millis(500))
    }

    pub fn validate(&self, n: usize) -> crate::Result<()> {
        use crate::bail;
        if self.workers == 0 {
            bail!("workers must be ≥ 1");
        }
        if self.max_cols == 0 || self.init_cols == 0 {
            bail!("max_cols and init_cols must be ≥ 1");
        }
        if self.init_cols > self.max_cols {
            bail!("init_cols > max_cols");
        }
        if self.max_cols > n {
            bail!("max_cols {} > n {}", self.max_cols, n);
        }
        if self.merge_batch == 0 {
            bail!("merge_batch must be ≥ 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        let ok = OasisPConfig::new(10, 2, 4);
        assert!(ok.validate(100).is_ok());
        assert!(ok.validate(5).is_err());
        assert!(OasisPConfig::new(10, 2, 0).validate(100).is_err());
        let mut bad = OasisPConfig::new(10, 2, 4);
        bad.init_cols = 20;
        assert!(bad.validate(100).is_err());
        let mut bad = OasisPConfig::new(10, 2, 4);
        bad.merge_batch = 0;
        assert!(bad.validate(100).is_err());
        assert!(OasisPConfig::new(10, 2, 4)
            .with_merge_batch(8)
            .validate(100)
            .is_ok());
    }

    #[test]
    fn heartbeat_interval_tracks_timeout() {
        let fast = OasisPConfig::new(10, 2, 4); // 60 s timeout → capped
        assert_eq!(fast.heartbeat_interval(), Duration::from_millis(500));
        let mut tight = OasisPConfig::new(10, 2, 4);
        tight.timeout = Duration::from_millis(800);
        assert_eq!(tight.heartbeat_interval(), Duration::from_millis(200));
    }
}
