//! oASIS-P configuration.

use std::time::Duration;

/// Fault-injection spec for resilience tests: worker `worker` dies right
/// before processing its `at_iteration`-th `Selected` message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailureSpec {
    pub worker: usize,
    pub at_iteration: usize,
}

/// Configuration for a distributed oASIS-P run.
#[derive(Debug, Clone)]
pub struct OasisPConfig {
    /// ℓ — maximum number of sampled columns.
    pub max_cols: usize,
    /// k₀ — random seed columns.
    pub init_cols: usize,
    /// ε — stopping tolerance on |Δ|.
    pub tol: f64,
    /// RNG seed (must match the sequential sampler's for equivalence).
    pub seed: u64,
    /// p — number of worker nodes (threads).
    pub workers: usize,
    /// leader-side timeout waiting for worker messages.
    pub timeout: Duration,
    /// optional injected fault (tests).
    pub failure: Option<FailureSpec>,
}

impl OasisPConfig {
    pub fn new(max_cols: usize, init_cols: usize, workers: usize) -> Self {
        OasisPConfig {
            max_cols,
            init_cols,
            tol: 1e-12,
            seed: 7,
            workers,
            timeout: Duration::from_secs(60),
            failure: None,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn validate(&self, n: usize) -> crate::Result<()> {
        use crate::bail;
        if self.workers == 0 {
            bail!("workers must be ≥ 1");
        }
        if self.max_cols == 0 || self.init_cols == 0 {
            bail!("max_cols and init_cols must be ≥ 1");
        }
        if self.init_cols > self.max_cols {
            bail!("init_cols > max_cols");
        }
        if self.max_cols > n {
            bail!("max_cols {} > n {}", self.max_cols, n);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        let ok = OasisPConfig::new(10, 2, 4);
        assert!(ok.validate(100).is_ok());
        assert!(ok.validate(5).is_err());
        assert!(OasisPConfig::new(10, 2, 0).validate(100).is_err());
        let mut bad = OasisPConfig::new(10, 2, 4);
        bad.init_cols = 20;
        assert!(bad.validate(100).is_err());
    }
}
