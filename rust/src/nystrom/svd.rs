//! Approximate eigendecomposition of G from its Nyström factors
//! (paper §II-C): the whole point of the approximation is that the SVD of
//! the n×n kernel matrix reduces to an O(k³) computation.
//!
//! We use the exact factor form: with `B = C (W⁺)^{1/2}` (n×k) we have
//! `G̃ = B Bᵀ`, so the nonzero eigenvalues of G̃ are the eigenvalues of
//! `BᵀB` (k×k) and the eigenvectors are `U = B V Λ^{-1/2}`. This is
//! numerically tighter than the paper's `(n/k)Σ_W` scaling estimate and
//! costs the same O(nk² + k³).

use super::NystromApprox;
use crate::linalg::{psd_sqrt, sym_eig, Mat};

/// The factor `B = C (W⁺)^{1/2}` with `G̃ = B Bᵀ` — the shared starting
/// point of the eigendecomposition below and the downstream-task fits
/// ([`crate::tasks`]), which both need G̃ in symmetric factor form.
pub fn nystrom_factor(approx: &NystromApprox) -> Mat {
    // (W⁺)^{1/2} = V diag(λ₊^{1/2}) Vᵀ — clamp tiny negatives from pinv
    approx.c.matmul(&psd_sqrt(&approx.winv))
}

/// Top eigenpairs of `G̃ = C W⁺ Cᵀ`: returns descending eigenvalues and the
/// matrix of corresponding orthonormal eigenvectors (n×r, r = retained
/// rank). Eigenvalues below `rtol * λmax` are dropped.
pub fn nystrom_eig(approx: &NystromApprox, rtol: f64) -> (Vec<f64>, Mat) {
    let b = nystrom_factor(approx); // n×k
    let btb = b.syrk(); // k×k Gram, half the flops of the general product
    let eig = sym_eig(&btb);
    let lmax = eig.vals.first().copied().unwrap_or(0.0).max(0.0);
    let keep: usize = eig.vals.iter().filter(|&&l| l > rtol * lmax && l > 0.0).count();
    let vals: Vec<f64> = eig.vals[..keep].to_vec();
    // U = B V Λ^{-1/2}
    let vkeep = eig.vecs.select_cols(&(0..keep).collect::<Vec<_>>());
    let mut u = b.matmul(&vkeep);
    for j in 0..keep {
        let f = 1.0 / vals[j].sqrt();
        for i in 0..u.rows {
            *u.at_mut(i, j) *= f;
        }
    }
    (vals, u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::inverse;

    fn rank2_g() -> (Mat, NystromApprox) {
        // G = XᵀX with X 2×6
        let x = Mat::from_vec(
            2,
            6,
            vec![1., 2., 0., -1., 1., 0.5, 0., 1., 1., 1., -1., 0.25],
        );
        let g = x.t_matmul(&x);
        let idx = vec![0usize, 2];
        let c = g.select_cols(&idx);
        let w = c.select_rows(&idx);
        let approx = NystromApprox {
            indices: idx,
            winv: inverse(&w).unwrap(),
            c,
            selection_secs: 0.0,
        };
        (g, approx)
    }

    #[test]
    fn eigenpairs_reconstruct_g_tilde() {
        let (_g, approx) = rank2_g();
        let (vals, u) = nystrom_eig(&approx, 1e-10);
        assert_eq!(vals.len(), 2);
        // U Λ Uᵀ == G̃
        let mut ul = u.clone();
        for j in 0..vals.len() {
            for i in 0..u.rows {
                *ul.at_mut(i, j) *= vals[j];
            }
        }
        let recon = ul.matmul(&u.transpose());
        assert!(recon.fro_dist(&approx.reconstruct()) < 1e-9);
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let (_g, approx) = rank2_g();
        let (_vals, u) = nystrom_eig(&approx, 1e-10);
        let utu = u.syrk();
        assert!(utu.fro_dist(&Mat::eye(2)) < 1e-9);
    }

    #[test]
    fn matches_exact_eig_when_reconstruction_exact() {
        // rank-2 G sampled with 2 independent columns ⇒ G̃ = G exactly,
        // so Nyström eigenvalues must equal the true ones.
        let (g, approx) = rank2_g();
        let (vals, _u) = nystrom_eig(&approx, 1e-10);
        let exact = sym_eig(&g);
        assert!((vals[0] - exact.vals[0]).abs() < 1e-9);
        assert!((vals[1] - exact.vals[1]).abs() < 1e-9);
    }
}
