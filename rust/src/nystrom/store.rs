//! Persistent approximation artifacts: serialize a finished (or
//! snapshot) [`NystromApprox`] to disk and load it back bit-identically,
//! so a factorization can outlive the process/session that computed it
//! and keep answering out-of-sample extension queries **without** the
//! original dataset or kernel oracle.
//!
//! What makes that possible: the Nyström extension `ĝ(z, i) = b(z)ᵀ W⁻¹
//! C(i, :)` only ever evaluates the kernel against the *k selected*
//! points (`b_t = k(z, x_{Λ(t)})`), so an artifact that carries Λ, `C`,
//! `W⁻¹`, the k selected points, and the kernel's resolved parameters
//! ([`KernelParams`]) is a complete, self-contained query server for the
//! approximation — the other n−k points are never needed again.
//!
//! # On-disk format (versions 1 and 2)
//!
//! ```text
//! oasis-artifact\n                 ← ASCII magic line
//! {…json header…}\n                ← one line, crate JSON (util::json)
//! <binary payload>                 ← framed little-endian sections
//! ```
//!
//! Header fields: `version` (1 or 2), `n`, `k`, `dim`, `indices`
//! (array of k column indices in selection order), `kernel` (`{"type":
//! …}` plus resolved numeric parameters), `provenance` (`{"source",
//! "method"}` — where the data came from and which sampler selected Λ),
//! `error_estimate` (number or null), `selection_secs`,
//! `payload_bytes`, and `checksum` (FNV-1a 64 of the payload, 16 hex
//! digits).
//!
//! Payload sections, in order, each framed as `[u64 LE count][count ×
//! value LE]` (see [`crate::util::framing`]):
//!
//! 1. `C` — n×k, row-major
//! 2. `W⁻¹` — k×k, row-major
//! 3. selected points `Z_Λ` — k×dim, point-major, always f64
//!
//! **Version 2** additions (a version-1 file is exactly the subset
//! above; the loader reads both):
//!
//! * `"encoding": "f32"` — the `C` and `W⁻¹` sections hold f32 values
//!   (`[u64 LE count][count × f32 LE]`), halving the payload for the
//!   n×k bulk. The compaction is **lossy**: factors reload widened to
//!   f64, so extension queries and task fits/predictions from an f32
//!   artifact differ from the f64 original at f32 precision (~1e-7
//!   relative). `Z_Λ` deliberately stays f64 — warm starts verify the
//!   stored points bit-equal the dataset's, and the kernel row `b(z)`
//!   keeps full precision either way.
//! * `"task": {"type": "krr"|"kpca"|"cluster", …}` — a fitted
//!   downstream model ([`crate::tasks::FittedTask`]), its numeric state
//!   appended as additional **f64** sections after `Z_Λ`:
//!   `krr` → `β` (k); `kpca` → eigenvalues (d), projection (k×d);
//!   `cluster` → eigenvalues (d), projection (k×d), centroids (c×d).
//!   Round-trips are bit-identical.
//!
//! Loads verify, in order: magic, header JSON, version, dimensional
//! consistency (index count/ranges, section sizes), payload byte count,
//! and checksum — so truncated, corrupted, or wrong-version files are
//! rejected with a clear error before any value is used. All f64
//! payloads round-trip bit-exactly (the JSON header's numbers use the
//! crate serializer's shortest-round-trip formatting).

use crate::data::Dataset;
use crate::kernels::{Kernel, KernelParams};
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::tasks::{ClusterModel, FittedTask, KpcaModel, KrrModel};
use crate::util::framing::{
    checksum_hex, fnv1a64, parse_checksum_hex, push_f32_section,
    push_f64_section, split_magic_file, SectionReader,
};
use crate::util::json::Json;
use crate::Result;
use crate::{anyhow, bail};
use std::path::Path;

/// Newest artifact format version this build writes (reads accept
/// `1..=FORMAT_VERSION`). Version 1 files are written whenever neither
/// v2 feature (f32 encoding, task section) is used, so plain artifacts
/// stay readable by older builds.
pub const FORMAT_VERSION: usize = 2;

/// Magic line opening every artifact file (includes the newline).
pub const MAGIC: &[u8] = b"oasis-artifact\n";

/// Where an artifact's approximation came from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Provenance {
    /// Dataset description, e.g. `generator:two-moons?n=2000&seed=7`,
    /// `file:digits.csv`, or `points:n=12`.
    pub source: String,
    /// Sampler that selected Λ (e.g. "oASIS").
    pub method: String,
}

/// A self-contained, persistable Nyström approximation: the factors, the
/// selected points, and the resolved kernel — everything needed to
/// answer [`query`](StoredArtifact::query) without the original oracle.
#[derive(Clone, Debug)]
pub struct StoredArtifact {
    pub approx: NystromApprox,
    pub kernel: KernelParams,
    /// The k selected points `Z_Λ`, in selection order (row t is the
    /// point of column `approx.indices[t]`).
    pub selected_points: Dataset,
    pub provenance: Provenance,
    pub error_estimate: Option<f64>,
    /// Fitted downstream model riding along (version-2 `task` section).
    pub task: Option<FittedTask>,
    /// Encode `C`/`W⁻¹` as f32 on save (version-2 compaction; lossy —
    /// see the module docs' precision caveat). Set by
    /// [`with_f32`](Self::with_f32), or by the loader to whatever the
    /// file used, so re-saving keeps the artifact's encoding.
    pub f32_payload: bool,
}

impl StoredArtifact {
    /// Package an approximation for storage, extracting the selected
    /// points from the dataset the approximation was computed on and the
    /// resolved parameters from its kernel. Fails cleanly for kernels
    /// that are not storable ([`Kernel::params`] is `None`) and for
    /// approximations without column indices (K-means Nyström's
    /// "columns" are centroid evaluations, not columns of G).
    pub fn from_parts(
        approx: NystromApprox,
        dataset: &Dataset,
        kernel: &dyn Kernel,
        provenance: Provenance,
        error_estimate: Option<f64>,
    ) -> Result<StoredArtifact> {
        if approx.n() != dataset.n() {
            bail!(
                "approximation has n = {} but the dataset has {} points",
                approx.n(),
                dataset.n()
            );
        }
        if let Some(&bad) = approx.indices.iter().find(|&&i| i >= dataset.n()) {
            bail!("selected index {bad} out of range (n = {})", dataset.n());
        }
        let selected_points = dataset.select(&approx.indices);
        Self::from_selected(approx, selected_points, kernel, provenance, error_estimate)
    }

    /// Package an approximation whose selected points `Z_Λ` are already
    /// extracted — the shard-read serving path, where no full dataset
    /// exists to extract them from. Row t of `selected_points` must be
    /// the data point of column `approx.indices[t]`.
    pub fn from_selected(
        approx: NystromApprox,
        selected_points: Dataset,
        kernel: &dyn Kernel,
        provenance: Provenance,
        error_estimate: Option<f64>,
    ) -> Result<StoredArtifact> {
        let params = kernel.params().ok_or_else(|| {
            anyhow!(
                "kernel '{}' is not storable (no resolved parameters)",
                kernel.name()
            )
        })?;
        if approx.indices.is_empty() || approx.indices.len() != approx.k() {
            bail!(
                "approximation is not storable: it has {} column indices \
                 for k = {} columns (index-free methods like kmeans cannot \
                 answer stored queries)",
                approx.indices.len(),
                approx.k()
            );
        }
        if let Some(&bad) = approx.indices.iter().find(|&&i| i >= approx.n()) {
            bail!("selected index {bad} out of range (n = {})", approx.n());
        }
        if selected_points.n() != approx.k() {
            bail!(
                "{} selected points for k = {} columns",
                selected_points.n(),
                approx.k()
            );
        }
        Ok(StoredArtifact {
            approx,
            kernel: params,
            selected_points,
            provenance,
            error_estimate,
            task: None,
            f32_payload: false,
        })
    }

    /// Attach a fitted downstream model (persisted as the version-2
    /// `task` section). The model must have been fit on this artifact's
    /// factors — its landmark count k has to match.
    pub fn with_task(mut self, task: FittedTask) -> Result<StoredArtifact> {
        if task.k() != self.k() {
            bail!(
                "task model was fit with k = {} landmarks but the artifact \
                 has k = {}",
                task.k(),
                self.k()
            );
        }
        // header scalars travel through JSON numbers: non-finite values
        // serialize as null and seeds past 2^53 lose bits — either would
        // save an artifact that later fails to load (or lies about the
        // fit), so refuse at attach time
        match &task {
            FittedTask::Krr(m) => {
                if !(m.lambda.is_finite() && m.train_rmse.is_finite()) {
                    bail!(
                        "krr model has non-finite header scalars (lambda = \
                         {}, train_rmse = {}) and is not storable",
                        m.lambda,
                        m.train_rmse
                    );
                }
            }
            FittedTask::Cluster(m) => {
                if m.seed > (1u64 << 53) {
                    bail!(
                        "cluster seed {} exceeds 2^53 and cannot be stored \
                         exactly — pick a smaller seed",
                        m.seed
                    );
                }
            }
            FittedTask::Kpca(_) => {}
        }
        self.task = Some(task);
        Ok(self)
    }

    /// Choose the compact f32 payload encoding for `C`/`W⁻¹` (version-2;
    /// lossy — see the module docs' precision caveat).
    pub fn with_f32(mut self, yes: bool) -> StoredArtifact {
        self.f32_payload = yes;
        self
    }

    /// Number of data points n in the approximated matrix.
    pub fn n(&self) -> usize {
        self.approx.n()
    }

    /// Number of selected columns k.
    pub fn k(&self) -> usize {
        self.approx.k()
    }

    /// Dimensionality of the underlying data points.
    pub fn dim(&self) -> usize {
        self.selected_points.dim()
    }

    /// Serialize: version 1 when no v2 feature is used, version 2 when
    /// the payload is f32-encoded or a task model rides along.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        if self.f32_payload {
            push_f32_section(&mut payload, &self.approx.c.data);
            push_f32_section(&mut payload, &self.approx.winv.data);
        } else {
            push_f64_section(&mut payload, &self.approx.c.data);
            push_f64_section(&mut payload, &self.approx.winv.data);
        }
        push_f64_section(&mut payload, self.selected_points.flat());
        if let Some(task) = &self.task {
            push_task_sections(&mut payload, task);
        }
        let version = if self.f32_payload || self.task.is_some() {
            FORMAT_VERSION
        } else {
            1
        };
        let mut fields = vec![
            ("version", Json::Num(version as f64)),
            ("n", Json::Num(self.n() as f64)),
            ("k", Json::Num(self.k() as f64)),
            ("dim", Json::Num(self.dim() as f64)),
            (
                "indices",
                Json::Arr(
                    self.approx
                        .indices
                        .iter()
                        .map(|&i| Json::Num(i as f64))
                        .collect(),
                ),
            ),
            ("kernel", kernel_to_json(&self.kernel)),
            (
                "provenance",
                Json::obj(vec![
                    ("source", Json::Str(self.provenance.source.clone())),
                    ("method", Json::Str(self.provenance.method.clone())),
                ]),
            ),
            (
                "error_estimate",
                match self.error_estimate {
                    Some(e) => Json::Num(e),
                    None => Json::Null,
                },
            ),
            ("selection_secs", Json::Num(self.approx.selection_secs)),
            ("payload_bytes", Json::Num(payload.len() as f64)),
            ("checksum", Json::Str(checksum_hex(fnv1a64(&payload)))),
        ];
        if self.f32_payload {
            fields.push(("encoding", Json::Str("f32".into())));
        }
        if let Some(task) = &self.task {
            fields.push(("task", task_header_json(task)));
        }
        let header = Json::obj(fields);
        let mut out = Vec::with_capacity(MAGIC.len() + payload.len() + 512);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(header.to_string().as_bytes());
        out.push(b'\n');
        out.extend_from_slice(&payload);
        out
    }

    /// Write the artifact to `path`, returning the byte count written.
    /// The write is atomic (temp file in the destination directory +
    /// rename — [`crate::util::fsio::write_atomic`]), so a crash
    /// mid-save can never leave a truncated artifact behind, and a
    /// reader racing a re-save sees either the old artifact or the new
    /// one, both complete.
    pub fn save(&self, path: &Path) -> Result<usize> {
        let bytes = self.to_bytes();
        crate::util::fsio::write_atomic(path, &bytes)
            .map_err(|e| e.wrap(format!("writing artifact {}", path.display())))?;
        Ok(bytes.len())
    }

    /// Parse and verify the byte format (versions 1 and 2).
    pub fn from_bytes(bytes: &[u8]) -> Result<StoredArtifact> {
        let (header_str, payload) =
            split_magic_file(bytes, MAGIC, "oasis artifact")?;
        let h = Json::parse(header_str)
            .map_err(|e| anyhow!("artifact header: {e}"))?;
        check_version(&h)?;
        let f32_payload = encoding_is_f32(&h)?;
        let n = field_usize(&h, "n")?;
        let k = field_usize(&h, "k")?;
        let dim = field_usize(&h, "dim")?;
        if n == 0 || k == 0 || dim == 0 {
            bail!("artifact header has empty dimensions (n={n}, k={k}, dim={dim})");
        }
        // size the sections with overflow-checked arithmetic: a crafted
        // header (n = 2⁶³) must be a clean error, not a panic or a
        // wrapped-to-zero allocation
        let c_elems = checked_elems(n, k, "C factor")?;
        let winv_elems = checked_elems(k, k, "W⁻¹ factor")?;
        let pts_elems = checked_elems(k, dim, "selected points")?;
        let payload_bytes = field_usize(&h, "payload_bytes")?;
        if payload.len() != payload_bytes {
            bail!(
                "artifact payload is {} bytes but the header promises \
                 {payload_bytes} (truncated or trailing garbage)",
                payload.len()
            );
        }
        let want = parse_checksum_hex(
            h.get("checksum")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact header missing checksum"))?,
        )?;
        let got = fnv1a64(payload);
        if got != want {
            bail!(
                "artifact checksum mismatch: payload hashes to \
                 {} but the header says {} (corrupted file)",
                checksum_hex(got),
                checksum_hex(want)
            );
        }
        let idx_json = h
            .get("indices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact header missing indices"))?;
        if idx_json.len() != k {
            bail!("artifact has {} indices for k = {k}", idx_json.len());
        }
        let mut indices = Vec::with_capacity(k);
        for v in idx_json {
            match v.as_f64() {
                Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => {
                    let i = x as usize;
                    if i >= n {
                        bail!("artifact index {i} out of range (n = {n})");
                    }
                    indices.push(i);
                }
                _ => bail!("artifact indices must be non-negative integers"),
            }
        }
        let kernel = kernel_from_json(
            h.get("kernel")
                .ok_or_else(|| anyhow!("artifact header missing kernel"))?,
        )?;
        let provenance = match h.get("provenance") {
            Some(p) => Provenance {
                source: p
                    .get("source")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                method: p
                    .get("method")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
            },
            None => Provenance {
                source: "unknown".into(),
                method: "unknown".into(),
            },
        };
        let error_estimate = h.get("error_estimate").and_then(Json::as_f64);
        let selection_secs = h
            .get("selection_secs")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);

        let mut r = SectionReader::new(payload);
        let (c, winv) = if f32_payload {
            (
                r.read_f32_section(c_elems, "C factor")?,
                r.read_f32_section(winv_elems, "W⁻¹ factor")?,
            )
        } else {
            (
                r.read_f64_section(c_elems, "C factor")?,
                r.read_f64_section(winv_elems, "W⁻¹ factor")?,
            )
        };
        let pts = r.read_f64_section(pts_elems, "selected points")?;
        let task = match h.get("task") {
            None | Some(Json::Null) => None,
            Some(th) => Some(read_task_sections(th, k, &mut r)?),
        };
        if r.remaining() != 0 {
            bail!("artifact payload has {} unread trailing bytes", r.remaining());
        }
        Ok(StoredArtifact {
            approx: NystromApprox {
                indices,
                c: Mat::from_vec(n, k, c),
                winv: Mat::from_vec(k, k, winv),
                selection_secs,
            },
            kernel,
            selected_points: Dataset::from_flat(dim, pts),
            provenance,
            error_estimate,
            task,
            f32_payload,
        })
    }

    /// Read and verify an artifact file.
    pub fn load(path: &Path) -> Result<StoredArtifact> {
        let bytes = std::fs::read(path).map_err(|e| {
            anyhow!("reading artifact {}: {e}", path.display())
        })?;
        Self::from_bytes(&bytes)
            .map_err(|e| e.wrap(format!("loading {}", path.display())))
    }

    /// Read just an artifact file's header — returning `(n, k, dim)` —
    /// without touching the payload, and verify that the file's total
    /// size is exactly what those dimensions imply. This is the serving
    /// layer's cap pre-check: an over-cap (or trailing-garbage-padded)
    /// file is refused before [`load`](Self::load) would materialize
    /// its bytes in memory.
    pub fn peek_dims(path: &Path) -> Result<(usize, usize, usize)> {
        let (_, n, k, dim, _) = Self::peek_header(path)?;
        Ok((n, k, dim))
    }

    /// Everything warm-start resolution needs — Λ (range-checked like
    /// [`from_bytes`](Self::from_bytes)), the resolved kernel, n/dim,
    /// and the k selected points `Z_Λ` (read by byte-range seek so a
    /// caller can verify the artifact really describes its dataset) —
    /// without materializing the n×k factor payload a warm start never
    /// touches (replay rebuilds state from the oracle). File size is
    /// validated against the header exactly as
    /// [`peek_dims`](Self::peek_dims) does, so truncation is still
    /// caught; the cost is O(header + k·dim), not O(n·k).
    pub fn peek_warm_start(path: &Path) -> Result<WarmStartHeader> {
        let (h, n, k, dim, payload_offset) = Self::peek_header(path)?;
        let idx_json = h
            .get("indices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact header missing indices"))?;
        if idx_json.len() != k {
            bail!("artifact has {} indices for k = {k}", idx_json.len());
        }
        let mut indices = Vec::with_capacity(k);
        for v in idx_json {
            match v.as_f64() {
                Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => {
                    let i = x as usize;
                    if i >= n {
                        bail!("artifact index {i} out of range (n = {n})");
                    }
                    indices.push(i);
                }
                _ => bail!("artifact indices must be non-negative integers"),
            }
        }
        let kernel = kernel_from_json(
            h.get("kernel")
                .ok_or_else(|| anyhow!("artifact header missing kernel"))?,
        )?;
        // the selected points follow the C and W⁻¹ sections (any task
        // sections come after them); seek straight to their frame — file
        // length was already verified to match the header exactly. The
        // factor sections' width depends on the payload encoding, the
        // selected points are always f64.
        let fw = if encoding_is_f32(&h)? { 4u64 } else { 8u64 };
        let pts_elems = checked_elems(k, dim, "selected points")?;
        let pts_offset = payload_offset
            + (8 + fw * checked_elems(n, k, "C factor")? as u64)
            + (8 + fw * checked_elems(k, k, "W⁻¹ factor")? as u64);
        let mut f = std::fs::File::open(path).map_err(|e| {
            anyhow!("reading artifact {}: {e}", path.display())
        })?;
        use std::io::{Read, Seek, SeekFrom};
        f.seek(SeekFrom::Start(pts_offset))
            .map_err(|e| anyhow!("seeking selected points: {e}"))?;
        let mut lenbuf = [0u8; 8];
        f.read_exact(&mut lenbuf)
            .map_err(|e| anyhow!("reading selected-points frame: {e}"))?;
        if u64::from_le_bytes(lenbuf) != pts_elems as u64 {
            bail!(
                "selected-points frame holds {} values but the header \
                 implies {pts_elems}",
                u64::from_le_bytes(lenbuf)
            );
        }
        let mut raw = vec![0u8; pts_elems * 8];
        f.read_exact(&mut raw)
            .map_err(|e| anyhow!("reading selected points: {e}"))?;
        let pts: Vec<f64> = raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(WarmStartHeader {
            n,
            k,
            dim,
            indices,
            kernel,
            selected_points: Dataset::from_flat(dim, pts),
        })
    }

    /// Shared header reader behind [`peek_dims`](Self::peek_dims) and
    /// [`peek_warm_start`](Self::peek_warm_start): parse the bounded
    /// header line and verify the file is exactly
    /// magic + header + payload for the dimensions it declares. The last
    /// element of the return tuple is the payload's byte offset.
    fn peek_header(path: &Path) -> Result<(Json, usize, usize, usize, u64)> {
        use std::io::{BufRead, BufReader, Read};
        let f = std::fs::File::open(path).map_err(|e| {
            anyhow!("reading artifact {}: {e}", path.display())
        })?;
        let file_len = f
            .metadata()
            .map_err(|e| anyhow!("stat artifact {}: {e}", path.display()))?
            .len();
        let mut reader = BufReader::new(f);
        let mut magic = vec![0u8; MAGIC.len()];
        reader
            .read_exact(&mut magic)
            .map_err(|_| anyhow!("not a oasis artifact file (bad magic)"))?;
        if magic != MAGIC {
            bail!("not a oasis artifact file (bad magic)");
        }
        // the header line carries the k-entry index array, so it can be
        // sizable — but still bounded
        const MAX_HEADER_BYTES: u64 = 64 * 1024 * 1024;
        let mut line = Vec::new();
        reader
            .by_ref()
            .take(MAX_HEADER_BYTES)
            .read_until(b'\n', &mut line)
            .map_err(|e| anyhow!("reading artifact header: {e}"))?;
        if line.last() != Some(&b'\n') {
            bail!("artifact header line did not terminate");
        }
        let header_bytes = line.len(); // includes the newline
        line.pop();
        let text = std::str::from_utf8(&line)
            .map_err(|_| anyhow!("artifact header is not UTF-8"))?;
        let h = Json::parse(text).map_err(|e| anyhow!("artifact header: {e}"))?;
        check_version(&h)?;
        let n = field_usize(&h, "n")?;
        let k = field_usize(&h, "k")?;
        let dim = field_usize(&h, "dim")?;
        let payload_bytes = field_usize(&h, "payload_bytes")?;
        // the payload must be exactly the framed sections the header
        // implies (three base sections plus any task sections, at the
        // declared encoding width), and the file exactly
        // magic+header+payload — a small header cannot front gigabytes
        // of trailing bytes
        let implied = implied_payload_bytes(&h, n, k, dim)?;
        if payload_bytes != implied {
            bail!(
                "artifact header promises {payload_bytes} payload bytes but \
                 its dimensions imply {implied}"
            );
        }
        let payload_offset = (MAGIC.len() + header_bytes) as u64;
        let expected_len = payload_offset + payload_bytes as u64;
        if file_len != expected_len {
            bail!(
                "artifact file is {file_len} bytes but its header implies \
                 {expected_len} (truncated or trailing garbage)"
            );
        }
        Ok((h, n, k, dim, payload_offset))
    }

    /// Out-of-sample extension weights `w = W⁻¹ b(z)` for a query point,
    /// evaluating the stored kernel against the k stored points only —
    /// no access to the original dataset or oracle.
    pub fn query_weights(&self, z: &[f64]) -> Result<Vec<f64>> {
        if z.len() != self.dim() {
            bail!(
                "query point has dimension {} but the artifact stores \
                 dimension {}",
                z.len(),
                self.dim()
            );
        }
        let kernel = self.kernel.build();
        let b: Vec<f64> = (0..self.k())
            .map(|t| kernel.eval(z, self.selected_points.point(t)))
            .collect();
        Ok(self.approx.extension_weights(&b))
    }

    /// `ĝ(z, i)` for each target row, from weights computed by
    /// [`query_weights`](Self::query_weights).
    pub fn extend(&self, weights: &[f64], targets: &[usize]) -> Result<Vec<f64>> {
        if let Some(&bad) = targets.iter().find(|&&t| t >= self.n()) {
            bail!("target index {bad} out of range (n = {})", self.n());
        }
        Ok(targets
            .iter()
            .map(|&t| self.approx.extend_entry(weights, t))
            .collect())
    }

    /// One-line JSON summary (CLI `query --load` info, server listings).
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            (
                "stored_task",
                match &self.task {
                    Some(t) => Json::Str(t.kind().as_str().to_string()),
                    None => Json::Null,
                },
            ),
            (
                "encoding",
                Json::Str(if self.f32_payload { "f32" } else { "f64" }.into()),
            ),
            ("n", Json::Num(self.n() as f64)),
            ("k", Json::Num(self.k() as f64)),
            ("dim", Json::Num(self.dim() as f64)),
            ("kernel", Json::Str(self.kernel.name().to_string())),
            ("method", Json::Str(self.provenance.method.clone())),
            ("source", Json::Str(self.provenance.source.clone())),
            (
                "error_estimate",
                match self.error_estimate {
                    Some(e) => Json::Num(e),
                    None => Json::Null,
                },
            ),
            ("selection_secs", Json::Num(self.approx.selection_secs)),
        ])
    }
}

/// The header-plus-selected-points view
/// [`StoredArtifact::peek_warm_start`] returns: what a warm start needs,
/// without the n×k factor payload.
#[derive(Clone, Debug)]
pub struct WarmStartHeader {
    pub n: usize,
    pub k: usize,
    pub dim: usize,
    /// Λ in selection order.
    pub indices: Vec<usize>,
    /// The resolved kernel the artifact was computed with.
    pub kernel: KernelParams,
    /// `Z_Λ` (row t is the point of column `indices[t]`) — lets warm
    /// starts verify the artifact was computed on *this* dataset, not
    /// merely one with the same shape.
    pub selected_points: Dataset,
}

/// Accept format versions `1..=FORMAT_VERSION`.
fn check_version(h: &Json) -> Result<()> {
    let version = field_usize(h, "version")?;
    if version == 0 || version > FORMAT_VERSION {
        bail!(
            "unsupported artifact version {version} (this build reads \
             versions 1..={FORMAT_VERSION})"
        );
    }
    Ok(())
}

/// Parse the header's payload encoding: absent → f64, `"f32"` → f32.
fn encoding_is_f32(h: &Json) -> Result<bool> {
    match h.get("encoding") {
        None | Some(Json::Null) => Ok(false),
        Some(v) => match v.as_str() {
            Some("f64") => Ok(false),
            Some("f32") => Ok(true),
            _ => bail!("artifact encoding must be \"f64\" or \"f32\""),
        },
    }
}

/// Exact payload byte count the header implies: the three base sections
/// at the declared encoding width (selected points always f64), plus any
/// task sections (always f64).
fn implied_payload_bytes(h: &Json, n: usize, k: usize, dim: usize) -> Result<usize> {
    let fw = if encoding_is_f32(h)? { 4 } else { 8 };
    let mut bytes = (8 + fw * checked_elems(n, k, "C factor")?)
        + (8 + fw * checked_elems(k, k, "W⁻¹ factor")?)
        + (8 + 8 * checked_elems(k, dim, "selected points")?);
    if let Some(th) = h.get("task").filter(|t| !matches!(t, Json::Null)) {
        for elems in task_section_elems(th, k)? {
            bytes += 8 + 8 * elems;
        }
    }
    Ok(bytes)
}

/// The `task` header object for a fitted model (its numeric state goes
/// into the payload sections, only scalars and dims live here).
fn task_header_json(task: &FittedTask) -> Json {
    match task {
        FittedTask::Krr(m) => {
            let mut fields = vec![
                ("type", Json::Str("krr".into())),
                ("lambda", Json::Num(m.lambda)),
                ("train_rmse", Json::Num(m.train_rmse)),
            ];
            // multi-output models record their column count; the field
            // is omitted at m = 1 so single-output artifacts keep the
            // exact header (and version) older readers understand
            if m.outputs > 1 {
                fields.push(("outputs", Json::Num(m.outputs as f64)));
            }
            Json::obj(fields)
        }
        FittedTask::Kpca(m) => Json::obj(vec![
            ("type", Json::Str("kpca".into())),
            ("components", Json::Num(m.vals.len() as f64)),
        ]),
        FittedTask::Cluster(m) => Json::obj(vec![
            ("type", Json::Str("cluster".into())),
            ("clusters", Json::Num(m.centroids.rows as f64)),
            ("components", Json::Num(m.embedding.vals.len() as f64)),
            ("seed", Json::Num(m.seed as f64)),
        ]),
    }
}

/// Append the task's payload sections (all f64; see the module docs for
/// the per-type section list).
fn push_task_sections(payload: &mut Vec<u8>, task: &FittedTask) {
    match task {
        FittedTask::Krr(m) => push_f64_section(payload, &m.beta),
        FittedTask::Kpca(m) => {
            push_f64_section(payload, &m.vals);
            push_f64_section(payload, &m.proj.data);
        }
        FittedTask::Cluster(m) => {
            push_f64_section(payload, &m.embedding.vals);
            push_f64_section(payload, &m.embedding.proj.data);
            push_f64_section(payload, &m.centroids.data);
        }
    }
}

/// Per-section element counts a `task` header implies (overflow-checked,
/// like every other size derived from header fields).
fn task_section_elems(th: &Json, k: usize) -> Result<Vec<usize>> {
    let t = th
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("artifact task header missing type"))?;
    Ok(match t {
        "krr" => vec![checked_elems(k, task_outputs(th)?, "task beta")?],
        "kpca" => {
            let d = task_dim(th, "components")?;
            vec![d, checked_elems(k, d, "task projection")?]
        }
        "cluster" => {
            let d = task_dim(th, "components")?;
            let c = task_dim(th, "clusters")?;
            vec![
                d,
                checked_elems(k, d, "task projection")?,
                checked_elems(c, d, "task centroids")?,
            ]
        }
        other => bail!("unknown stored task type '{other}'"),
    })
}

/// The krr header's output count: absent means 1 (the pre-multi-output
/// header shape, and what m = 1 models still write).
fn task_outputs(th: &Json) -> Result<usize> {
    match th.get("outputs") {
        None | Some(Json::Null) => Ok(1),
        Some(_) => task_dim(th, "outputs"),
    }
}

fn task_dim(th: &Json, key: &str) -> Result<usize> {
    match th.get(key).and_then(Json::as_f64) {
        Some(x) if x.is_finite() && x >= 1.0 && x.fract() == 0.0 && x <= 1e12 => {
            Ok(x as usize)
        }
        _ => bail!("artifact task header field '{key}' missing or invalid"),
    }
}

/// Read the task sections declared by `th` back into a [`FittedTask`].
fn read_task_sections(
    th: &Json,
    k: usize,
    r: &mut SectionReader<'_>,
) -> Result<FittedTask> {
    let t = th
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("artifact task header missing type"))?;
    let num = |key: &str| -> Result<f64> {
        th.get(key)
            .and_then(Json::as_f64)
            .filter(|x| x.is_finite())
            .ok_or_else(|| anyhow!("artifact task header missing finite '{key}'"))
    };
    Ok(match t {
        "krr" => {
            let outputs = task_outputs(th)?;
            let beta = r.read_f64_section(
                checked_elems(k, outputs, "task beta")?,
                "task beta",
            )?;
            FittedTask::Krr(KrrModel {
                lambda: num("lambda")?,
                beta,
                outputs,
                train_rmse: num("train_rmse")?,
            })
        }
        "kpca" => {
            let d = task_dim(th, "components")?;
            let vals = r.read_f64_section(d, "task eigenvalues")?;
            let proj = r.read_f64_section(
                checked_elems(k, d, "task projection")?,
                "task projection",
            )?;
            FittedTask::Kpca(KpcaModel { vals, proj: Mat::from_vec(k, d, proj) })
        }
        "cluster" => {
            let d = task_dim(th, "components")?;
            let c = task_dim(th, "clusters")?;
            let vals = r.read_f64_section(d, "task eigenvalues")?;
            let proj = r.read_f64_section(
                checked_elems(k, d, "task projection")?,
                "task projection",
            )?;
            let centroids = r.read_f64_section(
                checked_elems(c, d, "task centroids")?,
                "task centroids",
            )?;
            let seed = match th.get("seed").and_then(Json::as_f64) {
                Some(s) if s.is_finite() && s >= 0.0 && s.fract() == 0.0 => {
                    s as u64
                }
                _ => bail!("artifact task header missing integer 'seed'"),
            };
            FittedTask::Cluster(ClusterModel {
                embedding: KpcaModel { vals, proj: Mat::from_vec(k, d, proj) },
                centroids: Mat::from_vec(c, d, centroids),
                seed,
            })
        }
        other => bail!("unknown stored task type '{other}'"),
    })
}

/// `a × b` as a section element count, rejected well before it can
/// overflow a usize (or an allocation): the payload byte cap it implies,
/// `2⁴⁸ × 8`, is already far beyond any real artifact.
fn checked_elems(a: usize, b: usize, what: &str) -> Result<usize> {
    let n = (a as u128) * (b as u128);
    if n > (1u128 << 48) {
        bail!("artifact header implies an implausible {what} size ({a}×{b})");
    }
    Ok(n as usize)
}

fn field_usize(j: &Json, key: &str) -> Result<usize> {
    match j.get(key).and_then(Json::as_f64) {
        Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => {
            Ok(x as usize)
        }
        _ => bail!("artifact header field '{key}' missing or not an integer"),
    }
}

/// Serialize resolved kernel parameters for the artifact header.
pub fn kernel_to_json(k: &KernelParams) -> Json {
    let mut fields = vec![("type", Json::Str(k.name().to_string()))];
    match *k {
        KernelParams::Gaussian { inv_sigma_sq } => {
            fields.push(("inv_sigma_sq", Json::Num(inv_sigma_sq)));
        }
        KernelParams::Linear => {}
        KernelParams::Laplacian { inv_sigma } => {
            fields.push(("inv_sigma", Json::Num(inv_sigma)));
        }
        KernelParams::Polynomial { degree, offset } => {
            fields.push(("degree", Json::Num(degree as f64)));
            fields.push(("offset", Json::Num(offset)));
        }
    }
    Json::obj(fields)
}

/// Parse kernel parameters written by [`kernel_to_json`].
pub fn kernel_from_json(j: &Json) -> Result<KernelParams> {
    let t = j
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("kernel spec missing type"))?;
    let num = |key: &str| -> Result<f64> {
        j.get(key)
            .and_then(Json::as_f64)
            .filter(|x| x.is_finite())
            .ok_or_else(|| anyhow!("kernel spec missing finite '{key}'"))
    };
    Ok(match t {
        "gaussian" => KernelParams::Gaussian { inv_sigma_sq: num("inv_sigma_sq")? },
        "linear" => KernelParams::Linear,
        "laplacian" => KernelParams::Laplacian { inv_sigma: num("inv_sigma")? },
        "polynomial" => KernelParams::Polynomial {
            // any u32 degree that was saveable must load back (the
            // serving layer clamps *request* degrees separately)
            degree: {
                let d = num("degree")?;
                if d < 0.0 || d.fract() != 0.0 || d > u32::MAX as f64 {
                    bail!("kernel degree must be a u32 integer");
                }
                d as u32
            },
            offset: num("offset")?,
        },
        other => bail!("unknown stored kernel type '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::sampling::{assemble_from_indices, ImplicitOracle};

    fn sample_artifact() -> (StoredArtifact, Dataset, Gaussian) {
        let ds = two_moons(50, 0.05, 11);
        let kern = Gaussian::new(0.8);
        let art = {
            let oracle = ImplicitOracle::new(&ds, &kern);
            let approx =
                assemble_from_indices(&oracle, vec![3, 17, 29, 44], 1.25);
            StoredArtifact::from_parts(
                approx,
                &ds,
                &kern,
                Provenance {
                    source: "test:two-moons".into(),
                    method: "oASIS".into(),
                },
                Some(0.125),
            )
            .unwrap()
        };
        (art, ds, kern)
    }

    #[test]
    fn round_trip_is_bit_identical() {
        let (art, _, _) = sample_artifact();
        let bytes = art.to_bytes();
        let back = StoredArtifact::from_bytes(&bytes).unwrap();
        assert_eq!(back.approx.indices, art.approx.indices);
        assert_eq!(back.approx.c.data.len(), art.approx.c.data.len());
        for (a, b) in art.approx.c.data.iter().zip(&back.approx.c.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in art.approx.winv.data.iter().zip(&back.approx.winv.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.selected_points, art.selected_points);
        assert_eq!(back.kernel, art.kernel);
        assert_eq!(back.provenance, art.provenance);
        assert_eq!(back.error_estimate, art.error_estimate);
        assert_eq!(
            back.approx.selection_secs.to_bits(),
            art.approx.selection_secs.to_bits()
        );
        // and the serialization is stable: re-encoding gives identical bytes
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn stored_query_matches_live_oracle() {
        let (art, ds, kern) = sample_artifact();
        let z = [0.4, -0.2];
        let w = art.query_weights(&z).unwrap();
        // live path: b against the original dataset's selected points
        let b: Vec<f64> = art
            .approx
            .indices
            .iter()
            .map(|&j| kern.eval(&z, ds.point(j)))
            .collect();
        let live = art.approx.extension_weights(&b);
        assert_eq!(w.len(), live.len());
        for (a, b) in w.iter().zip(&live) {
            assert_eq!(a.to_bits(), b.to_bits(), "stored query diverged");
        }
        let vals = art.extend(&w, &[0, 10, 49]).unwrap();
        assert_eq!(vals.len(), 3);
        assert!(art.extend(&w, &[50]).is_err(), "out-of-range target");
        assert!(art.query_weights(&[1.0]).is_err(), "dimension mismatch");
    }

    #[test]
    fn corrupted_truncated_and_wrong_version_rejected() {
        let (art, _, _) = sample_artifact();
        let bytes = art.to_bytes();

        // bad magic
        assert!(StoredArtifact::from_bytes(b"not an artifact").is_err());

        // truncated payload
        let cut = &bytes[..bytes.len() - 9];
        let err = StoredArtifact::from_bytes(cut).unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");

        // single flipped payload byte → checksum mismatch
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        let err = StoredArtifact::from_bytes(&flipped).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");

        // wrong version: rewrite the header line
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let bumped = text.replacen("\"version\":1", "\"version\":99", 1);
        let err = StoredArtifact::from_bytes(bumped.as_bytes()).unwrap_err();
        assert!(format!("{err}").contains("version 99"), "{err}");

        // trailing garbage after the payload
        let mut padded = bytes.clone();
        padded.extend_from_slice(b"zzzz");
        assert!(StoredArtifact::from_bytes(&padded).is_err());
    }

    #[test]
    fn unstorable_inputs_rejected() {
        let ds = two_moons(20, 0.05, 2);
        let kern = Gaussian::new(0.5);
        let oracle = ImplicitOracle::new(&ds, &kern);
        // no indices (kmeans-style)
        let mut approx = assemble_from_indices(&oracle, vec![1, 2], 0.0);
        approx.indices.clear();
        assert!(StoredArtifact::from_parts(
            approx,
            &ds,
            &kern,
            Provenance { source: "t".into(), method: "kmeans".into() },
            None,
        )
        .is_err());
        // unstorable kernel
        struct Opaque;
        impl Kernel for Opaque {
            fn eval(&self, _a: &[f64], _b: &[f64]) -> f64 {
                0.0
            }
            fn name(&self) -> &'static str {
                "opaque"
            }
        }
        let approx = assemble_from_indices(&oracle, vec![1, 2], 0.0);
        let err = StoredArtifact::from_parts(
            approx,
            &ds,
            &Opaque,
            Provenance { source: "t".into(), method: "x".into() },
            None,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("not storable"), "{err}");
    }

    #[test]
    fn kernel_json_round_trips_every_variant() {
        let variants = [
            KernelParams::Gaussian { inv_sigma_sq: 1.0 / 3.0 },
            KernelParams::Linear,
            KernelParams::Laplacian { inv_sigma: 0.7 },
            KernelParams::Polynomial { degree: 4, offset: -0.25 },
        ];
        for v in variants {
            let j = kernel_to_json(&v);
            let back = kernel_from_json(&Json::parse(&j.to_string()).unwrap())
                .unwrap();
            assert_eq!(back, v);
        }
        assert!(kernel_from_json(&Json::parse(r#"{"type":"magic"}"#).unwrap())
            .is_err());
        assert!(kernel_from_json(&Json::parse(r#"{"type":"gaussian"}"#).unwrap())
            .is_err());
    }

    #[test]
    fn save_and_load_via_filesystem() {
        let (art, _, _) = sample_artifact();
        let dir = std::env::temp_dir().join("oasis-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.oasis");
        let bytes = art.save(&path).unwrap();
        assert!(bytes > 0);
        let back = StoredArtifact::load(&path).unwrap();
        assert_eq!(back.approx.indices, art.approx.indices);
        std::fs::remove_file(&path).ok();
        // missing file is a clean error naming the path
        let err = StoredArtifact::load(&dir.join("absent.oasis")).unwrap_err();
        assert!(format!("{err}").contains("absent.oasis"), "{err}");
    }

    /// Version-2 `task` section: every fitted-task variant rides along
    /// and round-trips bit-identically (header scalars and payload
    /// sections), and re-encoding is byte-stable.
    #[test]
    fn task_section_round_trips_bit_identically() {
        use crate::tasks::{FittedTask, TaskConfig, TaskKind};
        let (art, _, _) = sample_artifact();
        let configs = [
            {
                let mut c = TaskConfig::new(TaskKind::Krr);
                c.labels =
                    Some(vec![(0..art.n()).map(|i| (i % 2) as f64).collect()]);
                c
            },
            TaskConfig::new(TaskKind::Kpca),
            TaskConfig::new(TaskKind::Cluster),
        ];
        for cfg in configs {
            let fit = FittedTask::fit(&art.approx, &cfg).unwrap();
            let stored = art.clone().with_task(fit.model.clone()).unwrap();
            let bytes = stored.to_bytes();
            assert!(
                String::from_utf8_lossy(&bytes).contains("\"version\":2"),
                "task artifacts are version 2"
            );
            let back = StoredArtifact::from_bytes(&bytes).unwrap();
            let back_task = back.task.as_ref().expect("task survived");
            match (&fit.model, back_task) {
                (FittedTask::Krr(a), FittedTask::Krr(b)) => {
                    assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
                    assert_eq!(a.train_rmse.to_bits(), b.train_rmse.to_bits());
                    assert_eq!(a.beta.len(), b.beta.len());
                    for (x, y) in a.beta.iter().zip(&b.beta) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (FittedTask::Kpca(a), FittedTask::Kpca(b)) => {
                    assert_eq!(a.vals, b.vals);
                    assert_eq!(a.proj.data, b.proj.data);
                }
                (FittedTask::Cluster(a), FittedTask::Cluster(b)) => {
                    assert_eq!(a.embedding.vals, b.embedding.vals);
                    assert_eq!(a.embedding.proj.data, b.embedding.proj.data);
                    assert_eq!(a.centroids.data, b.centroids.data);
                    assert_eq!(a.seed, b.seed);
                }
                other => panic!("task variant changed in flight: {other:?}"),
            }
            // byte-stable re-encode, and the plain parts still agree
            assert_eq!(back.to_bytes(), bytes);
            assert_eq!(back.approx.indices, stored.approx.indices);
            // truncating the last (task) section is caught
            let cut = &bytes[..bytes.len() - 5];
            assert!(StoredArtifact::from_bytes(cut).is_err());
        }
        // a mismatched task is refused at attach time
        let other = {
            let ds = two_moons(30, 0.05, 4);
            let kern = Gaussian::new(0.5);
            let oracle = ImplicitOracle::new(&ds, &kern);
            let approx = assemble_from_indices(&oracle, vec![0, 9], 0.0);
            FittedTask::fit(&approx, &TaskConfig::new(TaskKind::Kpca))
                .unwrap()
                .model
        };
        assert!(sample_artifact().0.with_task(other).is_err());
    }

    /// Multi-output krr models persist their output count: the header
    /// grows an `outputs` field (only when m > 1 — single-output headers
    /// keep the legacy shape), the beta section carries k·m elements,
    /// and the model reloads bit-identically.
    #[test]
    fn multi_output_task_section_round_trips() {
        use crate::tasks::{FittedTask, TaskConfig, TaskKind};
        let (art, _, _) = sample_artifact();
        let mut cfg = TaskConfig::new(TaskKind::Krr);
        cfg.labels = Some(vec![
            (0..art.n()).map(|i| (i % 2) as f64).collect(),
            (0..art.n()).map(|i| (i as f64 * 0.17).cos()).collect(),
            (0..art.n()).map(|i| i as f64).collect(),
        ]);
        let fit = FittedTask::fit(&art.approx, &cfg).unwrap();
        assert_eq!(fit.model.outputs(), 3);
        let stored = art.clone().with_task(fit.model.clone()).unwrap();
        let bytes = stored.to_bytes();
        let header_end = bytes.iter().position(|&b| b == b'\n').unwrap();
        assert!(
            String::from_utf8_lossy(&bytes[..header_end])
                .contains("\"outputs\":3"),
            "multi-output header records the column count"
        );
        let back = StoredArtifact::from_bytes(&bytes).unwrap();
        match (&fit.model, back.task.as_ref().expect("task survived")) {
            (FittedTask::Krr(a), FittedTask::Krr(b)) => {
                assert_eq!(b.outputs, 3);
                assert_eq!(b.beta.len(), 3 * art.k());
                for (x, y) in a.beta.iter().zip(&b.beta) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
                assert_eq!(a.train_rmse.to_bits(), b.train_rmse.to_bits());
            }
            other => panic!("task variant changed in flight: {other:?}"),
        }
        assert_eq!(back.to_bytes(), bytes);
        // single-output headers keep the legacy shape (no outputs field)
        let mut c1 = TaskConfig::new(TaskKind::Krr);
        c1.labels = Some(vec![(0..art.n()).map(|i| (i % 2) as f64).collect()]);
        let f1 = FittedTask::fit(&art.approx, &c1).unwrap();
        let b1 = art.clone().with_task(f1.model).unwrap().to_bytes();
        let h1_end = b1.iter().position(|&b| b == b'\n').unwrap();
        assert!(
            !String::from_utf8_lossy(&b1[..h1_end]).contains("outputs"),
            "m = 1 headers stay backward compatible"
        );
    }

    /// Version-2 f32 compaction: the payload shrinks, factors reload at
    /// f32 precision, `Z_Λ` stays bit-exact (so queries still evaluate
    /// the kernel against exact points), and re-encoding is byte-stable.
    #[test]
    fn f32_encoding_round_trips_at_reduced_precision() {
        let (art, _, _) = sample_artifact();
        let f64_bytes = art.to_bytes();
        let compact = art.clone().with_f32(true);
        let bytes = compact.to_bytes();
        assert!(bytes.len() < f64_bytes.len(), "{} !< {}", bytes.len(), f64_bytes.len());
        let back = StoredArtifact::from_bytes(&bytes).unwrap();
        assert!(back.f32_payload);
        // factors: exactly the f32 cast of the originals
        for (a, b) in art.approx.c.data.iter().zip(&back.approx.c.data) {
            assert_eq!(((*a as f32) as f64).to_bits(), b.to_bits());
        }
        for (a, b) in art.approx.winv.data.iter().zip(&back.approx.winv.data) {
            assert_eq!(((*a as f32) as f64).to_bits(), b.to_bits());
        }
        // selected points stay f64-exact
        assert_eq!(back.selected_points, art.selected_points);
        // warm-start peek reads the exact points through the f32 layout
        let dir = std::env::temp_dir().join("oasis-store-f32-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("compact.oasis");
        compact.save(&path).unwrap();
        let h = StoredArtifact::peek_warm_start(&path).unwrap();
        assert_eq!(h.selected_points, art.selected_points);
        assert_eq!(h.indices, art.approx.indices);
        assert_eq!(
            StoredArtifact::peek_dims(&path).unwrap(),
            (art.n(), art.k(), art.dim())
        );
        // stable re-encode keeps the f32 encoding
        assert_eq!(back.to_bytes(), bytes);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The header-only warm-start view agrees with a full load — without
    /// touching the factor payload — and still rejects truncation.
    #[test]
    fn warm_start_header_matches_full_load() {
        let (art, _, _) = sample_artifact();
        let dir = std::env::temp_dir().join("oasis-store-warm-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("warm.oasis");
        art.save(&path).unwrap();
        let h = StoredArtifact::peek_warm_start(&path).unwrap();
        assert_eq!((h.n, h.k, h.dim), (art.n(), art.k(), art.dim()));
        assert_eq!(h.indices, art.approx.indices);
        assert_eq!(h.kernel, art.kernel);
        assert_eq!(h.selected_points, art.selected_points);
        // a truncated file is refused from the length check alone
        let bytes = std::fs::read(&path).unwrap();
        let cut = dir.join("cut.oasis");
        std::fs::write(&cut, &bytes[..bytes.len() - 7]).unwrap();
        let err = StoredArtifact::peek_warm_start(&cut).unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
