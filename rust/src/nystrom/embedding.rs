//! Diffusion-map embedding through the Nyström approximation — the
//! downstream manifold-learning application the paper motivates (§II-B,
//! [2] Coifman & Lafon).
//!
//! For the explicit class we normalize the Gaussian kernel matrix to
//! `M = D^{-1/2} N D^{-1/2}`, approximate M with a sampler, take the
//! Nyström eigenpairs, and map each point to
//! `(λ₂ᵗ φ₂(i), …, λ_{d+1}ᵗ φ_{d+1}(i))` (the first eigenpair is the
//! trivial stationary direction).

use super::{nystrom_eig, NystromApprox};
use crate::linalg::Mat;

/// Diffusion-map coordinates from a Nyström approximation of the
/// normalized kernel matrix. Returns an n×dims matrix of coordinates.
///
/// `t` is the diffusion time (eigenvalue power).
pub fn diffusion_coordinates(
    approx: &NystromApprox,
    dims: usize,
    t: f64,
) -> Mat {
    let (vals, u) = nystrom_eig(approx, 1e-12);
    let n = u.rows;
    let avail = vals.len().saturating_sub(1).min(dims);
    let mut coords = Mat::zeros(n, dims);
    for d in 0..avail {
        let lam = vals[d + 1].max(0.0).powf(t);
        for i in 0..n {
            *coords.at_mut(i, d) = lam * u.at(i, d + 1);
        }
    }
    coords
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::{diffusion_normalize, kernel_matrix, Gaussian};
    use crate::sampling::{assemble_from_indices, ExplicitOracle};

    #[test]
    fn moons_separate_in_diffusion_space() {
        // With a small kernel width the two moons are two diffusion
        // clusters: the second eigenvector separates them.
        let n = 120;
        let ds = two_moons(n, 0.03, 11);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.05);
        let mut m = kernel_matrix(&ds, &kern);
        diffusion_normalize(&mut m);
        let oracle = ExplicitOracle::new(&m);
        // generous sampling so the embedding is accurate
        let idx: Vec<usize> = (0..n).step_by(2).collect();
        let approx = assemble_from_indices(&oracle, idx, 0.0);
        let coords = diffusion_coordinates(&approx, 2, 1.0);
        // moon label alternates with index (see generator)
        let (mut lo_a, mut hi_a, mut lo_b, mut hi_b) =
            (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
        for i in 0..n {
            let v = coords.at(i, 0);
            if i % 2 == 0 {
                lo_a = lo_a.min(v);
                hi_a = hi_a.max(v);
            } else {
                lo_b = lo_b.min(v);
                hi_b = hi_b.max(v);
            }
        }
        // the two classes occupy (mostly) disjoint intervals
        let overlap = (hi_a.min(hi_b) - lo_a.max(lo_b)).max(0.0);
        let span = (hi_a.max(hi_b) - lo_a.min(lo_b)).max(1e-12);
        assert!(
            overlap / span < 0.35,
            "diffusion coordinate overlap {:.2}",
            overlap / span
        );
    }

    #[test]
    fn requesting_more_dims_than_rank_pads_zero() {
        let ds = two_moons(30, 0.05, 3);
        let kern = Gaussian::new(1.0);
        let m = kernel_matrix(&ds, &kern);
        let oracle = ExplicitOracle::new(&m);
        let approx = assemble_from_indices(&oracle, vec![0, 10, 20], 0.0);
        let coords = diffusion_coordinates(&approx, 10, 1.0);
        assert_eq!(coords.cols, 10);
        // columns beyond rank-1 (3 cols ⇒ ≤2 nontrivial dims) are zero
        for d in 2..10 {
            for i in 0..30 {
                assert_eq!(coords.at(i, d), 0.0);
            }
        }
    }
}
