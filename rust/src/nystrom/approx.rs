//! `G ≈ G̃ = C W⁺ Cᵀ` — the Nyström approximation (Eq. 2 of the paper).

use crate::linalg::Mat;

/// A Nyström approximation: the sampled columns `C` (n×k), the inverse (or
/// pseudo-inverse) of the sampled rows `W` (k×k), and the selected index
/// set Λ. For K-means Nyström, `indices` is empty (its "columns" are
/// kernel evaluations against centroids, not columns of G — §II-D4).
#[derive(Clone, Debug)]
pub struct NystromApprox {
    /// Λ — the selected column indices, in selection order.
    pub indices: Vec<usize>,
    /// C — n×k matrix of sampled columns.
    pub c: Mat,
    /// W⁻¹ (or W⁺) — k×k.
    pub winv: Mat,
    /// wall-clock seconds spent selecting columns (and forming C, W⁻¹) —
    /// the quantity the paper's runtime columns report.
    pub selection_secs: f64,
}

impl NystromApprox {
    /// Number of sampled columns k.
    pub fn k(&self) -> usize {
        self.c.cols
    }

    /// Number of data points n.
    pub fn n(&self) -> usize {
        self.c.rows
    }

    /// The projector factor `P = C W⁻¹` (n×k); `G̃ = P Cᵀ`.
    /// Precompute once for repeated entry evaluation.
    pub fn projector(&self) -> Mat {
        self.c.matmul(&self.winv)
    }

    /// A single entry `G̃(i, j)` given a precomputed projector.
    #[inline]
    pub fn entry_with(&self, p: &Mat, i: usize, j: usize) -> f64 {
        crate::linalg::matrix::dot(p.row(i), self.c.row(j))
    }

    /// Materialize the full n×n `G̃` (small problems / tests only).
    pub fn reconstruct(&self) -> Mat {
        let p = self.projector();
        p.matmul(&self.c.transpose())
    }

    /// Numerical rank of the approximation (rank of W's retained part).
    pub fn rank(&self, rtol: f64) -> usize {
        crate::linalg::eig::psd_rank(&self.winv, rtol)
    }

    /// Out-of-sample extension weights for a query point z:
    /// `w = W⁻¹ b` where `bₜ = k(z, x_{Λ(t)})` is the kernel evaluated
    /// against the selected points only. Together with
    /// [`extend_entry`](Self::extend_entry) this evaluates the Nyström
    /// extension `ĝ(z, i) = b(z)ᵀ W⁻¹ C(i, :)` — the approximation's
    /// natural prediction of the kernel row of an unseen point. Only the
    /// k selected points are ever touched (O(k²) here plus O(k·dim) for
    /// b), which is what makes serving queries against a live snapshot
    /// cheap.
    pub fn extension_weights(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(
            b.len(),
            self.k(),
            "extension_weights: b must have one entry per selected column"
        );
        (0..self.k())
            .map(|t| crate::linalg::matrix::dot(self.winv.row(t), b))
            .collect()
    }

    /// `ĝ(z, i)` from weights precomputed by
    /// [`extension_weights`](Self::extension_weights).
    #[inline]
    pub fn extend_entry(&self, w: &[f64], i: usize) -> f64 {
        crate::linalg::matrix::dot(self.c.row(i), w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{inverse, Mat};

    /// Tiny rank-2 PSD matrix: sampling 2 independent columns reconstructs.
    #[test]
    fn exact_on_full_rank_sample() {
        let x = Mat::from_vec(2, 4, vec![1., 0., 1., 2., 0., 1., 1., -1.]);
        let g = x.t_matmul(&x); // 4×4 rank 2
        let idx = vec![0usize, 1];
        let c = g.select_cols(&idx);
        let w = c.select_rows(&idx);
        let approx = NystromApprox {
            indices: idx,
            winv: inverse(&w).unwrap(),
            c,
            selection_secs: 0.0,
        };
        let recon = approx.reconstruct();
        assert!(recon.fro_dist(&g) < 1e-10, "dist {}", recon.fro_dist(&g));
        assert_eq!(approx.k(), 2);
        assert_eq!(approx.n(), 4);
    }

    #[test]
    fn entry_matches_reconstruct() {
        let x = Mat::from_vec(3, 5, {
            let mut v = vec![0.0; 15];
            for (i, x) in v.iter_mut().enumerate() {
                *x = ((i * 7 + 3) % 5) as f64 - 2.0;
            }
            v
        });
        let g = x.t_matmul(&x);
        let idx = vec![0usize, 2, 4];
        let c = g.select_cols(&idx);
        let w = c.select_rows(&idx);
        let approx = NystromApprox {
            indices: idx,
            winv: crate::linalg::pinv_psd(&w, 1e-12),
            c,
            selection_secs: 0.0,
        };
        let full = approx.reconstruct();
        let p = approx.projector();
        for i in 0..5 {
            for j in 0..5 {
                assert!((approx.entry_with(&p, i, j) - full.at(i, j)).abs() < 1e-10);
            }
        }
    }

    /// Querying the extension at a *selected* point must reproduce that
    /// point's sampled column exactly: b is then a column of W, so
    /// `w = W⁻¹ b = eⱼ` and `ĝ(·, λⱼ) = C(·, j)`.
    #[test]
    fn extension_reproduces_selected_columns() {
        let mut rng = crate::util::rng::Pcg64::new(9);
        let mut x = Mat::zeros(4, 8);
        rng.fill_normal(&mut x.data);
        let g = x.t_matmul(&x); // 8×8 PSD, rank 4
        let idx = vec![0usize, 3, 6];
        let c = g.select_cols(&idx);
        let w = c.select_rows(&idx);
        let approx = NystromApprox {
            indices: idx.clone(),
            winv: inverse(&w).unwrap(),
            c,
            selection_secs: 0.0,
        };
        let scale = g.max_abs();
        for (j, &lam) in idx.iter().enumerate() {
            let b: Vec<f64> = idx.iter().map(|&i| g.at(i, lam)).collect();
            let wts = approx.extension_weights(&b);
            // near the j-th standard basis vector
            for (t, &wt) in wts.iter().enumerate() {
                let expect = if t == j { 1.0 } else { 0.0 };
                assert!((wt - expect).abs() < 1e-8, "w[{t}] = {wt}");
            }
            for i in 0..8 {
                assert!(
                    (approx.extend_entry(&wts, i) - g.at(i, lam)).abs()
                        < 1e-8 * scale.max(1.0),
                    "ĝ({i}, {lam}) diverged"
                );
            }
        }
    }

    #[test]
    fn nystrom_exact_on_lambda_block() {
        // G̃ restricted to (·, Λ) must equal G there when W is invertible
        // (DESIGN.md invariant 6).
        let mut rng = crate::util::rng::Pcg64::new(42);
        let mut x = Mat::zeros(3, 6);
        rng.fill_normal(&mut x.data);
        let g = x.t_matmul(&x);
        let idx = vec![1usize, 3, 5];
        let c = g.select_cols(&idx);
        let w = c.select_rows(&idx);
        let approx = NystromApprox {
            indices: idx.clone(),
            winv: inverse(&w).unwrap(),
            c,
            selection_secs: 0.0,
        };
        let recon = approx.reconstruct();
        let scale = g.max_abs();
        for i in 0..6 {
            for &j in &idx {
                assert!(
                    (recon.at(i, j) - g.at(i, j)).abs() < 1e-8 * scale,
                    "({i},{j}): {} vs {}",
                    recon.at(i, j),
                    g.at(i, j)
                );
            }
        }
    }
}
