//! Approximation-error metrics.
//!
//! * Exact `‖G−G̃‖_F/‖G‖_F` for the explicit class (Table I, Fig. 6/7),
//!   computed blockwise without materializing G̃.
//! * The paper's sampled-entry estimator for the implicit classes
//!   (Tables II/III): Frobenius discrepancy over 100,000 random entries.

use super::NystromApprox;
use crate::sampling::ColumnOracle;
use crate::util::{parallel, rng::Pcg64};

/// Exact relative Frobenius error `‖G−G̃‖_F / ‖G‖_F`, evaluated row-block
/// by row-block (O(n²k) work, O(n) extra memory per thread).
pub fn relative_frobenius_error(
    oracle: &dyn ColumnOracle,
    approx: &NystromApprox,
) -> f64 {
    let n = oracle.n();
    assert_eq!(n, approx.n());
    let p = approx.projector(); // n×k
    let c = &approx.c;
    let k = approx.k();
    let parts = parallel::map_ranges(n, parallel::default_threads(), |range| {
        let mut col = vec![0.0; n];
        let mut num = 0.0;
        let mut den = 0.0;
        for j in range {
            // column j of G (= row j by symmetry)
            oracle.column_into(j, &mut col);
            let cj = c.row(j);
            for i in 0..n {
                // G̃(i,j) = P(i,:)·C(j,:)
                let mut acc = 0.0;
                let pi = &p.data[i * k..(i + 1) * k];
                for t in 0..k {
                    acc += pi[t] * cj[t];
                }
                let d = col[i] - acc;
                num += d * d;
                den += col[i] * col[i];
            }
        }
        (num, den)
    });
    let (num, den): (f64, f64) = parts
        .into_iter()
        .fold((0.0, 0.0), |(a, b), (x, y)| (a + x, b + y));
    if den == 0.0 {
        return 0.0;
    }
    (num / den).sqrt()
}

/// Sampled-entry relative error: Frobenius discrepancy between `samples`
/// random entries of G and G̃ (paper §V-C). Deterministic given `seed`.
pub fn sampled_relative_error(
    oracle: &dyn ColumnOracle,
    approx: &NystromApprox,
    samples: usize,
    seed: u64,
) -> f64 {
    let n = oracle.n();
    let p = approx.projector();
    let pairs: Vec<(usize, usize)> = {
        let mut rng = Pcg64::new(seed);
        (0..samples)
            .map(|_| (rng.below(n), rng.below(n)))
            .collect()
    };
    let parts = parallel::map_ranges(
        pairs.len(),
        parallel::default_threads(),
        |range| {
            let mut num = 0.0;
            let mut den = 0.0;
            for idx in range {
                let (i, j) = pairs[idx];
                let g = oracle.entry(i, j);
                let gt = approx.entry_with(&p, i, j);
                num += (g - gt) * (g - gt);
                den += g * g;
            }
            (num, den)
        },
    );
    let (num, den): (f64, f64) = parts
        .into_iter()
        .fold((0.0, 0.0), |(a, b), (x, y)| (a + x, b + y));
    if den == 0.0 {
        return 0.0;
    }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::{kernel_matrix, Gaussian};
    use crate::sampling::{assemble_from_indices, ExplicitOracle, ImplicitOracle};

    #[test]
    fn exact_error_matches_dense_computation() {
        let ds = two_moons(45, 0.05, 1);
        let kern = Gaussian::new(0.8);
        let g = kernel_matrix(&ds, &kern);
        let oracle = ExplicitOracle::new(&g);
        let approx = assemble_from_indices(&oracle, vec![0, 9, 21, 33, 44], 0.0);
        let fast = relative_frobenius_error(&oracle, &approx);
        let dense = approx.reconstruct().fro_dist(&g) / g.fro_norm();
        assert!((fast - dense).abs() < 1e-10, "{fast} vs {dense}");
    }

    #[test]
    fn error_zero_when_exact() {
        // full sampling ⇒ exact reconstruction ⇒ zero error
        let ds = two_moons(20, 0.05, 2);
        let kern = Gaussian::new(1.0);
        let g = kernel_matrix(&ds, &kern);
        let oracle = ExplicitOracle::new(&g);
        let approx = assemble_from_indices(&oracle, (0..20).collect(), 0.0);
        let e = relative_frobenius_error(&oracle, &approx);
        assert!(e < 1e-7, "error {e}");
    }

    #[test]
    fn sampled_error_tracks_exact() {
        let ds = two_moons(80, 0.05, 3);
        let kern = Gaussian::new(0.7);
        let g = kernel_matrix(&ds, &kern);
        let oracle = ExplicitOracle::new(&g);
        let approx =
            assemble_from_indices(&oracle, vec![0, 10, 20, 30, 40, 50, 60, 70], 0.0);
        let exact = relative_frobenius_error(&oracle, &approx);
        let est = sampled_relative_error(&oracle, &approx, 20_000, 7);
        assert!(
            (est - exact).abs() < 0.25 * exact.max(1e-6),
            "est {est} exact {exact}"
        );
    }

    #[test]
    fn implicit_and_explicit_errors_agree() {
        let ds = two_moons(35, 0.05, 4);
        let kern = Gaussian::new(0.9);
        let g = kernel_matrix(&ds, &kern);
        let expo = ExplicitOracle::new(&g);
        let impo = ImplicitOracle::new(&ds, &kern);
        let approx = assemble_from_indices(&expo, vec![1, 8, 15, 29], 0.0);
        let a = relative_frobenius_error(&expo, &approx);
        let b = relative_frobenius_error(&impo, &approx);
        assert!((a - b).abs() < 1e-12);
    }
}
