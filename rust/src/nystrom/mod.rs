//! The Nyström approximation object, error metrics, and the approximate
//! SVD / diffusion-map embedding built from it (paper §II-C).

pub mod approx;
pub mod assembly;
pub mod embedding;
pub mod error;
pub mod store;
pub mod svd;

pub use approx::NystromApprox;
pub use assembly::{approx_from_colmajor, IncrementalAssembler};
pub use error::{relative_frobenius_error, sampled_relative_error};
pub use store::{Provenance, StoredArtifact};
pub use svd::{nystrom_eig, nystrom_factor};
