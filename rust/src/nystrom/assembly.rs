//! Assembling a [`NystromApprox`] from live session state.
//!
//! Sequential samplers keep their sampled columns *column-major* (each
//! selection appends one contiguous n-slice) while [`NystromApprox`]
//! stores C row-major. A one-shot transpose per snapshot is O(nk) strided
//! writes; sessions that snapshot repeatedly while growing (the serving
//! pattern: grow a few columns per request, hand out the current
//! approximation) would pay that full transpose every time. The
//! [`IncrementalAssembler`] caches the row-major image and transposes
//! only the columns appended since the last sync, so a snapshot after m
//! new selections costs O(nm) transpose work plus one contiguous copy.

use super::NystromApprox;
use crate::linalg::Mat;

/// Cached row-major image of a growing column-major column buffer.
#[derive(Clone, Debug)]
pub struct IncrementalAssembler {
    n: usize,
    /// columns already transposed into `data`.
    cols_done: usize,
    /// current column capacity (row stride of `data`).
    cap: usize,
    /// n × cap row-major; first `cols_done` entries of each row are live.
    data: Vec<f64>,
}

impl IncrementalAssembler {
    pub fn new(n: usize) -> IncrementalAssembler {
        IncrementalAssembler { n, cols_done: 0, cap: 0, data: Vec::new() }
    }

    pub fn cols_done(&self) -> usize {
        self.cols_done
    }

    /// Bring the cache up to `k` columns of `c_colmajor` (column t lives
    /// at `c_colmajor[t*n .. (t+1)*n]`). Only columns `cols_done..k` are
    /// transposed; earlier columns are assumed unchanged, which holds for
    /// every session here (selection only ever appends columns).
    pub fn sync(&mut self, c_colmajor: &[f64], k: usize) {
        assert!(c_colmajor.len() >= k * self.n, "column buffer too short");
        assert!(k >= self.cols_done, "columns cannot be removed");
        if k > self.cap {
            self.grow(k);
        }
        for t in self.cols_done..k {
            let src = &c_colmajor[t * self.n..(t + 1) * self.n];
            for (i, &v) in src.iter().enumerate() {
                self.data[i * self.cap + t] = v;
            }
        }
        self.cols_done = k;
    }

    /// Re-stride to a capacity of at least `k` columns (geometric growth,
    /// preserving the live block).
    fn grow(&mut self, k: usize) {
        let new_cap = k.max(self.cap * 2).max(8);
        let mut data = vec![0.0; self.n * new_cap];
        for i in 0..self.n {
            data[i * new_cap..i * new_cap + self.cols_done].copy_from_slice(
                &self.data[i * self.cap..i * self.cap + self.cols_done],
            );
        }
        self.cap = new_cap;
        self.data = data;
    }

    /// The current n×cols_done row-major C (contiguous copies per row; a
    /// straight memcpy when the capacity is exact).
    pub fn to_mat(&self) -> Mat {
        let k = self.cols_done;
        if k == self.cap {
            return Mat::from_vec(self.n, k, self.data.clone());
        }
        let mut out = Mat::zeros(self.n, k);
        for i in 0..self.n {
            out.data[i * k..(i + 1) * k]
                .copy_from_slice(&self.data[i * self.cap..i * self.cap + k]);
        }
        out
    }
}

/// One-shot assembly from raw session state: column-major sampled columns
/// plus the live k×k block of a (possibly over-allocated, `stride`-wide)
/// W⁻¹ buffer. Used by sessions that do not keep an incremental cache.
pub fn approx_from_colmajor(
    indices: Vec<usize>,
    n: usize,
    c_colmajor: &[f64],
    winv: &[f64],
    winv_stride: usize,
    selection_secs: f64,
) -> NystromApprox {
    let k = indices.len();
    let mut asm = IncrementalAssembler::new(n);
    asm.sync(c_colmajor, k);
    NystromApprox {
        indices,
        c: asm.to_mat(),
        winv: winv_block(winv, winv_stride, k),
        selection_secs,
    }
}

/// Extract the live k×k block of a stride-`stride` W⁻¹ buffer.
pub fn winv_block(winv: &[f64], stride: usize, k: usize) -> Mat {
    assert!(stride >= k && winv.len() >= (k.saturating_sub(1)) * stride + k);
    let mut out = Mat::zeros(k, k);
    for i in 0..k {
        out.data[i * k..(i + 1) * k]
            .copy_from_slice(&winv[i * stride..i * stride + k]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn colmajor(n: usize, k: usize) -> Vec<f64> {
        (0..k * n).map(|x| (x * 7 % 23) as f64 - 11.0).collect()
    }

    #[test]
    fn incremental_sync_matches_full_transpose() {
        let (n, k) = (9, 7);
        let c = colmajor(n, k);
        // incremental: sync in uneven chunks
        let mut asm = IncrementalAssembler::new(n);
        asm.sync(&c, 2);
        asm.sync(&c, 2); // no-op sync is fine
        asm.sync(&c, 5);
        asm.sync(&c, k);
        let m = asm.to_mat();
        for i in 0..n {
            for t in 0..k {
                assert_eq!(m.at(i, t), c[t * n + i]);
            }
        }
    }

    #[test]
    fn growth_preserves_live_block() {
        let (n, k) = (5, 40);
        let c = colmajor(n, k);
        let mut asm = IncrementalAssembler::new(n);
        for step in 1..=k {
            asm.sync(&c, step); // forces several re-strides
        }
        let m = asm.to_mat();
        assert_eq!((m.rows, m.cols), (n, k));
        for i in 0..n {
            for t in 0..k {
                assert_eq!(m.at(i, t), c[t * n + i]);
            }
        }
    }

    #[test]
    fn one_shot_assembly_extracts_winv_block() {
        let (n, k, stride) = (6, 3, 5);
        let c = colmajor(n, k);
        let mut winv = vec![0.0; stride * stride];
        for i in 0..k {
            for j in 0..k {
                winv[i * stride + j] = (i * 10 + j) as f64;
            }
        }
        let a = approx_from_colmajor(vec![1, 3, 5], n, &c, &winv, stride, 0.25);
        assert_eq!(a.k(), k);
        assert_eq!(a.n(), n);
        assert_eq!(a.selection_secs, 0.25);
        for i in 0..k {
            for j in 0..k {
                assert_eq!(a.winv.at(i, j), (i * 10 + j) as f64);
            }
        }
        assert_eq!(a.c.at(4, 2), c[2 * n + 4]);
    }
}
