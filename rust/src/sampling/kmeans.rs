//! K-means Nyström (Zhang, Tsang & Kwok [16], paper §II-D4).
//!
//! Instead of sampling columns of G, cluster the *data* into K centroids
//! and approximate `G ≈ E W⁺ Eᵀ` where `E(i,j) = k(zᵢ, cⱼ)` and
//! `W(i,j) = k(cᵢ, cⱼ)`. The centroids are not data points, so the method
//! yields no index set Λ (`indices` is empty) and cannot serve general CSS
//! — the limitation the paper highlights.

use super::{ColumnOracle, ColumnSampler};
use crate::data::Dataset;
use crate::kernels::Kernel;
use crate::linalg::{pinv_psd, Mat};
use crate::nystrom::NystromApprox;
use crate::util::{parallel, rng::Pcg64, timing::Stopwatch};
use crate::Result;
use crate::bail;

/// Lloyd's algorithm with k-means++ seeding.
pub struct KMeans {
    pub k: usize,
    pub max_iters: usize,
    pub seed: u64,
}

impl KMeans {
    pub fn new(k: usize, seed: u64) -> KMeans {
        KMeans { k, max_iters: 25, seed }
    }

    /// Run Lloyd's algorithm; returns (centroids, assignments, iterations).
    pub fn fit(&self, ds: &Dataset) -> (Dataset, Vec<usize>, usize) {
        let n = ds.n();
        let dim = ds.dim();
        let k = self.k.min(n);
        let mut rng = Pcg64::new(self.seed);

        // --- k-means++ seeding ---
        let mut centroids = Dataset::zeros(k, dim);
        let first = rng.below(n);
        centroids.point_mut(0).copy_from_slice(ds.point(first));
        let mut dist2 = vec![f64::INFINITY; n];
        for c in 1..k {
            let prev = centroids.point(c - 1).to_vec();
            for i in 0..n {
                let d = sq_dist(ds.point(i), &prev);
                if d < dist2[i] {
                    dist2[i] = d;
                }
            }
            let total: f64 = dist2.iter().sum();
            let next = if total > 0.0 {
                rng.weighted_index(&dist2)
            } else {
                rng.below(n)
            };
            centroids.point_mut(c).copy_from_slice(ds.point(next));
        }

        // --- Lloyd iterations ---
        let threads = parallel::default_threads();
        let mut assign = vec![0usize; n];
        let mut iters = 0;
        for it in 0..self.max_iters {
            iters = it + 1;
            // assignment step (threaded)
            let new_assign: Vec<usize> = parallel::map_ranges(n, threads, |range| {
                let mut out = Vec::with_capacity(range.len());
                for i in range {
                    let p = ds.point(i);
                    let mut best = 0;
                    let mut bd = f64::INFINITY;
                    for c in 0..k {
                        let d = sq_dist(p, centroids.point(c));
                        if d < bd {
                            bd = d;
                            best = c;
                        }
                    }
                    out.push(best);
                }
                out
            })
            .into_iter()
            .flatten()
            .collect();
            let changed = new_assign
                .iter()
                .zip(&assign)
                .filter(|(a, b)| a != b)
                .count();
            assign = new_assign;
            // update step
            let mut sums = vec![0.0; k * dim];
            let mut counts = vec![0usize; k];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                let p = ds.point(i);
                for d in 0..dim {
                    sums[c * dim + d] += p[d];
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    // re-seed empty cluster at a random point
                    let j = rng.below(n);
                    centroids.point_mut(c).copy_from_slice(ds.point(j));
                } else {
                    let inv = 1.0 / counts[c] as f64;
                    let cp = centroids.point_mut(c);
                    for d in 0..dim {
                        cp[d] = sums[c * dim + d] * inv;
                    }
                }
            }
            if changed == 0 && it > 0 {
                break;
            }
        }
        (centroids, assign, iters)
    }
}

#[inline]
fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// The K-means Nyström approximator. Unlike the column samplers it needs
/// the raw dataset and kernel function, not just a column oracle.
pub struct KMeansNystrom<'a> {
    pub ds: &'a Dataset,
    pub kernel: &'a dyn Kernel,
    pub k: usize,
    pub seed: u64,
}

impl<'a> KMeansNystrom<'a> {
    pub fn new(
        ds: &'a Dataset,
        kernel: &'a dyn Kernel,
        k: usize,
        seed: u64,
    ) -> Self {
        KMeansNystrom { ds, kernel, k, seed }
    }

    /// Build the approximation G ≈ E W⁺ Eᵀ from K centroids.
    pub fn approximate(&self) -> Result<NystromApprox> {
        let sw = Stopwatch::start();
        let n = self.ds.n();
        if self.k > n {
            bail!("k > n");
        }
        let (centroids, _assign, _iters) =
            KMeans::new(self.k, self.seed).fit(self.ds);
        let k = centroids.n();
        // E: n×k kernel evaluations against centroids (threaded)
        let mut e = Mat::zeros(n, k);
        {
            let ds = self.ds;
            let kernel = self.kernel;
            let cent = &centroids;
            parallel::for_each_chunk_mut(
                &mut e.data,
                k,
                parallel::default_threads(),
                |range, chunk| {
                    for (local, i) in range.clone().enumerate() {
                        let zi = ds.point(i);
                        let row = &mut chunk[local * k..(local + 1) * k];
                        for (c, out) in row.iter_mut().enumerate() {
                            *out = kernel.eval(zi, cent.point(c));
                        }
                    }
                },
            );
        }
        // W: k×k centroid kernel matrix
        let w = Mat::from_fn(k, k, |i, j| {
            self.kernel.eval(centroids.point(i), centroids.point(j))
        });
        let winv = pinv_psd(&w, 1e-12);
        Ok(NystromApprox {
            indices: vec![], // no columns of G are sampled (§II-D4)
            c: e,
            winv,
            selection_secs: sw.secs(),
        })
    }
}

/// Adapter so K-means Nyström can sit in `&[&dyn ColumnSampler]` method
/// sweeps. `sample` ignores the oracle's columns and uses the bound
/// dataset; callers must pass an oracle over the same data.
impl ColumnSampler for KMeansNystrom<'_> {
    fn name(&self) -> &'static str {
        "K-means"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        if oracle.n() != self.ds.n() {
            bail!("oracle size does not match bound dataset");
        }
        self.approximate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_clusters, two_moons};
    use crate::kernels::Gaussian;
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::ImplicitOracle;

    #[test]
    fn kmeans_recovers_separated_clusters() {
        // 4 tight, well-separated clusters: inertia must be near zero and
        // each cluster pure.
        let ds = gaussian_clusters(200, 3, 4, 0.05, 1);
        let (cent, assign, _) = KMeans::new(4, 2).fit(&ds);
        assert_eq!(cent.n(), 4);
        // all points close to their centroid
        for i in 0..ds.n() {
            let d = sq_dist(ds.point(i), cent.point(assign[i]));
            assert!(d < 0.5, "point {i} far from centroid: {d}");
        }
    }

    #[test]
    fn empty_cluster_reseeded() {
        // k larger than distinct points — must not panic
        let ds = crate::data::Dataset::from_rows(vec![vec![0.0, 0.0]; 10]);
        let (cent, _, _) = KMeans::new(5, 3).fit(&ds);
        assert_eq!(cent.n(), 5);
    }

    #[test]
    fn nystrom_accuracy_on_cluster_data() {
        // BORG-like data is K-means's best case (paper §V-E)
        let ds = gaussian_clusters(150, 4, 6, 0.15, 4);
        let kern = Gaussian::new(2.0);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = KMeansNystrom::new(&ds, &kern, 24, 5).approximate().unwrap();
        assert!(approx.indices.is_empty());
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 0.05, "err {err}");
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = two_moons(80, 0.05, 6);
        let (c1, a1, _) = KMeans::new(8, 9).fit(&ds);
        let (c2, a2, _) = KMeans::new(8, 9).fit(&ds);
        assert_eq!(a1, a2);
        assert_eq!(c1, c2);
    }
}
