//! oASIS — Accelerated Sequential Incoherence Selection (paper Alg. 1).
//!
//! Selects columns greedily by the Schur-complement criterion
//! `Δᵢ = dᵢ − bᵢᵀ W_k⁻¹ bᵢ` (the squared distance of xᵢ from the span of
//! the selected columns' factor), maintaining `W⁻¹` by the Eq. 5 block
//! inverse update. Two scoring variants are provided:
//!
//! * [`Variant::PaperR`] — the paper's formulation: maintain
//!   `R = W⁻¹Cᵀ` with the Eq. 6 rank-1 update and score with
//!   `Δ = d − colsum(C∘R)`. O(kn) per iteration, 2·ℓn state.
//! * [`Variant::Incremental`] — an algebraically identical optimization
//!   (EXPERIMENTS.md §Perf): after appending column i with Schur
//!   complement s⁻¹ and `diff = C q − c_new`, every candidate score
//!   updates in place as `Δᵢ ← Δᵢ − s·diffᵢ²`, so R need not be stored or
//!   updated at all. Same O(kn) asymptotic with roughly half the memory
//!   traffic; bit-equal selection sequences are enforced by tests.
//!
//! Both variants select identical column sequences (up to f64 rounding in
//! degenerate ties) and satisfy Lemma 1/Theorem 1: each selected column is
//! linearly independent of its predecessors while Δ > 0, and a rank-r
//! matrix is recovered exactly in r steps.
//!
//! The selection loop lives in [`OasisSession`] — one selection per
//! [`step`](SamplerSession::step), state growing on demand so a session
//! can be resumed past its constructor's budget. [`Oasis::sample`] /
//! [`Oasis::sample_traced`] are thin adapters: create a session, drive it
//! with [`run_to_completion`] under a column-budget rule, assemble.

use super::session::{
    run_to_completion, SamplerSession, StepOutcome, StopReason, StoppingRule,
};
use super::{ColumnOracle, ColumnSampler, SelectionTrace, TracedSampler};
use crate::linalg::Mat;
use crate::nystrom::{assembly, NystromApprox};
use crate::util::{parallel, rng::Pcg64, timing::Stopwatch};
use crate::{anyhow, bail};
use crate::Result;
use std::cell::RefCell;

/// Scoring strategy (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Paper-faithful: maintain R (Eq. 6) and recompute colsum(C∘R).
    PaperR,
    /// Optimized: maintain Δ incrementally, never store R.
    Incremental,
}

/// The oASIS sampler.
#[derive(Clone, Debug)]
pub struct Oasis {
    /// ℓ — maximum number of sampled columns.
    pub max_cols: usize,
    /// k₀ — number of random seed columns.
    pub init_cols: usize,
    /// ε — stop when max |Δ| falls below this.
    pub tol: f64,
    /// seed for the random initial columns.
    pub seed: u64,
    pub variant: Variant,
    /// worker threads for the O(kn) sweeps (defaults to the machine).
    pub threads: usize,
}

impl Oasis {
    pub fn new(max_cols: usize, init_cols: usize, tol: f64, seed: u64) -> Oasis {
        assert!(init_cols >= 1 && init_cols <= max_cols);
        Oasis {
            max_cols,
            init_cols,
            tol,
            seed,
            variant: Variant::Incremental,
            threads: parallel::default_threads(),
        }
    }

    pub fn with_variant(mut self, v: Variant) -> Oasis {
        self.variant = v;
        self
    }

    /// Open a stepwise session: draws and incorporates the k₀ random seed
    /// columns (redrawn if W₀ is singular), computes the initial Δ scores,
    /// and returns with `session.k() == k₀`, ready to step. The session
    /// borrows the oracle; its state grows on demand, so it can be driven
    /// past `max_cols` (that field only sizes the initial allocation and
    /// the budget used by the one-shot [`Oasis::sample`] adapter).
    pub fn session<'a>(
        &self,
        oracle: &'a dyn ColumnOracle,
    ) -> Result<OasisSession<'a>> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let l = self.max_cols.min(n);
        if l == 0 {
            bail!("max_cols must be ≥ 1");
        }
        let k0 = self.init_cols.min(l);
        let d = oracle.diag();
        let tol = super::effective_tol(self.tol, &d);
        let d_abs_sum: f64 = d.iter().map(|x| x.abs()).sum();

        let mut state = State::new(n, l, self.threads);

        // --- seed: k₀ random columns (redrawn if W₀ is singular) ---
        let mut rng = Pcg64::new(self.seed);
        let lambda: Vec<usize>;
        let mut attempt = 0;
        loop {
            let cand = rng.sample_without_replacement(n, k0);
            if state.try_seed(oracle, &cand) {
                lambda = cand;
                break;
            }
            attempt += 1;
            if attempt >= 16 {
                return Err(anyhow!(
                    "oASIS: could not find {k0} linearly independent seed columns \
                     in 16 draws (matrix rank < k0?) — lower init_cols"
                ));
            }
        }
        let mut selected = vec![false; n];
        for &j in &lambda {
            selected[j] = true;
        }

        let mut trace = SelectionTrace::default();
        for &j in &lambda {
            trace.order.push(j);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(f64::NAN); // seed columns have no Δ
        }

        // --- initial Δ ---
        let mut delta = vec![0.0; n];
        match self.variant {
            Variant::PaperR => {
                state.build_r_from_scratch();
                state.colsum_delta(&d, &mut delta);
            }
            Variant::Incremental => state.seed_delta(&d, &mut delta),
        }

        Ok(OasisSession {
            oracle,
            variant: self.variant,
            tol,
            n,
            d,
            d_abs_sum,
            delta,
            selected,
            state,
            trace,
            assembler: RefCell::new(assembly::IncrementalAssembler::new(n)),
            exhausted: None,
            busy_secs: sw.secs(),
        })
    }

    /// Open a session warm-started from a previously selected index set
    /// (artifact warm start): the first `init_cols` indices seed W₀ by
    /// direct inversion — the same arithmetic [`session`](Oasis::session)
    /// applies to its successful seed draw — and the remaining indices
    /// are *replayed* through the step arithmetic with the argmax
    /// replaced by the stored selection. Because a step's arithmetic
    /// depends only on which index is incorporated (never on how it was
    /// chosen), the resulting state is bit-identical to the session that
    /// produced `indices` — given the same oracle, `init_cols`, and
    /// variant — so continued selection extends it exactly as an
    /// uninterrupted run would.
    ///
    /// Replay cost is the same O(kn) per column as selection was, minus
    /// the argmax sweeps. Errors cleanly when the indices repeat, fall
    /// out of range, or score below the tolerance mid-replay — the
    /// signature of an artifact that does not match this dataset/kernel.
    pub fn session_from_indices<'a>(
        &self,
        oracle: &'a dyn ColumnOracle,
        indices: &[usize],
    ) -> Result<OasisSession<'a>> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        if indices.is_empty() {
            bail!("warm start needs at least one stored index");
        }
        let mut seen = vec![false; n];
        for &j in indices {
            if j >= n {
                bail!("stored index {j} out of range (n = {n})");
            }
            if seen[j] {
                bail!("stored index {j} repeats");
            }
            seen[j] = true;
        }
        // capacity covers both the configured budget and the warm prefix
        // (indices.len() ≤ n — all distinct and < n); the W⁻¹ stride this
        // picks never affects the arithmetic, only reallocation count
        let l = self.max_cols.min(n).max(indices.len());
        let k0 = self.init_cols.min(l).min(indices.len());
        let d = oracle.diag();
        let tol = super::effective_tol(self.tol, &d);
        let d_abs_sum: f64 = d.iter().map(|x| x.abs()).sum();
        let mut state = State::new(n, l, self.threads);
        if !state.try_seed(oracle, &indices[..k0]) {
            bail!(
                "the stored seed columns are singular on this dataset/kernel \
                 — artifact mismatch?"
            );
        }
        let mut selected = vec![false; n];
        let mut trace = SelectionTrace::default();
        for &j in &indices[..k0] {
            selected[j] = true;
            trace.order.push(j);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(f64::NAN);
        }
        let mut delta = vec![0.0; n];
        match self.variant {
            Variant::PaperR => {
                state.build_r_from_scratch();
                state.colsum_delta(&d, &mut delta);
            }
            Variant::Incremental => state.seed_delta(&d, &mut delta),
        }
        let mut session = OasisSession {
            oracle,
            variant: self.variant,
            tol,
            n,
            d,
            d_abs_sum,
            delta,
            selected,
            state,
            trace,
            assembler: RefCell::new(assembly::IncrementalAssembler::new(n)),
            exhausted: None,
            busy_secs: sw.secs(),
        };
        for &j in &indices[k0..] {
            session
                .force_select(j)
                .map_err(|e| e.wrap("warm-start replay"))?;
        }
        Ok(session)
    }

    /// Run selection, returning the approximation and the per-step trace.
    pub fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let mut session = self.session(oracle)?;
        run_to_completion(&mut session, &StoppingRule::budget(self.max_cols))?;
        let trace = session.trace().clone();
        let approx = session.snapshot()?;
        Ok((approx, trace))
    }
}

impl ColumnSampler for Oasis {
    fn name(&self) -> &'static str {
        "oASIS"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for Oasis {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        Oasis::sample_traced(self, oracle)
    }
}

/// A paused oASIS run (see [`Oasis::session`]). One column selection per
/// [`step`](SamplerSession::step); the selection sequence is bit-identical
/// to the one-shot [`Oasis::sample`] path for either [`Variant`].
pub struct OasisSession<'a> {
    oracle: &'a dyn ColumnOracle,
    variant: Variant,
    /// effective tolerance (numerical floor; see `effective_tol`).
    tol: f64,
    n: usize,
    d: Vec<f64>,
    d_abs_sum: f64,
    delta: Vec<f64>,
    selected: Vec<bool>,
    state: State,
    trace: SelectionTrace,
    /// cached row-major C for cheap repeated snapshots.
    assembler: RefCell<assembly::IncrementalAssembler>,
    exhausted: Option<StopReason>,
    busy_secs: f64,
}

impl SamplerSession for OasisSession<'_> {
    fn name(&self) -> &'static str {
        "oASIS"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn indices(&self) -> &[usize] {
        &self.trace.order
    }

    fn trace(&self) -> &SelectionTrace {
        &self.trace
    }

    fn selection_secs(&self) -> f64 {
        self.busy_secs
    }

    /// Residual trace ratio `Σ_unselected |Δᵢ| / Σ |dᵢ|` — the residual
    /// diagonal after k selections is exactly Δ, so this is
    /// `trace(G − G̃) / trace-scale(G)`, a cheap proxy for the relative
    /// error that decreases to 0 as the approximation becomes exact. For
    /// [`Variant::PaperR`] the Δ vector is the one from the most recent
    /// scoring sweep (stale by at most one update).
    fn error_estimate(&self) -> Option<f64> {
        if self.d_abs_sum <= 0.0 {
            return Some(0.0);
        }
        let resid: f64 = self
            .delta
            .iter()
            .zip(&self.selected)
            .filter(|(_, &sel)| !sel)
            .map(|(&dv, _)| dv.abs())
            .sum();
        Some(resid / self.d_abs_sum)
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.exhausted {
            return Ok(StepOutcome::Exhausted(reason));
        }
        let sw = Stopwatch::start();
        let scan_span = crate::obs::span("score_scan", "sampling");
        if self.variant == Variant::PaperR {
            self.state.colsum_delta(&self.d, &mut self.delta);
        }
        // argmax |Δ| over unselected
        let (best, best_abs) = argmax_abs(&self.delta, &self.selected);
        drop(scan_span);
        if best == usize::MAX {
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        if best_abs < self.tol {
            self.exhausted = Some(StopReason::ScoreBelowTol);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::ScoreBelowTol));
        }
        self.incorporate(best, best_abs, &sw);
        Ok(StepOutcome::Selected { index: best, score: best_abs })
    }

    fn snapshot(&self) -> Result<NystromApprox> {
        let k = self.state.k;
        let mut asm = self.assembler.borrow_mut();
        asm.sync(&self.state.c, k);
        Ok(NystromApprox {
            indices: self.trace.order.clone(),
            c: asm.to_mat(),
            winv: assembly::winv_block(&self.state.winv, self.state.cap, k),
            selection_secs: self.busy_secs,
        })
    }
}

impl OasisSession<'_> {
    /// Incorporate column `best` into the state — Eq. 5 (and, for
    /// PaperR, Eq. 6) updates, selection bookkeeping, trace, and time
    /// accounting. `best_abs` is `|Δ[best]|`, already verified ≥ the
    /// tolerance by the caller. Shared by
    /// [`step`](SamplerSession::step) (argmax selection) and
    /// [`force_select`](OasisSession::force_select) (warm-start replay),
    /// so both perform bit-identical arithmetic.
    fn incorporate(&mut self, best: usize, best_abs: f64, sw: &Stopwatch) {
        let k = self.state.k;
        let s = 1.0 / self.delta[best];
        // new column from the oracle
        let fetch_span = crate::obs::span("column_fetch", "sampling");
        let col = self.state.fetch_column(self.oracle, best);
        drop(fetch_span);
        let _update_span = crate::obs::span("factor_update", "sampling");
        // q = W⁻¹ b where b = C(Λ, best) = row `best` of C
        let q = self.state.q_for(best, k);
        match self.variant {
            // fused: diff = C q − c_new and Δᵢ ← Δᵢ − s·diffᵢ² in one
            // sweep (the Δ chunk is consumed while the diff chunk is
            // still cache-hot; bit-identical to the two-pass form)
            Variant::Incremental => {
                self.state.compute_diff_update_delta(&q, &col, s, &mut self.delta)
            }
            // PaperR only needs diff (Δ comes from the colsum rescore)
            Variant::PaperR => self.state.compute_diff(&q, &col, k),
        }
        self.state.apply_update(&q, &col, s, k, self.variant);
        self.selected[best] = true;
        self.trace.order.push(best);
        self.trace.cum_secs.push(self.busy_secs + sw.secs());
        self.trace.deltas.push(best_abs);
        self.busy_secs += sw.secs();
    }

    /// Warm-start replay: incorporate a *stored* selection instead of
    /// the argmax. Mirrors [`step`](SamplerSession::step) exactly —
    /// including the PaperR per-step rescore — with the argmax sweep
    /// replaced by the given index, so a replayed session's state is
    /// bit-identical to the one that recorded the index.
    fn force_select(&mut self, best: usize) -> Result<()> {
        let sw = Stopwatch::start();
        if self.variant == Variant::PaperR {
            self.state.colsum_delta(&self.d, &mut self.delta);
        }
        if best >= self.n || self.selected[best] {
            bail!("stored index {best} is out of range or already selected");
        }
        let best_abs = self.delta[best].abs();
        // `!(≥)` also catches a NaN score
        if !(best_abs >= self.tol) {
            bail!(
                "replaying stored index {best}: |Δ| = {best_abs:.3e} is below \
                 the selection tolerance — the artifact does not match this \
                 dataset/kernel"
            );
        }
        self.incorporate(best, best_abs, &sw);
        Ok(())
    }
}

/// Mutable algorithm state shared by the variants. Capacity (`cap`, the
/// W⁻¹ stride) grows geometrically when a session is driven past its
/// initial budget, so resumed sessions extend in place.
struct State {
    n: usize,
    /// current column capacity; also the row stride of `winv`.
    cap: usize,
    threads: usize,
    /// sampled columns, column-major: column t at `c[t*n .. (t+1)*n]`
    c: Vec<f64>,
    /// W⁻¹, row-major with stride `cap`; live block k×k
    winv: Vec<f64>,
    /// R = W⁻¹Cᵀ, row-major with stride n; live rows 0..k (PaperR only,
    /// allocated lazily on first use and grown row-by-row)
    r: Vec<f64>,
    r_allocated: bool,
    /// scratch: diff = C q − c_new
    diff: Vec<f64>,
    k: usize,
}

impl State {
    fn new(n: usize, cap: usize, threads: usize) -> State {
        State {
            n,
            cap,
            threads,
            c: Vec::with_capacity(cap * n),
            winv: vec![0.0; cap * cap],
            r: Vec::new(),
            r_allocated: false,
            diff: vec![0.0; n],
            k: 0,
        }
    }

    /// Ensure room for one more column, re-striding W⁻¹ if needed.
    fn ensure_capacity(&mut self, k_next: usize) {
        if k_next <= self.cap {
            return;
        }
        let new_cap = (self.cap * 2).max(k_next).min(self.n.max(k_next));
        let mut winv = vec![0.0; new_cap * new_cap];
        for i in 0..self.k {
            winv[i * new_cap..i * new_cap + self.k]
                .copy_from_slice(&self.winv[i * self.cap..i * self.cap + self.k]);
        }
        self.winv = winv;
        self.cap = new_cap;
    }

    fn ensure_r(&mut self, rows: usize) {
        self.r_allocated = true;
        if self.r.len() < rows * self.n {
            self.r.resize(rows * self.n, 0.0);
        }
    }

    /// Try to seed with the candidate index set; false if W₀ is singular.
    /// Columns arrive through one batched oracle fill.
    fn try_seed(&mut self, oracle: &dyn ColumnOracle, cand: &[usize]) -> bool {
        let k0 = cand.len();
        let n = self.n;
        let mut block = Mat::zeros(n, k0);
        oracle.columns_into(cand, &mut block);
        self.c.clear();
        self.c.resize(k0 * n, 0.0);
        for t in 0..k0 {
            for i in 0..n {
                self.c[t * n + i] = block.data[i * k0 + t];
            }
        }
        // W₀ = C(Λ, :) — k0×k0
        let mut w = Mat::zeros(k0, k0);
        for (ti, &i) in cand.iter().enumerate() {
            for tj in 0..k0 {
                *w.at_mut(ti, tj) = self.c[tj * n + i];
            }
        }
        let inv = match crate::linalg::inverse(&w) {
            Some(inv) => inv,
            None => return false,
        };
        // reject near-singular seeds (would poison later updates)
        let cond_proxy = inv.max_abs() * w.max_abs();
        if !cond_proxy.is_finite() || cond_proxy > 1e12 {
            return false;
        }
        for i in 0..k0 {
            for j in 0..k0 {
                self.winv[i * self.cap + j] = inv.at(i, j);
            }
        }
        self.k = k0;
        true
    }

    /// The paper's per-iteration scoring: Δ = d − colsum(C∘R), reading the
    /// maintained R (PaperR variant).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): the sweep streams row-pairs
    /// (t-outer) so both `c_t` and `r_t` are read sequentially; the naive
    /// i-outer loop strides by n per element and is several times slower
    /// at n=20k, k=256.
    fn colsum_delta(&self, d: &[f64], delta: &mut [f64]) {
        debug_assert!(self.r_allocated);
        let k = self.k;
        let n = self.n;
        let c = &self.c;
        let r = &self.r;
        parallel::for_each_chunk_mut(delta, 1, self.threads, |range, chunk| {
            let (lo, hi) = (range.start, range.end);
            // chunk = d[lo..hi] − Σ_t c_t[lo..hi] ∘ r_t[lo..hi]
            chunk.copy_from_slice(&d[lo..hi]);
            for t in 0..k {
                let ct = &c[t * n + lo..t * n + hi];
                let rt = &r[t * n + lo..t * n + hi];
                for ((o, &cv), &rv) in chunk.iter_mut().zip(ct).zip(rt) {
                    *o -= cv * rv;
                }
            }
        });
    }

    /// Seed-time Δ for the Incremental variant (which never stores R):
    /// Δᵢ = dᵢ − bᵢᵀ W⁻¹ bᵢ with bᵢ = C(i,:). O(k₀²·n).
    fn seed_delta(&self, d: &[f64], delta: &mut [f64]) {
        let k = self.k;
        let n = self.n;
        let cap = self.cap;
        let c = &self.c;
        let winv = &self.winv;
        parallel::for_each_chunk_mut(delta, 1, self.threads, |range, chunk| {
            let mut b = vec![0.0; k];
            for (local, i) in range.clone().enumerate() {
                for (t, bt) in b.iter_mut().enumerate() {
                    *bt = c[t * n + i];
                }
                let mut quad = 0.0;
                for t in 0..k {
                    let row = &winv[t * cap..t * cap + k];
                    quad += b[t] * crate::linalg::matrix::dot(row, &b);
                }
                chunk[local] = d[i] - quad;
            }
        });
    }

    /// Build R = W⁻¹Cᵀ from scratch (seed time, PaperR variant).
    fn build_r_from_scratch(&mut self) {
        let k = self.k;
        self.ensure_r(k);
        let n = self.n;
        let cap = self.cap;
        let winv = &self.winv;
        let c = &self.c;
        parallel::for_each_chunk_mut(
            &mut self.r[..k * n],
            n,
            self.threads,
            |range, chunk| {
                for (local, t) in range.clone().enumerate() {
                    let row = &mut chunk[local * n..(local + 1) * n];
                    row.fill(0.0);
                    for u in 0..k {
                        let w = winv[t * cap + u];
                        if w == 0.0 {
                            continue;
                        }
                        let cu = &c[u * n..(u + 1) * n];
                        for (o, &cv) in row.iter_mut().zip(cu) {
                            *o += w * cv;
                        }
                    }
                }
            },
        );
    }

    fn fetch_column(&mut self, oracle: &dyn ColumnOracle, j: usize) -> Vec<f64> {
        let mut col = vec![0.0; self.n];
        oracle.column_into(j, &mut col);
        col
    }

    /// q = W⁻¹ b with b = C(best,:) over live columns.
    fn q_for(&self, best: usize, k: usize) -> Vec<f64> {
        let n = self.n;
        let cap = self.cap;
        let mut b = vec![0.0; k];
        for (t, bt) in b.iter_mut().enumerate() {
            *bt = self.c[t * n + best];
        }
        let mut q = vec![0.0; k];
        for t in 0..k {
            let row = &self.winv[t * cap..t * cap + k];
            q[t] = crate::linalg::matrix::dot(row, &b);
        }
        q
    }

    /// diff = C q − c_new (threaded O(kn) sweep, streaming t-outer).
    fn compute_diff(&mut self, q: &[f64], col: &[f64], k: usize) {
        let n = self.n;
        let c = &self.c;
        parallel::for_each_chunk_mut(&mut self.diff, 1, self.threads, |range, chunk| {
            let (lo, hi) = (range.start, range.end);
            for (o, &cv) in chunk.iter_mut().zip(&col[lo..hi]) {
                *o = -cv;
            }
            for (t, &qt) in q.iter().enumerate().take(k) {
                if qt == 0.0 {
                    continue;
                }
                let ct = &c[t * n + lo..t * n + hi];
                for (o, &cv) in chunk.iter_mut().zip(ct) {
                    *o += qt * cv;
                }
            }
        });
    }

    /// Fused Incremental-variant step sweep: [`fused_step_update`] over
    /// this state's diff scratch (see that function for the contract).
    fn compute_diff_update_delta(
        &mut self,
        q: &[f64],
        col: &[f64],
        s: f64,
        delta: &mut [f64],
    ) {
        fused_step_update(
            &self.c,
            self.n,
            q,
            col,
            s,
            &mut self.diff,
            delta,
            self.threads,
        );
    }

    /// Apply Eq. 5 (W⁻¹) and, for PaperR, Eq. 6 (R); append the column.
    fn apply_update(&mut self, q: &[f64], col: &[f64], s: f64, k: usize, v: Variant) {
        self.ensure_capacity(k + 1);
        let cap = self.cap;
        let n = self.n;
        // W⁻¹ ← [W⁻¹ + s qqᵀ, −sq; −sqᵀ, s]
        for i in 0..k {
            let qi = q[i];
            let row = &mut self.winv[i * cap..i * cap + k];
            for (j, w) in row.iter_mut().enumerate() {
                *w += s * qi * q[j];
            }
            self.winv[i * cap + k] = -s * qi;
            self.winv[k * cap + i] = -s * qi;
        }
        self.winv[k * cap + k] = s;
        if v == Variant::PaperR {
            self.ensure_r(k + 1);
            // R rows 0..k: R_t += s q_t diff ; new row k: −s diff
            let diff = &self.diff;
            let threads = self.threads;
            parallel::for_each_chunk_mut(
                &mut self.r[..k * n],
                n,
                threads,
                |range, chunk| {
                    for (local, t) in range.clone().enumerate() {
                        let qt = s * q[t];
                        if qt == 0.0 {
                            continue;
                        }
                        let row = &mut chunk[local * n..(local + 1) * n];
                        for (o, &dv) in row.iter_mut().zip(diff) {
                            *o += qt * dv;
                        }
                    }
                },
            );
            for i in 0..n {
                self.r[k * n + i] = -s * diff[i];
            }
        }
        self.c.extend_from_slice(col);
        self.k = k + 1;
    }
}

/// The Incremental-variant step recurrence as one fused sweep: compute
/// `diff = C q − c_new` and immediately apply `Δᵢ ← Δᵢ − s·diffᵢ²` while
/// each freshly written diff chunk is still cache-hot — one pass over Δ
/// folded into the diff sweep instead of the separate O(n) re-read the
/// two-pass form pays. `c` holds the k = `q.len()` sampled columns
/// column-major (column t at `c[t*n..(t+1)*n]`); `diff` and `delta` have
/// length n.
///
/// Bit-identity contract: within a chunk the diff computation finishes
/// (init `−col`, then t-ascending `+= q_t·c_t` skipping `q_t == 0.0` —
/// exactly `State::compute_diff`'s order) before any Δ element is
/// touched, and chunk boundaries are shared, so every element sees the
/// same arithmetic in the same order as the unfused pair. Pinned by a
/// property test and by the in-test naive reference in
/// `rust/tests/session.rs`.
#[allow(clippy::too_many_arguments)]
pub fn fused_step_update(
    c: &[f64],
    n: usize,
    q: &[f64],
    col: &[f64],
    s: f64,
    diff: &mut [f64],
    delta: &mut [f64],
    threads: usize,
) {
    debug_assert_eq!(diff.len(), n);
    debug_assert_eq!(delta.len(), n);
    debug_assert!(c.len() >= q.len() * n);
    parallel::for_each_chunk_mut2(diff, delta, threads, |range, dchunk, delta_chunk| {
        let (lo, hi) = (range.start, range.end);
        for (o, &cv) in dchunk.iter_mut().zip(&col[lo..hi]) {
            *o = -cv;
        }
        for (t, &qt) in q.iter().enumerate() {
            if qt == 0.0 {
                continue;
            }
            let ct = &c[t * n + lo..t * n + hi];
            for (o, &cv) in dchunk.iter_mut().zip(ct) {
                *o += qt * cv;
            }
        }
        for (dl, &dv) in delta_chunk.iter_mut().zip(dchunk.iter()) {
            *dl -= s * dv * dv;
        }
    });
}

/// argmax of |Δ| over unselected indices; returns (index, |Δ|).
fn argmax_abs(delta: &[f64], selected: &[bool]) -> (usize, f64) {
    let mut best = usize::MAX;
    let mut best_abs = -1.0;
    for (i, &d) in delta.iter().enumerate() {
        if selected[i] {
            continue;
        }
        let a = d.abs();
        if a > best_abs {
            best_abs = a;
            best = i;
        }
    }
    (best, best_abs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gauss_2d_plus_3d, two_moons};
    use crate::kernels::{kernel_matrix, Gaussian, Linear};
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::{ExplicitOracle, ImplicitOracle};

    #[test]
    fn exact_recovery_rank3_in_3_steps() {
        // Fig. 5 / Theorem 1: rank-3 Gram matrix recovered in 3 columns.
        let ds = gauss_2d_plus_3d(60, 60, 5);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let (approx, trace) = Oasis::new(20, 1, 1e-8, 1)
            .sample_traced(&oracle)
            .unwrap();
        // terminates early at (or just past) rank 3
        assert!(approx.k() <= 4, "k = {}", approx.k());
        assert!(trace.order.len() == approx.k());
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "error {err}");
    }

    #[test]
    fn variants_select_identical_sequences() {
        let ds = two_moons(150, 0.05, 9);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let base = Oasis::new(40, 5, 1e-12, 33);
        let (_, ta) = base
            .clone()
            .with_variant(Variant::PaperR)
            .sample_traced(&oracle)
            .unwrap();
        let (_, tb) = base
            .with_variant(Variant::Incremental)
            .sample_traced(&oracle)
            .unwrap();
        assert_eq!(ta.order, tb.order);
    }

    #[test]
    fn winv_is_true_inverse_throughout() {
        // Lemma 1: selected columns stay independent, so the iterated W⁻¹
        // must equal the direct inverse of W = C(Λ,Λ).
        let ds = two_moons(100, 0.05, 2);
        let kern = Gaussian::new(0.5);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (approx, _) = Oasis::new(25, 4, 1e-12, 11).sample_traced(&oracle).unwrap();
        let w = approx.c.select_rows(&approx.indices);
        let prod = w.matmul(&approx.winv);
        let eye = Mat::eye(approx.k());
        assert!(
            prod.fro_dist(&eye) < 1e-6,
            "‖W·W⁻¹−I‖ = {}",
            prod.fro_dist(&eye)
        );
    }

    #[test]
    fn error_decreases_with_more_columns() {
        let ds = two_moons(200, 0.05, 4);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.05);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let mut prev = f64::INFINITY;
        for l in [5usize, 15, 40, 80] {
            let approx = Oasis::new(l, 3, 1e-14, 7).sample(&oracle).unwrap();
            let err = relative_frobenius_error(&oracle, &approx);
            assert!(err <= prev + 1e-9, "error went up: {prev} -> {err} at l={l}");
            prev = err;
        }
        assert!(prev < 0.05, "final error {prev}");
    }

    #[test]
    fn tolerance_stops_early() {
        // full-rank budget but exact matrix reachable at rank 3
        let ds = gauss_2d_plus_3d(40, 40, 6);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let approx = Oasis::new(80, 1, 1e-6, 3).sample(&oracle).unwrap();
        assert!(approx.k() < 10, "did not stop early: k={}", approx.k());
    }

    #[test]
    fn trace_is_consistent() {
        let ds = two_moons(80, 0.05, 5);
        let kern = Gaussian::new(0.7);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (approx, trace) = Oasis::new(20, 4, 1e-12, 13).sample_traced(&oracle).unwrap();
        assert_eq!(trace.order, approx.indices);
        assert_eq!(trace.cum_secs.len(), trace.order.len());
        // cumulative times are non-decreasing
        for w in trace.cum_secs.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // no duplicate selections
        let set: std::collections::HashSet<_> = trace.order.iter().collect();
        assert_eq!(set.len(), trace.order.len());
        // seed deltas are NaN, adaptive deltas are finite
        assert!(trace.deltas[0].is_nan());
        assert!(trace.deltas[4..].iter().all(|d| d.is_finite()));
    }

    #[test]
    fn degenerate_duplicate_points_terminate() {
        // identical points ⇒ rank-1 kernel; oASIS must stop at 1 column
        let ds = crate::data::Dataset::from_rows(vec![vec![1.0, 2.0]; 30]);
        let kern = Gaussian::new(1.0);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = Oasis::new(10, 1, 1e-10, 1).sample(&oracle).unwrap();
        assert_eq!(approx.k(), 1);
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-10);
    }

    #[test]
    fn session_is_idempotent_once_exhausted() {
        let ds = gauss_2d_plus_3d(30, 30, 2);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let mut s = Oasis::new(20, 1, 1e-8, 1).session(&oracle).unwrap();
        let reason = run_to_completion(&mut s, &StoppingRule::new()).unwrap();
        assert_eq!(reason, StopReason::ScoreBelowTol);
        let k = s.k();
        assert!(k <= 4, "rank-3 data, k = {k}");
        // stepping again changes nothing
        assert_eq!(
            s.step().unwrap(),
            StepOutcome::Exhausted(StopReason::ScoreBelowTol)
        );
        assert_eq!(s.k(), k);
    }

    #[test]
    fn snapshot_mid_run_does_not_disturb_selection() {
        let ds = two_moons(90, 0.05, 3);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (reference, _) = Oasis::new(20, 3, 1e-12, 5).sample_traced(&oracle).unwrap();
        let mut s = Oasis::new(20, 3, 1e-12, 5).session(&oracle).unwrap();
        let mut snaps = Vec::new();
        while s.k() < 20 {
            if s.k() % 5 == 0 {
                snaps.push(s.snapshot().unwrap());
            }
            match s.step().unwrap() {
                StepOutcome::Selected { .. } => {}
                StepOutcome::Exhausted(_) => break,
            }
        }
        let fin = Box::new(s).finish().unwrap();
        assert_eq!(fin.indices, reference.indices);
        assert_eq!(fin.c.data, reference.c.data);
        assert_eq!(fin.winv.data, reference.winv.data);
        // snapshots were consistent prefixes
        for snap in snaps {
            assert_eq!(snap.indices, reference.indices[..snap.k()]);
        }
    }

    /// Warm start (artifact resume): seeding from a stored prefix and
    /// replaying it reproduces the recording session's state bit for
    /// bit, so continued selection matches an uninterrupted run exactly
    /// — for both scoring variants.
    #[test]
    fn warm_started_session_is_bit_identical_to_prefix_resume() {
        let ds = two_moons(200, 0.05, 8);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        for variant in [Variant::Incremental, Variant::PaperR] {
            let sampler = Oasis::new(40, 5, 1e-12, 3).with_variant(variant);
            let (reference, ref_trace) = sampler.sample_traced(&oracle).unwrap();

            let mut prefix = sampler.session(&oracle).unwrap();
            run_to_completion(&mut prefix, &StoppingRule::budget(20)).unwrap();
            let stored: Vec<usize> = prefix.indices().to_vec();

            let mut warm =
                sampler.session_from_indices(&oracle, &stored).unwrap();
            assert_eq!(warm.k(), 20, "{variant:?}");
            assert_eq!(warm.indices(), &stored[..], "{variant:?}");
            run_to_completion(&mut warm, &StoppingRule::budget(40)).unwrap();
            let warmed = warm.snapshot().unwrap();
            assert_eq!(warmed.indices, ref_trace.order, "{variant:?}");
            assert_eq!(warmed.c.data, reference.c.data, "{variant:?}");
            assert_eq!(warmed.winv.data, reference.winv.data, "{variant:?}");
        }
        // malformed index sets error cleanly
        let sampler = Oasis::new(10, 2, 1e-12, 3);
        assert!(sampler.session_from_indices(&oracle, &[]).is_err());
        assert!(sampler.session_from_indices(&oracle, &[4, 4]).is_err());
        assert!(sampler.session_from_indices(&oracle, &[999]).is_err());
    }

    #[test]
    fn error_estimate_decreases_and_reaches_zero_scale() {
        let ds = two_moons(120, 0.05, 7);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let mut s = Oasis::new(60, 4, 1e-14, 9).session(&oracle).unwrap();
        let e0 = s.error_estimate().unwrap();
        assert!(e0 > 0.0 && e0 <= 1.5, "initial estimate {e0}");
        run_to_completion(&mut s, &StoppingRule::budget(60)).unwrap();
        let e1 = s.error_estimate().unwrap();
        assert!(e1 < e0, "estimate did not decrease: {e0} → {e1}");
    }
}
