//! oASIS — Accelerated Sequential Incoherence Selection (paper Alg. 1).
//!
//! Selects columns greedily by the Schur-complement criterion
//! `Δᵢ = dᵢ − bᵢᵀ W_k⁻¹ bᵢ` (the squared distance of xᵢ from the span of
//! the selected columns' factor), maintaining `W⁻¹` by the Eq. 5 block
//! inverse update. Two scoring variants are provided:
//!
//! * [`Variant::PaperR`] — the paper's formulation: maintain
//!   `R = W⁻¹Cᵀ` with the Eq. 6 rank-1 update and score with
//!   `Δ = d − colsum(C∘R)`. O(kn) per iteration, 2·ℓn state.
//! * [`Variant::Incremental`] — an algebraically identical optimization
//!   (EXPERIMENTS.md §Perf): after appending column i with Schur
//!   complement s⁻¹ and `diff = C q − c_new`, every candidate score
//!   updates in place as `Δᵢ ← Δᵢ − s·diffᵢ²`, so R need not be stored or
//!   updated at all. Same O(kn) asymptotic with roughly half the memory
//!   traffic; bit-equal selection sequences are enforced by tests.
//!
//! Both variants select identical column sequences (up to f64 rounding in
//! degenerate ties) and satisfy Lemma 1/Theorem 1: each selected column is
//! linearly independent of its predecessors while Δ > 0, and a rank-r
//! matrix is recovered exactly in r steps.

use super::{ColumnOracle, ColumnSampler, SelectionTrace, TracedSampler};
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::util::{parallel, rng::Pcg64, timing::Stopwatch};
use crate::Result;
use anyhow::{anyhow, bail};

/// Scoring strategy (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Paper-faithful: maintain R (Eq. 6) and recompute colsum(C∘R).
    PaperR,
    /// Optimized: maintain Δ incrementally, never store R.
    Incremental,
}

/// The oASIS sampler.
#[derive(Clone, Debug)]
pub struct Oasis {
    /// ℓ — maximum number of sampled columns.
    pub max_cols: usize,
    /// k₀ — number of random seed columns.
    pub init_cols: usize,
    /// ε — stop when max |Δ| falls below this.
    pub tol: f64,
    /// seed for the random initial columns.
    pub seed: u64,
    pub variant: Variant,
    /// worker threads for the O(kn) sweeps (defaults to the machine).
    pub threads: usize,
}

impl Oasis {
    pub fn new(max_cols: usize, init_cols: usize, tol: f64, seed: u64) -> Oasis {
        assert!(init_cols >= 1 && init_cols <= max_cols);
        Oasis {
            max_cols,
            init_cols,
            tol,
            seed,
            variant: Variant::Incremental,
            threads: parallel::default_threads(),
        }
    }

    pub fn with_variant(mut self, v: Variant) -> Oasis {
        self.variant = v;
        self
    }

    /// Run selection, returning the approximation and the per-step trace.
    pub fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let l = self.max_cols.min(n);
        if l == 0 {
            bail!("max_cols must be ≥ 1");
        }
        let k0 = self.init_cols.min(l);
        let d = oracle.diag();
        let tol = super::effective_tol(self.tol, &d);

        let mut state = State::new(n, l, self.threads);

        // --- seed: k₀ random columns (redrawn if W₀ is singular) ---
        let mut rng = Pcg64::new(self.seed);
        let mut lambda: Vec<usize>;
        let mut attempt = 0;
        loop {
            let cand = rng.sample_without_replacement(n, k0);
            if state.try_seed(oracle, &cand) {
                lambda = cand;
                break;
            }
            attempt += 1;
            if attempt >= 16 {
                return Err(anyhow!(
                    "oASIS: could not find {k0} linearly independent seed columns \
                     in 16 draws (matrix rank < k0?) — lower init_cols"
                ));
            }
        }
        let mut selected = vec![false; n];
        for &j in &lambda {
            selected[j] = true;
        }

        let mut trace = SelectionTrace::default();
        for &j in &lambda {
            trace.order.push(j);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(f64::NAN); // seed columns have no Δ
        }

        // --- initial Δ ---
        let mut delta = vec![0.0; n];
        match self.variant {
            Variant::PaperR => {
                state.build_r_from_scratch();
                state.colsum_delta(&d, &mut delta);
            }
            Variant::Incremental => state.seed_delta(&d, &mut delta),
        }

        // --- main loop ---
        while lambda.len() < l {
            let k = lambda.len();
            if self.variant == Variant::PaperR {
                state.colsum_delta(&d, &mut delta);
            }
            // argmax |Δ| over unselected
            let (best, best_abs) = argmax_abs(&delta, &selected);
            if best_abs < tol {
                break; // approximation is (near-)exact
            }
            let s = 1.0 / delta[best];
            // new column from the oracle
            let col = state.fetch_column(oracle, best);
            // q = W⁻¹ b where b = C(Λ, best) = row `best` of C
            let q = state.q_for(best, k);
            // diff = C q − c_new
            state.compute_diff(&q, &col, k);
            if self.variant == Variant::Incremental {
                state.update_delta_inc(&mut delta, s);
            }
            state.apply_update(&q, &col, s, k, self.variant);
            selected[best] = true;
            lambda.push(best);
            trace.order.push(best);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(best_abs);
        }

        let approx = state.into_approx(lambda, sw.secs());
        Ok((approx, trace))
    }
}

impl ColumnSampler for Oasis {
    fn name(&self) -> &'static str {
        "oASIS"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for Oasis {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        Oasis::sample_traced(self, oracle)
    }
}

/// Mutable algorithm state shared by the variants.
struct State {
    n: usize,
    l: usize,
    threads: usize,
    /// sampled columns, column-major: column t at `c[t*n .. (t+1)*n]`
    c: Vec<f64>,
    /// W⁻¹, row-major with stride l; live block k×k
    winv: Vec<f64>,
    /// R = W⁻¹Cᵀ, row-major with stride n; live rows 0..k (PaperR only,
    /// but allocated lazily on first use)
    r: Vec<f64>,
    r_allocated: bool,
    /// scratch: diff = C q − c_new
    diff: Vec<f64>,
    k: usize,
}

impl State {
    fn new(n: usize, l: usize, threads: usize) -> State {
        State {
            n,
            l,
            threads,
            c: Vec::with_capacity(l * n),
            winv: vec![0.0; l * l],
            r: Vec::new(),
            r_allocated: false,
            diff: vec![0.0; n],
            k: 0,
        }
    }

    fn ensure_r(&mut self) {
        if !self.r_allocated {
            self.r = vec![0.0; self.l * self.n];
            self.r_allocated = true;
        }
    }

    /// Try to seed with the candidate index set; false if W₀ is singular.
    fn try_seed(&mut self, oracle: &dyn ColumnOracle, cand: &[usize]) -> bool {
        let k0 = cand.len();
        let n = self.n;
        self.c.clear();
        self.c.resize(k0 * n, 0.0);
        for (t, &j) in cand.iter().enumerate() {
            oracle.column_into(j, &mut self.c[t * n..(t + 1) * n]);
        }
        // W₀ = C(Λ, :) — k0×k0
        let mut w = Mat::zeros(k0, k0);
        for (ti, &i) in cand.iter().enumerate() {
            for tj in 0..k0 {
                *w.at_mut(ti, tj) = self.c[tj * n + i];
            }
        }
        let inv = match crate::linalg::inverse(&w) {
            Some(inv) => inv,
            None => return false,
        };
        // reject near-singular seeds (would poison later updates)
        let cond_proxy = inv.max_abs() * w.max_abs();
        if !cond_proxy.is_finite() || cond_proxy > 1e12 {
            return false;
        }
        for i in 0..k0 {
            for j in 0..k0 {
                self.winv[i * self.l + j] = inv.at(i, j);
            }
        }
        self.k = k0;
        true
    }

    /// The paper's per-iteration scoring: Δ = d − colsum(C∘R), reading the
    /// maintained R (PaperR variant).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): the sweep streams row-pairs
    /// (t-outer) so both `c_t` and `r_t` are read sequentially; the naive
    /// i-outer loop strides by n per element and is several times slower
    /// at n=20k, k=256.
    fn colsum_delta(&self, d: &[f64], delta: &mut [f64]) {
        debug_assert!(self.r_allocated);
        let k = self.k;
        let n = self.n;
        let c = &self.c;
        let r = &self.r;
        parallel::for_each_chunk_mut(delta, 1, self.threads, |range, chunk| {
            let (lo, hi) = (range.start, range.end);
            // chunk = d[lo..hi] − Σ_t c_t[lo..hi] ∘ r_t[lo..hi]
            chunk.copy_from_slice(&d[lo..hi]);
            for t in 0..k {
                let ct = &c[t * n + lo..t * n + hi];
                let rt = &r[t * n + lo..t * n + hi];
                for ((o, &cv), &rv) in chunk.iter_mut().zip(ct).zip(rt) {
                    *o -= cv * rv;
                }
            }
        });
    }

    /// Seed-time Δ for the Incremental variant (which never stores R):
    /// Δᵢ = dᵢ − bᵢᵀ W⁻¹ bᵢ with bᵢ = C(i,:). O(k₀²·n).
    fn seed_delta(&self, d: &[f64], delta: &mut [f64]) {
        let k = self.k;
        let n = self.n;
        let l = self.l;
        let c = &self.c;
        let winv = &self.winv;
        parallel::for_each_chunk_mut(delta, 1, self.threads, |range, chunk| {
            let mut b = vec![0.0; k];
            for (local, i) in range.clone().enumerate() {
                for (t, bt) in b.iter_mut().enumerate() {
                    *bt = c[t * n + i];
                }
                let mut quad = 0.0;
                for t in 0..k {
                    let row = &winv[t * l..t * l + k];
                    quad += b[t] * crate::linalg::matrix::dot(row, &b);
                }
                chunk[local] = d[i] - quad;
            }
        });
    }

    /// Build R = W⁻¹Cᵀ from scratch (seed time, PaperR variant).
    fn build_r_from_scratch(&mut self) {
        self.ensure_r();
        let k = self.k;
        let n = self.n;
        let l = self.l;
        let winv = &self.winv;
        let c = &self.c;
        parallel::for_each_chunk_mut(
            &mut self.r[..k * n],
            n,
            self.threads,
            |range, chunk| {
                for (local, t) in range.clone().enumerate() {
                    let row = &mut chunk[local * n..(local + 1) * n];
                    row.fill(0.0);
                    for u in 0..k {
                        let w = winv[t * l + u];
                        if w == 0.0 {
                            continue;
                        }
                        let cu = &c[u * n..(u + 1) * n];
                        for (o, &cv) in row.iter_mut().zip(cu) {
                            *o += w * cv;
                        }
                    }
                }
            },
        );
    }

    fn fetch_column(&mut self, oracle: &dyn ColumnOracle, j: usize) -> Vec<f64> {
        let mut col = vec![0.0; self.n];
        oracle.column_into(j, &mut col);
        col
    }

    /// q = W⁻¹ b with b = C(best,:) over live columns.
    fn q_for(&self, best: usize, k: usize) -> Vec<f64> {
        let n = self.n;
        let l = self.l;
        let mut b = vec![0.0; k];
        for (t, bt) in b.iter_mut().enumerate() {
            *bt = self.c[t * n + best];
        }
        let mut q = vec![0.0; k];
        for t in 0..k {
            let row = &self.winv[t * l..t * l + k];
            q[t] = crate::linalg::matrix::dot(row, &b);
        }
        q
    }

    /// diff = C q − c_new (threaded O(kn) sweep, streaming t-outer).
    fn compute_diff(&mut self, q: &[f64], col: &[f64], k: usize) {
        let n = self.n;
        let c = &self.c;
        parallel::for_each_chunk_mut(&mut self.diff, 1, self.threads, |range, chunk| {
            let (lo, hi) = (range.start, range.end);
            for (o, &cv) in chunk.iter_mut().zip(&col[lo..hi]) {
                *o = -cv;
            }
            for (t, &qt) in q.iter().enumerate().take(k) {
                if qt == 0.0 {
                    continue;
                }
                let ct = &c[t * n + lo..t * n + hi];
                for (o, &cv) in chunk.iter_mut().zip(ct) {
                    *o += qt * cv;
                }
            }
        });
    }

    /// Incremental score update: Δᵢ ← Δᵢ − s·diffᵢ².
    fn update_delta_inc(&self, delta: &mut [f64], s: f64) {
        let diff = &self.diff;
        parallel::for_each_chunk_mut(delta, 1, self.threads, |range, chunk| {
            for (local, i) in range.clone().enumerate() {
                let dv = diff[i];
                chunk[local] -= s * dv * dv;
            }
        });
    }

    /// Apply Eq. 5 (W⁻¹) and, for PaperR, Eq. 6 (R); append the column.
    fn apply_update(&mut self, q: &[f64], col: &[f64], s: f64, k: usize, v: Variant) {
        let l = self.l;
        let n = self.n;
        // W⁻¹ ← [W⁻¹ + s qqᵀ, −sq; −sqᵀ, s]
        for i in 0..k {
            let qi = q[i];
            let row = &mut self.winv[i * l..i * l + k];
            for (j, w) in row.iter_mut().enumerate() {
                *w += s * qi * q[j];
            }
            self.winv[i * l + k] = -s * qi;
            self.winv[k * l + i] = -s * qi;
        }
        self.winv[k * l + k] = s;
        if v == Variant::PaperR {
            self.ensure_r();
            // R rows 0..k: R_t += s q_t diff ; new row k: −s diff
            let diff = &self.diff;
            let threads = self.threads;
            parallel::for_each_chunk_mut(
                &mut self.r[..k * n],
                n,
                threads,
                |range, chunk| {
                    for (local, t) in range.clone().enumerate() {
                        let qt = s * q[t];
                        if qt == 0.0 {
                            continue;
                        }
                        let row = &mut chunk[local * n..(local + 1) * n];
                        for (o, &dv) in row.iter_mut().zip(diff) {
                            *o += qt * dv;
                        }
                    }
                },
            );
            for i in 0..n {
                self.r[k * n + i] = -s * diff[i];
            }
        }
        self.c.extend_from_slice(col);
        self.k = k + 1;
    }

    fn into_approx(self, lambda: Vec<usize>, secs: f64) -> NystromApprox {
        let k = lambda.len();
        let n = self.n;
        // C: column-major buffer → row-major Mat
        let mut c = Mat::zeros(n, k);
        for t in 0..k {
            let src = &self.c[t * n..(t + 1) * n];
            for i in 0..n {
                c.data[i * k + t] = src[i];
            }
        }
        let mut winv = Mat::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                winv.data[i * k + j] = self.winv[i * self.l + j];
            }
        }
        NystromApprox { indices: lambda, c, winv, selection_secs: secs }
    }
}

/// argmax of |Δ| over unselected indices; returns (index, |Δ|).
fn argmax_abs(delta: &[f64], selected: &[bool]) -> (usize, f64) {
    let mut best = usize::MAX;
    let mut best_abs = -1.0;
    for (i, &d) in delta.iter().enumerate() {
        if selected[i] {
            continue;
        }
        let a = d.abs();
        if a > best_abs {
            best_abs = a;
            best = i;
        }
    }
    (best, best_abs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gauss_2d_plus_3d, two_moons};
    use crate::kernels::{kernel_matrix, Gaussian, Linear};
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::{ExplicitOracle, ImplicitOracle};

    #[test]
    fn exact_recovery_rank3_in_3_steps() {
        // Fig. 5 / Theorem 1: rank-3 Gram matrix recovered in 3 columns.
        let ds = gauss_2d_plus_3d(60, 60, 5);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let (approx, trace) = Oasis::new(20, 1, 1e-8, 1)
            .sample_traced(&oracle)
            .unwrap();
        // terminates early at (or just past) rank 3
        assert!(approx.k() <= 4, "k = {}", approx.k());
        assert!(trace.order.len() == approx.k());
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "error {err}");
    }

    #[test]
    fn variants_select_identical_sequences() {
        let ds = two_moons(150, 0.05, 9);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let base = Oasis::new(40, 5, 1e-12, 33);
        let (_, ta) = base
            .clone()
            .with_variant(Variant::PaperR)
            .sample_traced(&oracle)
            .unwrap();
        let (_, tb) = base
            .with_variant(Variant::Incremental)
            .sample_traced(&oracle)
            .unwrap();
        assert_eq!(ta.order, tb.order);
    }

    #[test]
    fn winv_is_true_inverse_throughout() {
        // Lemma 1: selected columns stay independent, so the iterated W⁻¹
        // must equal the direct inverse of W = C(Λ,Λ).
        let ds = two_moons(100, 0.05, 2);
        let kern = Gaussian::new(0.5);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (approx, _) = Oasis::new(25, 4, 1e-12, 11).sample_traced(&oracle).unwrap();
        let w = approx.c.select_rows(&approx.indices);
        let prod = w.matmul(&approx.winv);
        let eye = Mat::eye(approx.k());
        assert!(
            prod.fro_dist(&eye) < 1e-6,
            "‖W·W⁻¹−I‖ = {}",
            prod.fro_dist(&eye)
        );
    }

    #[test]
    fn error_decreases_with_more_columns() {
        let ds = two_moons(200, 0.05, 4);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.05);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let mut prev = f64::INFINITY;
        for l in [5usize, 15, 40, 80] {
            let approx = Oasis::new(l, 3, 1e-14, 7).sample(&oracle).unwrap();
            let err = relative_frobenius_error(&oracle, &approx);
            assert!(err <= prev + 1e-9, "error went up: {prev} -> {err} at l={l}");
            prev = err;
        }
        assert!(prev < 0.05, "final error {prev}");
    }

    #[test]
    fn tolerance_stops_early() {
        // full-rank budget but exact matrix reachable at rank 3
        let ds = gauss_2d_plus_3d(40, 40, 6);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let approx = Oasis::new(80, 1, 1e-6, 3).sample(&oracle).unwrap();
        assert!(approx.k() < 10, "did not stop early: k={}", approx.k());
    }

    #[test]
    fn trace_is_consistent() {
        let ds = two_moons(80, 0.05, 5);
        let kern = Gaussian::new(0.7);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (approx, trace) = Oasis::new(20, 4, 1e-12, 13).sample_traced(&oracle).unwrap();
        assert_eq!(trace.order, approx.indices);
        assert_eq!(trace.cum_secs.len(), trace.order.len());
        // cumulative times are non-decreasing
        for w in trace.cum_secs.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // no duplicate selections
        let set: std::collections::HashSet<_> = trace.order.iter().collect();
        assert_eq!(set.len(), trace.order.len());
        // seed deltas are NaN, adaptive deltas are finite & non-increasinging trend not guaranteed, just finite
        assert!(trace.deltas[0].is_nan());
        assert!(trace.deltas[4..].iter().all(|d| d.is_finite()));
    }

    #[test]
    fn degenerate_duplicate_points_terminate() {
        // identical points ⇒ rank-1 kernel; oASIS must stop at 1 column
        let ds = crate::data::Dataset::from_rows(vec![vec![1.0, 2.0]; 30]);
        let kern = Gaussian::new(1.0);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = Oasis::new(10, 1, 1e-10, 1).sample(&oracle).unwrap();
        assert_eq!(approx.k(), 1);
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-10);
    }
}
