//! Leverage-score sampling (Gittens & Mahoney [15], paper §II-D2).
//!
//! Columns are drawn with probability proportional to the squared row
//! norms of the top-k eigenvector matrix of G. Like the paper's setup this
//! requires the *full* explicit G (the reason the method is excluded from
//! the implicit/large classes). We compute the top-k subspace with
//! randomized subspace iteration (Halko et al. [38]) — the "fast
//! approximation" route the paper references — which costs O(n²(k+p))
//! instead of a full O(n³) eigendecomposition.

use super::{
    assemble_from_indices, ColumnOracle, ColumnSampler, SelectionTrace,
    TracedSampler,
};
use crate::linalg::{sym_eig, thin_qr, Mat};
use crate::nystrom::NystromApprox;
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::Result;
use crate::bail;

/// Leverage-score sampler over an explicit kernel matrix.
#[derive(Clone, Debug)]
pub struct LeverageScores {
    /// number of columns ℓ to draw.
    pub cols: usize,
    /// rank of the leverage subspace (defaults to `cols` like [15]).
    pub rank: usize,
    /// subspace-iteration oversampling and power passes.
    pub oversample: usize,
    pub power_iters: usize,
    pub seed: u64,
}

impl LeverageScores {
    pub fn new(cols: usize, rank: usize, seed: u64) -> LeverageScores {
        // one power pass suffices for the fast-decaying kernel spectra the
        // paper targets (Halko et al. [38]); each extra pass costs an
        // O(n²p) multiply plus a serial O(np²) QR — see §Perf
        LeverageScores { cols, rank, oversample: 8, power_iters: 1, seed }
    }

    /// The leverage scores sⱼ = ‖U_k(j,:)‖² (probability weights).
    pub fn scores(&self, g: &Mat) -> Vec<f64> {
        let n = g.rows;
        let k = self.rank.min(n);
        let p = (k + self.oversample).min(n);
        let mut rng = Pcg64::new(self.seed ^ 0x1e7e_7a6e);
        // randomized range finder: Y = G Ω
        let mut omega = Mat::zeros(n, p);
        rng.fill_normal(&mut omega.data);
        let mut y = g.matmul(&omega);
        let mut q = thin_qr(&y).0;
        for _ in 0..self.power_iters {
            y = g.matmul(&q);
            q = thin_qr(&y).0;
        }
        // small projected eig: B = Qᵀ G Q (p×p)
        let gq = g.matmul(&q);
        let b = q.t_matmul(&gq);
        let eig = sym_eig(&b);
        // top-k eigenvectors of G ≈ Q · V[:, :k]
        let vk = eig.vecs.select_cols(&(0..k).collect::<Vec<_>>());
        let u = q.matmul(&vk); // n×k
        (0..n)
            .map(|j| u.row(j).iter().map(|x| x * x).sum::<f64>())
            .collect()
    }
}

impl ColumnSampler for LeverageScores {
    fn name(&self) -> &'static str {
        "Leverage scores"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for LeverageScores {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        if self.cols > n {
            bail!("cols > n");
        }
        // materialize G (the method requires it — paper §II-D2)
        let mut g = Mat::zeros(n, n);
        {
            let mut col = vec![0.0; n];
            for j in 0..n {
                oracle.column_into(j, &mut col);
                for i in 0..n {
                    g.data[i * n + j] = col[i];
                }
            }
        }
        let mut weights = self.scores(&g);
        // draw ℓ distinct indices with probability ∝ score
        let mut rng = Pcg64::new(self.seed);
        let mut order = Vec::with_capacity(self.cols);
        for _ in 0..self.cols {
            let total: f64 = weights.iter().sum();
            let j = if total <= 0.0 {
                // all remaining scores zero — fall back to uniform
                loop {
                    let c = rng.below(n);
                    if weights[c] >= 0.0 {
                        break c;
                    }
                }
            } else {
                rng.weighted_index(&weights)
            };
            order.push(j);
            weights[j] = 0.0; // without replacement
        }
        let secs = sw.secs();
        let mut trace = SelectionTrace::default();
        for (i, &j) in order.iter().enumerate() {
            trace.order.push(j);
            trace.cum_secs.push(secs * (i + 1) as f64 / self.cols as f64);
            trace.deltas.push(f64::NAN);
        }
        let approx = assemble_from_indices(oracle, order, 0.0);
        let approx = NystromApprox { selection_secs: sw.secs(), ..approx };
        Ok((approx, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_clusters, two_moons};
    use crate::kernels::{kernel_matrix, Gaussian};
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::ExplicitOracle;

    #[test]
    fn scores_concentrate_on_informative_columns() {
        // rank-1 spike: one big outlier direction dominates the top
        // subspace, so its leverage must rank near the top.
        let ds = gaussian_clusters(60, 4, 3, 0.1, 5);
        let g = kernel_matrix(&ds, &Gaussian::new(2.0));
        let lev = LeverageScores::new(10, 10, 1);
        let scores = lev.scores(&g);
        assert_eq!(scores.len(), 60);
        assert!(scores.iter().all(|&s| s >= -1e-9));
        // scores sum ≈ rank (property of orthonormal U)
        let sum: f64 = scores.iter().sum();
        assert!((sum - 10.0).abs() < 0.5, "score mass {sum}");
    }

    #[test]
    fn sampling_improves_over_worst_case() {
        let ds = two_moons(120, 0.05, 7);
        let g = kernel_matrix(&ds, &Gaussian::with_sigma_fraction(&ds, 0.05));
        let oracle = ExplicitOracle::new(&g);
        let approx = LeverageScores::new(40, 40, 3).sample(&oracle).unwrap();
        assert_eq!(approx.k(), 40);
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 0.5, "err {err}");
    }

    #[test]
    fn without_replacement() {
        let ds = two_moons(50, 0.05, 8);
        let g = kernel_matrix(&ds, &Gaussian::new(0.5));
        let oracle = ExplicitOracle::new(&g);
        let approx = LeverageScores::new(25, 25, 4).sample(&oracle).unwrap();
        let set: std::collections::HashSet<_> = approx.indices.iter().collect();
        assert_eq!(set.len(), 25);
    }
}
