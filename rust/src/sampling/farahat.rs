//! Farahat's greedy residual method ([12], paper §II-D3).
//!
//! At each step selects the column with the largest residual contribution
//! `‖E(:,j)‖² / E(j,j)` and deflates `E ← E − E_j E_jᵀ / E(j,j)` — a greedy
//! pivoted rank-1 deflation (partial Cholesky with a norm-ratio pivot).
//! Accurate, but requires the precomputed n×n matrix and O(n²) work per
//! iteration — exactly the cost profile the paper contrasts oASIS against
//! (O(ℓn²) total vs oASIS's O(ℓ²n)).

use super::session::{
    run_to_completion, SamplerSession, StepOutcome, StopReason, StoppingRule,
};
use super::{
    assemble_from_indices, ColumnOracle, ColumnSampler, SelectionTrace,
    TracedSampler,
};
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::util::{parallel, timing::Stopwatch};
use crate::bail;
use crate::Result;

/// Farahat greedy residual sampler (explicit matrices only).
#[derive(Clone, Debug)]
pub struct Farahat {
    pub cols: usize,
    /// numerical floor for a usable pivot E(j,j).
    pub pivot_tol: f64,
}

impl Farahat {
    pub fn new(cols: usize) -> Farahat {
        Farahat { cols, pivot_tol: 1e-12 }
    }

    /// Open a stepwise session. Materializes the residual E = G with one
    /// batched oracle fill (the method's requirement); each step performs
    /// one greedy selection + rank-1 deflation.
    pub fn session<'a>(
        &self,
        oracle: &'a dyn ColumnOracle,
    ) -> Result<FarahatSession<'a>> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        if self.cols > n {
            bail!("cols > n");
        }
        // materialize the residual E = G via the batched column API
        let mut e = Mat::zeros(n, n);
        let all: Vec<usize> = (0..n).collect();
        oracle.columns_into(&all, &mut e);
        let threads = parallel::default_threads();
        let g_fro = super::fro_norm(&e, threads);
        Ok(FarahatSession {
            oracle,
            n,
            threads,
            pivot_tol: self.pivot_tol,
            e,
            g_fro,
            selected: vec![false; n],
            trace: SelectionTrace::default(),
            exhausted: None,
            busy_secs: sw.secs(),
        })
    }
}

impl ColumnSampler for Farahat {
    fn name(&self) -> &'static str {
        "Farahat"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for Farahat {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let mut session = self.session(oracle)?;
        run_to_completion(&mut session, &StoppingRule::budget(self.cols))?;
        let trace = session.trace().clone();
        let approx = session.snapshot()?;
        Ok((approx, trace))
    }
}

/// A paused Farahat run (see [`Farahat::session`]).
pub struct FarahatSession<'a> {
    oracle: &'a dyn ColumnOracle,
    n: usize,
    threads: usize,
    pivot_tol: f64,
    /// current residual E = G − G̃_k.
    e: Mat,
    /// ‖G‖_F at materialization (error-estimate denominator).
    g_fro: f64,
    selected: Vec<bool>,
    trace: SelectionTrace,
    exhausted: Option<StopReason>,
    busy_secs: f64,
}

impl SamplerSession for FarahatSession<'_> {
    fn name(&self) -> &'static str {
        "Farahat"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn indices(&self) -> &[usize] {
        &self.trace.order
    }

    fn trace(&self) -> &SelectionTrace {
        &self.trace
    }

    fn selection_secs(&self) -> f64 {
        self.busy_secs
    }

    /// **Exact** current relative error `‖E‖_F / ‖G‖_F` — the deflation
    /// methods hold the residual explicitly, so no estimation is needed.
    /// Costs one O(n²) pass, the same order as a single step.
    fn error_estimate(&self) -> Option<f64> {
        if self.g_fro <= 0.0 {
            return Some(0.0);
        }
        Some(super::fro_norm(&self.e, self.threads) / self.g_fro)
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.exhausted {
            return Ok(StepOutcome::Exhausted(reason));
        }
        let sw = Stopwatch::start();
        let n = self.n;
        let threads = self.threads;
        let e = &mut self.e;
        // criterion: ‖E(:,j)‖² / E(j,j) over unselected columns.
        // Row-streaming accumulation (each thread sums the squares of
        // its row block into a local n-vector) — the column-wise loop
        // strides by n and is several times slower (§Perf).
        let colnorms: Vec<f64> = {
            let parts = parallel::map_ranges(n, threads, |range| {
                let mut acc = vec![0.0f64; n];
                for i in range {
                    let row = &e.data[i * n..(i + 1) * n];
                    for (a, &v) in acc.iter_mut().zip(row) {
                        *a += v * v;
                    }
                }
                acc
            });
            let mut total = vec![0.0f64; n];
            for p in parts {
                for (t, v) in total.iter_mut().zip(p) {
                    *t += v;
                }
            }
            total
        };
        let mut best = usize::MAX;
        let mut best_score = -1.0;
        for j in 0..n {
            if self.selected[j] {
                continue;
            }
            let piv = e.at(j, j);
            if piv <= self.pivot_tol {
                continue;
            }
            let score = colnorms[j] / piv;
            if score > best_score {
                best_score = score;
                best = j;
            }
        }
        if best == usize::MAX {
            // residual exhausted — approximation exact
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        // deflate: E ← E − E_j E_jᵀ / E(j,j)
        let piv = e.at(best, best);
        let ej: Vec<f64> = (0..n).map(|i| e.at(i, best)).collect();
        let inv_piv = 1.0 / piv;
        parallel::for_each_chunk_mut(&mut e.data, n, threads, |range, chunk| {
            for (local, i) in range.clone().enumerate() {
                let f = ej[i] * inv_piv;
                if f == 0.0 {
                    continue;
                }
                let row = &mut chunk[local * n..(local + 1) * n];
                for (o, &v) in row.iter_mut().zip(&ej) {
                    *o -= f * v;
                }
            }
        });
        self.selected[best] = true;
        self.trace.order.push(best);
        self.trace.cum_secs.push(self.busy_secs + sw.secs());
        self.trace.deltas.push(best_score);
        self.busy_secs += sw.secs();
        Ok(StepOutcome::Selected { index: best, score: best_score })
    }

    fn snapshot(&self) -> Result<NystromApprox> {
        Ok(assemble_from_indices(
            self.oracle,
            self.trace.order.clone(),
            self.busy_secs,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gauss_2d_plus_3d, two_moons};
    use crate::kernels::{kernel_matrix, Gaussian, Linear};
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::{ExplicitOracle, ImplicitOracle, StoppingCriterion};

    #[test]
    fn exact_recovery_on_low_rank() {
        let ds = gauss_2d_plus_3d(30, 30, 1);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        // ask for more columns than the rank — must stop at rank
        let approx = Farahat::new(10).sample(&oracle).unwrap();
        assert!(approx.k() <= 4, "k = {}", approx.k());
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "err {err}");
    }

    #[test]
    fn accuracy_beats_uniform_on_clustered_data() {
        let ds = two_moons(120, 0.05, 3);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.05);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let far = Farahat::new(30).sample(&oracle).unwrap();
        let uni = crate::sampling::uniform::Uniform::new(30, 1)
            .sample(&oracle)
            .unwrap();
        let err_f = relative_frobenius_error(&oracle, &far);
        let err_u = relative_frobenius_error(&oracle, &uni);
        assert!(err_f < err_u, "farahat {err_f} vs uniform {err_u}");
    }

    #[test]
    fn selections_distinct_and_traced() {
        let ds = two_moons(60, 0.05, 4);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (approx, trace) = Farahat::new(15).sample_traced(&oracle).unwrap();
        let set: std::collections::HashSet<_> = approx.indices.iter().collect();
        assert_eq!(set.len(), approx.k());
        assert_eq!(trace.order, approx.indices);
        // greedy scores are positive
        assert!(trace.deltas.iter().all(|&d| d > 0.0));
    }

    /// The exact error estimate tracks the true relative Frobenius error
    /// and drives the error-target criterion.
    #[test]
    fn farahat_error_estimate_is_exact() {
        let ds = two_moons(80, 0.05, 6);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let mut s = Farahat::new(40).session(&oracle).unwrap();
        let rule = StoppingRule::budget(40)
            .with(StoppingCriterion::ErrorBelow(0.2));
        let reason = run_to_completion(&mut s, &rule).unwrap();
        assert_eq!(reason, StopReason::ErrorTargetMet);
        assert!(s.k() < 40, "stopped early at k = {}", s.k());
        let approx = s.snapshot().unwrap();
        let true_err = relative_frobenius_error(&oracle, &approx);
        let est = s.error_estimate().unwrap();
        assert!(
            (true_err - est).abs() < 0.05 * est.max(1e-6) + 1e-9,
            "estimate {est} vs true {true_err}"
        );
    }
}
