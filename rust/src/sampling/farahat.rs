//! Farahat's greedy residual method ([12], paper §II-D3).
//!
//! At each step selects the column with the largest residual contribution
//! `‖E(:,j)‖² / E(j,j)` and deflates `E ← E − E_j E_jᵀ / E(j,j)` — a greedy
//! pivoted rank-1 deflation (partial Cholesky with a norm-ratio pivot).
//! Accurate, but requires the precomputed n×n matrix and O(n²) work per
//! iteration — exactly the cost profile the paper contrasts oASIS against
//! (O(ℓn²) total vs oASIS's O(ℓ²n)).

use super::{
    assemble_from_indices, ColumnOracle, ColumnSampler, SelectionTrace,
    TracedSampler,
};
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::util::{parallel, timing::Stopwatch};
use crate::Result;
use anyhow::bail;

/// Farahat greedy residual sampler (explicit matrices only).
#[derive(Clone, Debug)]
pub struct Farahat {
    pub cols: usize,
    /// numerical floor for a usable pivot E(j,j).
    pub pivot_tol: f64,
}

impl Farahat {
    pub fn new(cols: usize) -> Farahat {
        Farahat { cols, pivot_tol: 1e-12 }
    }
}

impl ColumnSampler for Farahat {
    fn name(&self) -> &'static str {
        "Farahat"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for Farahat {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        if self.cols > n {
            bail!("cols > n");
        }
        // materialize the residual E = G (the method's requirement)
        let mut e = Mat::zeros(n, n);
        {
            let mut col = vec![0.0; n];
            for j in 0..n {
                oracle.column_into(j, &mut col);
                for i in 0..n {
                    e.data[i * n + j] = col[i];
                }
            }
        }
        let threads = parallel::default_threads();
        let mut selected = vec![false; n];
        let mut order = Vec::with_capacity(self.cols);
        let mut trace = SelectionTrace::default();
        for _step in 0..self.cols {
            // criterion: ‖E(:,j)‖² / E(j,j) over unselected columns.
            // Row-streaming accumulation (each thread sums the squares of
            // its row block into a local n-vector) — the column-wise loop
            // strides by n and is several times slower (§Perf).
            let colnorms: Vec<f64> = {
                let parts = parallel::map_ranges(n, threads, |range| {
                    let mut acc = vec![0.0f64; n];
                    for i in range {
                        let row = &e.data[i * n..(i + 1) * n];
                        for (a, &v) in acc.iter_mut().zip(row) {
                            *a += v * v;
                        }
                    }
                    acc
                });
                let mut total = vec![0.0f64; n];
                for p in parts {
                    for (t, v) in total.iter_mut().zip(p) {
                        *t += v;
                    }
                }
                total
            };
            let mut best = usize::MAX;
            let mut best_score = -1.0;
            for j in 0..n {
                if selected[j] {
                    continue;
                }
                let piv = e.at(j, j);
                if piv <= self.pivot_tol {
                    continue;
                }
                let score = colnorms[j] / piv;
                if score > best_score {
                    best_score = score;
                    best = j;
                }
            }
            if best == usize::MAX {
                break; // residual exhausted — approximation exact
            }
            // deflate: E ← E − E_j E_jᵀ / E(j,j)
            let piv = e.at(best, best);
            let ej: Vec<f64> = (0..n).map(|i| e.at(i, best)).collect();
            let inv_piv = 1.0 / piv;
            parallel::for_each_chunk_mut(&mut e.data, n, threads, |range, chunk| {
                for (local, i) in range.clone().enumerate() {
                    let f = ej[i] * inv_piv;
                    if f == 0.0 {
                        continue;
                    }
                    let row = &mut chunk[local * n..(local + 1) * n];
                    for (o, &v) in row.iter_mut().zip(&ej) {
                        *o -= f * v;
                    }
                }
            });
            selected[best] = true;
            order.push(best);
            trace.order.push(best);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(best_score);
        }
        let approx = assemble_from_indices(oracle, order, 0.0);
        let approx = NystromApprox { selection_secs: sw.secs(), ..approx };
        Ok((approx, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gauss_2d_plus_3d, two_moons};
    use crate::kernels::{kernel_matrix, Gaussian, Linear};
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::{ExplicitOracle, ImplicitOracle};

    #[test]
    fn exact_recovery_on_low_rank() {
        let ds = gauss_2d_plus_3d(30, 30, 1);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        // ask for more columns than the rank — must stop at rank
        let approx = Farahat::new(10).sample(&oracle).unwrap();
        assert!(approx.k() <= 4, "k = {}", approx.k());
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "err {err}");
    }

    #[test]
    fn accuracy_beats_uniform_on_clustered_data() {
        let ds = two_moons(120, 0.05, 3);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.05);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let far = Farahat::new(30).sample(&oracle).unwrap();
        let uni = crate::sampling::uniform::Uniform::new(30, 1)
            .sample(&oracle)
            .unwrap();
        let err_f = relative_frobenius_error(&oracle, &far);
        let err_u = relative_frobenius_error(&oracle, &uni);
        assert!(err_f < err_u, "farahat {err_f} vs uniform {err_u}");
    }

    #[test]
    fn selections_distinct_and_traced() {
        let ds = two_moons(60, 0.05, 4);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (approx, trace) = Farahat::new(15).sample_traced(&oracle).unwrap();
        let set: std::collections::HashSet<_> = approx.indices.iter().collect();
        assert_eq!(set.len(), approx.k());
        assert_eq!(trace.order, approx.indices);
        // greedy scores are positive
        assert!(trace.deltas.iter().all(|&d| d > 0.0));
    }
}
