//! Deshpande-style adaptive random sampling ([11], §II-D3): columns are
//! drawn with probability proportional to the squared norms of the current
//! *residual* columns, in rounds; the residual is deflated after each
//! round. This is the stochastic counterpart of Farahat's deterministic
//! greedy rule and, like it, requires the explicit matrix.

use super::session::{
    run_to_completion, SamplerSession, StepOutcome, StopReason, StoppingRule,
};
use super::{
    assemble_from_indices, ColumnOracle, ColumnSampler, SelectionTrace,
    TracedSampler,
};
use crate::linalg::{pinv_psd, Mat};
use crate::nystrom::NystromApprox;
use crate::util::{parallel, rng::Pcg64, timing::Stopwatch};
use crate::bail;
use crate::Result;

/// Adaptive (residual-norm-weighted) random sampler.
#[derive(Clone, Debug)]
pub struct AdaptiveRandom {
    pub cols: usize,
    /// columns drawn per round before the residual is re-deflated.
    pub batch: usize,
    pub seed: u64,
}

impl AdaptiveRandom {
    pub fn new(cols: usize, batch: usize, seed: u64) -> Self {
        assert!(batch >= 1);
        AdaptiveRandom { cols, batch, seed }
    }

    /// Open a stepwise session: one weighted draw per step, deflating the
    /// residual every `batch` draws. Driving it with a budget of ℓ yields
    /// the same draw sequence as the one-shot path with `cols = ℓ` (the
    /// RNG stream and deflation schedule are identical).
    pub fn session<'a>(
        &self,
        oracle: &'a dyn ColumnOracle,
    ) -> Result<AdaptiveRandomSession<'a>> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        if self.cols > n {
            bail!("cols > n");
        }
        let threads = parallel::default_threads();
        // materialize G into the residual via the batched column API
        let mut e = Mat::zeros(n, n);
        let all: Vec<usize> = (0..n).collect();
        oracle.columns_into(&all, &mut e);
        let g_fro = super::fro_norm(&e, threads);
        Ok(AdaptiveRandomSession {
            oracle,
            n,
            threads,
            batch: self.batch,
            rng: Pcg64::new(self.seed),
            e,
            g_fro,
            e_fro_cache: std::cell::Cell::new(Some(g_fro)),
            weights: Vec::new(),
            weights_stale: true,
            round: Vec::new(),
            selected: vec![false; n],
            trace: SelectionTrace::default(),
            exhausted: None,
            busy_secs: sw.secs(),
        })
    }
}

impl ColumnSampler for AdaptiveRandom {
    fn name(&self) -> &'static str {
        "Adaptive random"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for AdaptiveRandom {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let mut session = self.session(oracle)?;
        run_to_completion(&mut session, &StoppingRule::budget(self.cols))?;
        let trace = session.trace().clone();
        let approx = session.snapshot()?;
        Ok((approx, trace))
    }
}

/// A paused adaptive-random run (see [`AdaptiveRandom::session`]).
pub struct AdaptiveRandomSession<'a> {
    oracle: &'a dyn ColumnOracle,
    n: usize,
    threads: usize,
    batch: usize,
    rng: Pcg64,
    /// residual E, deflated once per completed round.
    e: Mat,
    g_fro: f64,
    /// cached ‖E‖_F — E only changes at deflation, so the estimate is
    /// recomputed at most once per round (invalidated in `deflate_round`).
    e_fro_cache: std::cell::Cell<Option<f64>>,
    /// residual column norms; zeroed as columns are drawn within a round.
    weights: Vec<f64>,
    weights_stale: bool,
    /// columns drawn in the current (incomplete) round.
    round: Vec<usize>,
    selected: Vec<bool>,
    trace: SelectionTrace,
    exhausted: Option<StopReason>,
    busy_secs: f64,
}

impl AdaptiveRandomSession<'_> {
    /// Recompute residual column norms (row-streaming accumulation).
    fn recompute_weights(&mut self) {
        let n = self.n;
        let e = &self.e;
        let parts = parallel::map_ranges(n, self.threads, |range| {
            let mut acc = vec![0.0f64; n];
            for i in range {
                let row = &e.data[i * n..(i + 1) * n];
                for (a, &v) in acc.iter_mut().zip(row) {
                    *a += v * v;
                }
            }
            acc
        });
        let mut total = vec![0.0f64; n];
        for p in parts {
            for (t, v) in total.iter_mut().zip(p) {
                *t += v;
            }
        }
        for (j, w) in total.iter_mut().enumerate() {
            if self.selected[j] {
                *w = 0.0;
            }
        }
        self.weights = total;
        self.weights_stale = false;
    }

    /// Deflate the residual by the span of the current round's columns:
    /// `E ← E − E_B (E_BB)⁺ E_Bᵀ` (orthogonal projection step).
    fn deflate_round(&mut self) {
        let n = self.n;
        let batch = std::mem::take(&mut self.round);
        if batch.is_empty() {
            return;
        }
        let eb = self.e.select_cols(&batch); // n×b
        let ebb = eb.select_rows(&batch); // b×b
        let pinv = pinv_psd(&ebb, 1e-10);
        let proj = eb.matmul(&pinv); // n×b
        // E −= proj · ebᵀ (threaded over rows)
        let b = batch.len();
        parallel::for_each_chunk_mut(&mut self.e.data, n, self.threads, |range, chunk| {
            for (local, i) in range.clone().enumerate() {
                let row = &mut chunk[local * n..(local + 1) * n];
                for t in 0..b {
                    let f = proj.at(i, t);
                    if f == 0.0 {
                        continue;
                    }
                    // ebᵀ row t = eb column t
                    for (j, o) in row.iter_mut().enumerate() {
                        *o -= f * eb.at(j, t);
                    }
                }
            }
        });
        self.weights_stale = true;
        self.e_fro_cache.set(None);
    }
}

impl SamplerSession for AdaptiveRandomSession<'_> {
    fn name(&self) -> &'static str {
        "Adaptive random"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn indices(&self) -> &[usize] {
        &self.trace.order
    }

    fn trace(&self) -> &SelectionTrace {
        &self.trace
    }

    fn selection_secs(&self) -> f64 {
        self.busy_secs
    }

    /// `‖E‖_F / ‖G‖_F` for the residual as of the last completed round
    /// (columns drawn in the current round deflate only at the round
    /// boundary, so the estimate is conservative mid-round).
    fn error_estimate(&self) -> Option<f64> {
        if self.g_fro <= 0.0 {
            return Some(0.0);
        }
        let e_fro = match self.e_fro_cache.get() {
            Some(v) => v,
            None => {
                let v = super::fro_norm(&self.e, self.threads);
                self.e_fro_cache.set(Some(v));
                v
            }
        };
        Some(e_fro / self.g_fro)
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.exhausted {
            return Ok(StepOutcome::Exhausted(reason));
        }
        let sw = Stopwatch::start();
        if self.round.len() == self.batch {
            self.deflate_round();
        }
        if self.weights_stale {
            self.recompute_weights();
        }
        let total: f64 = self.weights.iter().sum();
        if total <= 1e-300 {
            // residual exhausted
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        let j = self.rng.weighted_index(&self.weights);
        self.weights[j] = 0.0;
        self.selected[j] = true;
        self.round.push(j);
        self.trace.order.push(j);
        self.trace.cum_secs.push(self.busy_secs + sw.secs());
        self.trace.deltas.push(f64::NAN);
        self.busy_secs += sw.secs();
        Ok(StepOutcome::Selected { index: j, score: f64::NAN })
    }

    fn snapshot(&self) -> Result<NystromApprox> {
        Ok(assemble_from_indices(
            self.oracle,
            self.trace.order.clone(),
            self.busy_secs,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::{uniform::Uniform, ImplicitOracle};

    #[test]
    fn beats_uniform_on_clustered_data() {
        let ds = two_moons(150, 0.05, 13);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.08);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let l = 30;
        let mut e_ad = 0.0;
        let mut e_un = 0.0;
        for s in 0..3 {
            e_ad += relative_frobenius_error(
                &oracle,
                &AdaptiveRandom::new(l, 5, 40 + s).sample(&oracle).unwrap(),
            );
            e_un += relative_frobenius_error(
                &oracle,
                &Uniform::new(l, 40 + s).sample(&oracle).unwrap(),
            );
        }
        assert!(e_ad < e_un, "adaptive {e_ad} !< uniform {e_un}");
    }

    #[test]
    fn draws_distinct_indices() {
        let ds = two_moons(60, 0.05, 2);
        let kern = Gaussian::new(0.5);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = AdaptiveRandom::new(25, 4, 7).sample(&oracle).unwrap();
        let set: std::collections::HashSet<_> = approx.indices.iter().collect();
        assert_eq!(set.len(), approx.k());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = two_moons(50, 0.05, 3);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let a = AdaptiveRandom::new(12, 3, 11).sample(&oracle).unwrap();
        let b = AdaptiveRandom::new(12, 3, 11).sample(&oracle).unwrap();
        assert_eq!(a.indices, b.indices);
    }

    /// A session driven one step at a time (budget checked externally)
    /// draws exactly the same columns as the one-shot path, regardless of
    /// whether the budget is a multiple of the deflation batch.
    #[test]
    fn session_draws_match_sample_for_ragged_budget() {
        let ds = two_moons(70, 0.05, 9);
        let kern = Gaussian::new(0.5);
        let oracle = ImplicitOracle::new(&ds, &kern);
        for cols in [10usize, 12, 15] {
            let reference = AdaptiveRandom::new(cols, 4, 21).sample(&oracle).unwrap();
            let mut s = AdaptiveRandom::new(cols, 4, 21).session(&oracle).unwrap();
            while s.k() < cols {
                match s.step().unwrap() {
                    StepOutcome::Selected { .. } => {}
                    StepOutcome::Exhausted(_) => break,
                }
            }
            assert_eq!(s.indices(), &reference.indices[..], "cols = {cols}");
        }
    }
}
