//! Deshpande-style adaptive random sampling ([11], §II-D3): columns are
//! drawn with probability proportional to the squared norms of the current
//! *residual* columns, in rounds; the residual is deflated after each
//! round. This is the stochastic counterpart of Farahat's deterministic
//! greedy rule and, like it, requires the explicit matrix.

use super::{
    assemble_from_indices, ColumnOracle, ColumnSampler, SelectionTrace,
    TracedSampler,
};
use crate::linalg::{pinv_psd, Mat};
use crate::nystrom::NystromApprox;
use crate::util::{parallel, rng::Pcg64, timing::Stopwatch};
use crate::Result;
use anyhow::bail;

/// Adaptive (residual-norm-weighted) random sampler.
#[derive(Clone, Debug)]
pub struct AdaptiveRandom {
    pub cols: usize,
    /// columns drawn per round before the residual is re-deflated.
    pub batch: usize,
    pub seed: u64,
}

impl AdaptiveRandom {
    pub fn new(cols: usize, batch: usize, seed: u64) -> Self {
        assert!(batch >= 1);
        AdaptiveRandom { cols, batch, seed }
    }
}

impl ColumnSampler for AdaptiveRandom {
    fn name(&self) -> &'static str {
        "Adaptive random"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for AdaptiveRandom {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        if self.cols > n {
            bail!("cols > n");
        }
        let threads = parallel::default_threads();
        // materialize G into the residual
        let mut e = Mat::zeros(n, n);
        {
            let mut col = vec![0.0; n];
            for j in 0..n {
                oracle.column_into(j, &mut col);
                for i in 0..n {
                    e.data[i * n + j] = col[i];
                }
            }
        }
        let mut rng = Pcg64::new(self.seed);
        let mut selected = vec![false; n];
        let mut order = Vec::with_capacity(self.cols);
        let mut trace = SelectionTrace::default();
        while order.len() < self.cols {
            // residual column norms (row-streaming accumulation)
            let mut weights = {
                let parts = parallel::map_ranges(n, threads, |range| {
                    let mut acc = vec![0.0f64; n];
                    for i in range {
                        let row = &e.data[i * n..(i + 1) * n];
                        for (a, &v) in acc.iter_mut().zip(row) {
                            *a += v * v;
                        }
                    }
                    acc
                });
                let mut total = vec![0.0f64; n];
                for p in parts {
                    for (t, v) in total.iter_mut().zip(p) {
                        *t += v;
                    }
                }
                total
            };
            for (j, w) in weights.iter_mut().enumerate() {
                if selected[j] {
                    *w = 0.0;
                }
            }
            if weights.iter().sum::<f64>() <= 1e-300 {
                break; // residual exhausted
            }
            // draw a batch without replacement by the weighted distribution
            let mut batch = Vec::new();
            for _ in 0..self.batch.min(self.cols - order.len()) {
                let total: f64 = weights.iter().sum();
                if total <= 1e-300 {
                    break;
                }
                let j = rng.weighted_index(&weights);
                weights[j] = 0.0;
                selected[j] = true;
                batch.push(j);
                order.push(j);
                trace.order.push(j);
                trace.cum_secs.push(sw.secs());
                trace.deltas.push(f64::NAN);
            }
            // deflate the residual by the span of the batch columns:
            // E ← E − E_B (E_BB)⁺ E_Bᵀ   (orthogonal projection step)
            let eb = e.select_cols(&batch); // n×b
            let ebb = eb.select_rows(&batch); // b×b
            let pinv = pinv_psd(&ebb, 1e-10);
            let proj = eb.matmul(&pinv); // n×b
            // E −= proj · ebᵀ (threaded over rows)
            let b = batch.len();
            parallel::for_each_chunk_mut(&mut e.data, n, threads, |range, chunk| {
                for (local, i) in range.clone().enumerate() {
                    let row = &mut chunk[local * n..(local + 1) * n];
                    for t in 0..b {
                        let f = proj.at(i, t);
                        if f == 0.0 {
                            continue;
                        }
                        // ebᵀ row t = eb column t
                        for (j, o) in row.iter_mut().enumerate() {
                            *o -= f * eb.at(j, t);
                        }
                    }
                }
            });
        }
        let approx = assemble_from_indices(oracle, order, 0.0);
        let approx = NystromApprox { selection_secs: sw.secs(), ..approx };
        Ok((approx, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::{uniform::Uniform, ImplicitOracle};

    #[test]
    fn beats_uniform_on_clustered_data() {
        let ds = two_moons(150, 0.05, 13);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.08);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let l = 30;
        let mut e_ad = 0.0;
        let mut e_un = 0.0;
        for s in 0..3 {
            e_ad += relative_frobenius_error(
                &oracle,
                &AdaptiveRandom::new(l, 5, 40 + s).sample(&oracle).unwrap(),
            );
            e_un += relative_frobenius_error(
                &oracle,
                &Uniform::new(l, 40 + s).sample(&oracle).unwrap(),
            );
        }
        assert!(e_ad < e_un, "adaptive {e_ad} !< uniform {e_un}");
    }

    #[test]
    fn draws_distinct_indices() {
        let ds = two_moons(60, 0.05, 2);
        let kern = Gaussian::new(0.5);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = AdaptiveRandom::new(25, 4, 7).sample(&oracle).unwrap();
        let set: std::collections::HashSet<_> = approx.indices.iter().collect();
        assert_eq!(set.len(), approx.k());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = two_moons(50, 0.05, 3);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let a = AdaptiveRandom::new(12, 3, 11).sample(&oracle).unwrap();
        let b = AdaptiveRandom::new(12, 3, 11).sample(&oracle).unwrap();
        assert_eq!(a.indices, b.indices);
    }
}
