//! Incomplete Cholesky Decomposition with greedy diagonal pivoting
//! (Fine & Scheinberg [27], referenced in §II-D4 via the Kumar survey).
//!
//! Partial Cholesky selects the pivot with the largest *residual diagonal*
//! — and the residual diagonal after k pivots is exactly the oASIS Schur
//! complement `Δᵢ = dᵢ − bᵢᵀW⁻¹bᵢ`. ICD is therefore an independent
//! O(kn)-per-step implementation of the same selection rule through
//! triangular factors instead of the Eq. 5/6 inverse updates; the
//! cross-validation test below asserts the selection sequences coincide,
//! which checks both implementations' numerics against each other.

use super::{
    assemble_from_indices, ColumnOracle, ColumnSampler, SelectionTrace,
    TracedSampler,
};
use crate::nystrom::NystromApprox;
use crate::util::timing::Stopwatch;
use crate::Result;

/// Greedy-pivot incomplete Cholesky sampler.
#[derive(Clone, Debug)]
pub struct IncompleteCholesky {
    pub max_cols: usize,
    /// stop when the largest residual diagonal falls below this.
    pub tol: f64,
}

impl IncompleteCholesky {
    pub fn new(max_cols: usize, tol: f64) -> Self {
        IncompleteCholesky { max_cols, tol }
    }
}

impl ColumnSampler for IncompleteCholesky {
    fn name(&self) -> &'static str {
        "ICD"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for IncompleteCholesky {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let l = self.max_cols.min(n);
        let d = oracle.diag();
        let tol = super::effective_tol(self.tol, &d);
        // residual diagonal, updated as pivots are added
        let mut resid = d.clone();
        // Cholesky columns: column t (length n) at ell[t*n..]
        let mut ell: Vec<f64> = Vec::with_capacity(l * n);
        let mut order = Vec::with_capacity(l);
        let mut selected = vec![false; n];
        let mut trace = SelectionTrace::default();
        let mut col = vec![0.0; n];
        for _step in 0..l {
            // pivot: largest residual diagonal among unselected
            let mut best = usize::MAX;
            let mut best_val = -1.0;
            for i in 0..n {
                if !selected[i] && resid[i] > best_val {
                    best_val = resid[i];
                    best = i;
                }
            }
            if best == usize::MAX || best_val < tol {
                break;
            }
            let k = order.len();
            oracle.column_into(best, &mut col);
            // new Cholesky column:
            //   v = (g_best − Σ_t ℓ_t ℓ_t[best]) / sqrt(resid[best])
            let piv_sqrt = best_val.sqrt();
            let start = ell.len();
            ell.extend_from_slice(&col);
            {
                let (prev, new) = ell.split_at_mut(start);
                for t in 0..k {
                    let f = prev[t * n + best];
                    if f == 0.0 {
                        continue;
                    }
                    let lt = &prev[t * n..(t + 1) * n];
                    for (o, &lv) in new.iter_mut().zip(lt) {
                        *o -= f * lv;
                    }
                }
                for o in new.iter_mut() {
                    *o /= piv_sqrt;
                }
            }
            // update residual diagonal: resid_i −= ℓ_k[i]²
            {
                let lk = &ell[start..start + n];
                for (r, &lv) in resid.iter_mut().zip(lk) {
                    *r -= lv * lv;
                }
            }
            selected[best] = true;
            order.push(best);
            trace.order.push(best);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(best_val);
        }
        let approx = assemble_from_indices(oracle, order, 0.0);
        let approx = NystromApprox { selection_secs: sw.secs(), ..approx };
        Ok((approx, trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gauss_2d_plus_3d, two_moons};
    use crate::kernels::{kernel_matrix, Gaussian, Linear};
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::{oasis::Oasis, ExplicitOracle, ImplicitOracle};

    /// The headline cross-validation: ICD's greedy diagonal pivoting and
    /// oASIS's Δ-argmax are the same rule, so (seeded with k₀=1 from the
    /// *same first pivot*) the sequences must match. We let oASIS pick its
    /// random seed column first and hand ICD the same start by checking
    /// from the first adaptive step onward on a deterministic start:
    /// with init_cols=1 and seed such that oASIS's seed column equals the
    /// max-diagonal pivot, both sequences coincide entirely. To avoid
    /// depending on the random seed, we compare ICD against oASIS started
    /// from ICD's own first pivot via a custom run below.
    #[test]
    fn icd_matches_oasis_criterion() {
        let ds = two_moons(150, 0.05, 3);
        // non-constant diagonal so pivots are informative: linear kernel
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let (_, icd_trace) = IncompleteCholesky::new(12, 1e-12)
            .sample_traced(&oracle)
            .unwrap();
        // run oASIS brute-force style from the same first column: emulate
        // by trying all oASIS seeds until seed column == icd first pivot
        let first = icd_trace.order[0];
        let mut matched = false;
        for seed in 0..200u64 {
            let (_, tr) = Oasis::new(12, 1, 1e-12, seed)
                .sample_traced(&oracle)
                .unwrap();
            if tr.order[0] == first {
                assert_eq!(
                    tr.order, icd_trace.order,
                    "ICD and oASIS diverged from the same start"
                );
                matched = true;
                break;
            }
        }
        assert!(matched, "no oASIS seed started at ICD's pivot {first}");
    }

    #[test]
    fn icd_residual_diag_equals_delta() {
        // after k pivots, the residual diagonal equals Δ computed from the
        // explicit W⁻¹ quadratic form
        let ds = two_moons(80, 0.05, 5);
        let kern = Gaussian::new(0.7);
        let g = kernel_matrix(&ds, &kern);
        let oracle = ExplicitOracle::new(&g);
        let (_, trace) = IncompleteCholesky::new(6, 1e-12)
            .sample_traced(&oracle)
            .unwrap();
        // Δ from the trace must match d − bᵀW⁻¹b at each selection
        for k in 1..trace.order.len() {
            let lam = &trace.order[..k];
            let w = g.select_cols(lam).select_rows(lam);
            let winv = crate::linalg::inverse(&w).unwrap();
            let j = trace.order[k];
            let b: Vec<f64> = lam.iter().map(|&i| g.at(i, j)).collect();
            let wb = winv.matvec(&b);
            let quad: f64 = b.iter().zip(&wb).map(|(x, y)| x * y).sum();
            let delta = g.at(j, j) - quad;
            assert!(
                (delta - trace.deltas[k]).abs() < 1e-8 * (1.0 + delta.abs()),
                "step {k}: residual {} vs Δ {delta}",
                trace.deltas[k]
            );
        }
    }

    #[test]
    fn icd_exact_recovery_on_low_rank() {
        let ds = gauss_2d_plus_3d(40, 40, 9);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let (approx, _) = IncompleteCholesky::new(10, 1e-9)
            .sample_traced(&oracle)
            .unwrap();
        assert!(approx.k() <= 4);
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "err {err}");
    }

    #[test]
    fn icd_works_on_implicit_oracle() {
        let ds = two_moons(120, 0.05, 7);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = IncompleteCholesky::new(30, 1e-12).sample(&oracle).unwrap();
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 0.1, "err {err}");
    }
}
