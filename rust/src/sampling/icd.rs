//! Incomplete Cholesky Decomposition with greedy diagonal pivoting
//! (Fine & Scheinberg [27], referenced in §II-D4 via the Kumar survey).
//!
//! Partial Cholesky selects the pivot with the largest *residual diagonal*
//! — and the residual diagonal after k pivots is exactly the oASIS Schur
//! complement `Δᵢ = dᵢ − bᵢᵀW⁻¹bᵢ`. ICD is therefore an independent
//! O(kn)-per-step implementation of the same selection rule through
//! triangular factors instead of the Eq. 5/6 inverse updates; the
//! cross-validation test below asserts the selection sequences coincide,
//! which checks both implementations' numerics against each other.

use super::session::{
    run_to_completion, SamplerSession, StepOutcome, StopReason, StoppingRule,
};
use super::{
    assemble_from_indices, ColumnOracle, ColumnSampler, SelectionTrace,
    TracedSampler,
};
use crate::nystrom::NystromApprox;
use crate::util::timing::Stopwatch;
use crate::Result;

/// Greedy-pivot incomplete Cholesky sampler.
#[derive(Clone, Debug)]
pub struct IncompleteCholesky {
    pub max_cols: usize,
    /// stop when the largest residual diagonal falls below this.
    pub tol: f64,
}

impl IncompleteCholesky {
    pub fn new(max_cols: usize, tol: f64) -> Self {
        IncompleteCholesky { max_cols, tol }
    }

    /// Open a stepwise session (one pivot per step). The Cholesky factor
    /// grows unboundedly, so the session can be driven past `max_cols`.
    pub fn session<'a>(&self, oracle: &'a dyn ColumnOracle) -> Result<IcdSession<'a>> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let d = oracle.diag();
        let tol = super::effective_tol(self.tol, &d);
        let d_abs_sum = d.iter().map(|x| x.abs()).sum();
        Ok(IcdSession {
            oracle,
            n,
            tol,
            d_abs_sum,
            resid: d,
            ell: Vec::new(),
            selected: vec![false; n],
            trace: SelectionTrace::default(),
            col: vec![0.0; n],
            exhausted: None,
            busy_secs: sw.secs(),
        })
    }
}

impl ColumnSampler for IncompleteCholesky {
    fn name(&self) -> &'static str {
        "ICD"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for IncompleteCholesky {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let mut session = self.session(oracle)?;
        run_to_completion(&mut session, &StoppingRule::budget(self.max_cols))?;
        let trace = session.trace().clone();
        let approx = session.snapshot()?;
        Ok((approx, trace))
    }
}

/// A paused ICD run (see [`IncompleteCholesky::session`]).
pub struct IcdSession<'a> {
    oracle: &'a dyn ColumnOracle,
    n: usize,
    tol: f64,
    d_abs_sum: f64,
    /// residual diagonal, updated as pivots are added — exactly the oASIS
    /// Δ score for every candidate, always current.
    resid: Vec<f64>,
    /// Cholesky columns: column t (length n) at ell[t*n..]
    ell: Vec<f64>,
    selected: Vec<bool>,
    trace: SelectionTrace,
    /// scratch column buffer
    col: Vec<f64>,
    exhausted: Option<StopReason>,
    busy_secs: f64,
}

impl SamplerSession for IcdSession<'_> {
    fn name(&self) -> &'static str {
        "ICD"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn indices(&self) -> &[usize] {
        &self.trace.order
    }

    fn trace(&self) -> &SelectionTrace {
        &self.trace
    }

    fn selection_secs(&self) -> f64 {
        self.busy_secs
    }

    /// Residual trace ratio `Σ max(residᵢ, 0) / Σ|dᵢ|` — exact (the
    /// residual diagonal is maintained every step), clamping the tiny
    /// negative values f64 cancellation can leave behind.
    fn error_estimate(&self) -> Option<f64> {
        if self.d_abs_sum <= 0.0 {
            return Some(0.0);
        }
        let resid: f64 = self
            .resid
            .iter()
            .zip(&self.selected)
            .filter(|(_, &sel)| !sel)
            .map(|(&r, _)| r.max(0.0))
            .sum();
        Some(resid / self.d_abs_sum)
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.exhausted {
            return Ok(StepOutcome::Exhausted(reason));
        }
        let sw = Stopwatch::start();
        let n = self.n;
        // pivot: largest residual diagonal among unselected
        let mut best = usize::MAX;
        let mut best_val = -1.0;
        for i in 0..n {
            if !self.selected[i] && self.resid[i] > best_val {
                best_val = self.resid[i];
                best = i;
            }
        }
        if best == usize::MAX {
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        if best_val < self.tol {
            self.exhausted = Some(StopReason::ScoreBelowTol);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::ScoreBelowTol));
        }
        let k = self.trace.order.len();
        self.oracle.column_into(best, &mut self.col);
        // new Cholesky column:
        //   v = (g_best − Σ_t ℓ_t ℓ_t[best]) / sqrt(resid[best])
        let piv_sqrt = best_val.sqrt();
        let start = self.ell.len();
        self.ell.extend_from_slice(&self.col);
        {
            let (prev, new) = self.ell.split_at_mut(start);
            for t in 0..k {
                let f = prev[t * n + best];
                if f == 0.0 {
                    continue;
                }
                let lt = &prev[t * n..(t + 1) * n];
                for (o, &lv) in new.iter_mut().zip(lt) {
                    *o -= f * lv;
                }
            }
            for o in new.iter_mut() {
                *o /= piv_sqrt;
            }
        }
        // update residual diagonal: resid_i −= ℓ_k[i]²
        {
            let lk = &self.ell[start..start + n];
            for (r, &lv) in self.resid.iter_mut().zip(lk) {
                *r -= lv * lv;
            }
        }
        self.selected[best] = true;
        self.trace.order.push(best);
        self.trace.cum_secs.push(self.busy_secs + sw.secs());
        self.trace.deltas.push(best_val);
        self.busy_secs += sw.secs();
        Ok(StepOutcome::Selected { index: best, score: best_val })
    }

    fn snapshot(&self) -> Result<NystromApprox> {
        let approx = assemble_from_indices(
            self.oracle,
            self.trace.order.clone(),
            self.busy_secs,
        );
        Ok(approx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gauss_2d_plus_3d, two_moons};
    use crate::kernels::{kernel_matrix, Gaussian, Linear};
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::{oasis::Oasis, ExplicitOracle, ImplicitOracle};

    /// The headline cross-validation: ICD's greedy diagonal pivoting and
    /// oASIS's Δ-argmax are the same rule, so (seeded with k₀=1 from the
    /// *same first pivot*) the sequences must match. We let oASIS pick its
    /// random seed column first and hand ICD the same start by checking
    /// from the first adaptive step onward on a deterministic start:
    /// with init_cols=1 and seed such that oASIS's seed column equals the
    /// max-diagonal pivot, both sequences coincide entirely. To avoid
    /// depending on the random seed, we compare ICD against oASIS started
    /// from ICD's own first pivot via a custom run below.
    #[test]
    fn icd_matches_oasis_criterion() {
        let ds = two_moons(150, 0.05, 3);
        // non-constant diagonal so pivots are informative: linear kernel
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let (_, icd_trace) = IncompleteCholesky::new(12, 1e-12)
            .sample_traced(&oracle)
            .unwrap();
        // run oASIS brute-force style from the same first column: emulate
        // by trying all oASIS seeds until seed column == icd first pivot
        let first = icd_trace.order[0];
        let mut matched = false;
        for seed in 0..200u64 {
            let (_, tr) = Oasis::new(12, 1, 1e-12, seed)
                .sample_traced(&oracle)
                .unwrap();
            if tr.order[0] == first {
                assert_eq!(
                    tr.order, icd_trace.order,
                    "ICD and oASIS diverged from the same start"
                );
                matched = true;
                break;
            }
        }
        assert!(matched, "no oASIS seed started at ICD's pivot {first}");
    }

    #[test]
    fn icd_residual_diag_equals_delta() {
        // after k pivots, the residual diagonal equals Δ computed from the
        // explicit W⁻¹ quadratic form
        let ds = two_moons(80, 0.05, 5);
        let kern = Gaussian::new(0.7);
        let g = kernel_matrix(&ds, &kern);
        let oracle = ExplicitOracle::new(&g);
        let (_, trace) = IncompleteCholesky::new(6, 1e-12)
            .sample_traced(&oracle)
            .unwrap();
        // Δ from the trace must match d − bᵀW⁻¹b at each selection
        for k in 1..trace.order.len() {
            let lam = &trace.order[..k];
            let w = g.select_cols(lam).select_rows(lam);
            let winv = crate::linalg::inverse(&w).unwrap();
            let j = trace.order[k];
            let b: Vec<f64> = lam.iter().map(|&i| g.at(i, j)).collect();
            let wb = winv.matvec(&b);
            let quad: f64 = b.iter().zip(&wb).map(|(x, y)| x * y).sum();
            let delta = g.at(j, j) - quad;
            assert!(
                (delta - trace.deltas[k]).abs() < 1e-8 * (1.0 + delta.abs()),
                "step {k}: residual {} vs Δ {delta}",
                trace.deltas[k]
            );
        }
    }

    #[test]
    fn icd_exact_recovery_on_low_rank() {
        let ds = gauss_2d_plus_3d(40, 40, 9);
        let g = kernel_matrix(&ds, &Linear);
        let oracle = ExplicitOracle::new(&g);
        let (approx, _) = IncompleteCholesky::new(10, 1e-9)
            .sample_traced(&oracle)
            .unwrap();
        assert!(approx.k() <= 4);
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "err {err}");
    }

    #[test]
    fn icd_works_on_implicit_oracle() {
        let ds = two_moons(120, 0.05, 7);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = IncompleteCholesky::new(30, 1e-12).sample(&oracle).unwrap();
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 0.1, "err {err}");
    }

    /// Resuming a budget-stopped ICD session continues the same sequence.
    #[test]
    fn icd_session_resumes() {
        let ds = two_moons(100, 0.05, 4);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (reference, _) = IncompleteCholesky::new(24, 1e-12)
            .sample_traced(&oracle)
            .unwrap();
        let mut s = IncompleteCholesky::new(8, 1e-12).session(&oracle).unwrap();
        run_to_completion(&mut s, &StoppingRule::budget(8)).unwrap();
        assert_eq!(s.k(), 8);
        run_to_completion(&mut s, &StoppingRule::budget(24)).unwrap();
        assert_eq!(s.indices(), &reference.indices[..]);
    }
}
