//! Stepwise sampling sessions and pluggable stopping criteria.
//!
//! oASIS's core advantage (paper §III) is that selection is *sequential
//! and cheap per step* — this module exposes that directly. A
//! [`SamplerSession`] is the paused state of a selection run: each
//! [`step`](SamplerSession::step) performs exactly one column selection,
//! [`snapshot`](SamplerSession::snapshot) assembles the current
//! [`NystromApprox`] without ending the run, and
//! [`finish`](SamplerSession::finish) consumes the session for the final
//! approximation. Because *when to stop* is now the caller's decision,
//! budgets become [`StoppingRule`]s evaluated by [`run_to_completion`]
//! instead of constructor parameters — a run can stop on a column budget,
//! a Δ-score tolerance, an estimated-error target, or a wall-clock
//! deadline, and a stopped session can be resumed with a larger budget:
//! the index set *extends*, it never restarts.
//!
//! Design note: a session captures its column source (oracle, dataset +
//! kernel, or PJRT context) at construction rather than taking it per
//! `step`. Swapping matrices mid-run would silently corrupt the cached
//! `C`/`W⁻¹` state, and it lets sessions that do not read a
//! [`ColumnOracle`](super::ColumnOracle) at all — the distributed
//! coordinator, the PJRT-accelerated path — implement the same trait.
//!
//! ```no_run
//! use oasis::data::generators::two_moons;
//! use oasis::kernels::Gaussian;
//! use oasis::sampling::oasis::Oasis;
//! use oasis::sampling::{
//!     run_to_completion, ImplicitOracle, SamplerSession, StoppingCriterion,
//!     StoppingRule,
//! };
//!
//! let ds = two_moons(2_000, 0.05, 42);
//! let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
//! let oracle = ImplicitOracle::new(&ds, &kernel);
//! let mut session = Oasis::new(450, 10, 1e-12, 7).session(&oracle).unwrap();
//! let rule = StoppingRule::budget(450)
//!     .with(StoppingCriterion::ErrorBelow(1e-3));
//! let reason = run_to_completion(&mut session, &rule).unwrap();
//! println!("stopped after {} columns: {reason:?}", session.k());
//! let approx = session.snapshot().unwrap();
//! ```

use super::SelectionTrace;
use crate::nystrom::NystromApprox;
use crate::Result;
use std::time::Duration;

/// What a single [`SamplerSession::step`] did.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepOutcome {
    /// One column was selected and incorporated into the session state.
    Selected {
        /// global index of the selected column.
        index: usize,
        /// the method's selection score for it (|Δ| for the Schur-
        /// complement methods, the greedy residual ratio for Farahat,
        /// NaN for randomized draws without a score).
        score: f64,
    },
    /// The session cannot make further progress; stepping again returns
    /// the same outcome.
    Exhausted(StopReason),
}

/// Why a run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// a [`StoppingCriterion::ColumnBudget`] was reached.
    BudgetReached,
    /// the best selection score fell below the tolerance (either the
    /// session's internal numerical floor — see
    /// [`effective_tol`](super::effective_tol) — or a
    /// [`StoppingCriterion::ScoreBelow`]). The approximation is
    /// (near-)exact: selecting more columns would divide by ≈0.
    ScoreBelowTol,
    /// a [`StoppingCriterion::ErrorBelow`] target was met.
    ErrorTargetMet,
    /// a [`StoppingCriterion::Deadline`] expired.
    DeadlineExpired,
    /// nothing selectable remains (all n columns taken, the residual is
    /// exhausted, or a fixed-capacity session hit its allocation limit).
    Exhausted,
}

impl StopReason {
    /// Canonical short spelling, shared by the CLI's `--json` output and
    /// the server's wire format.
    pub fn as_str(self) -> &'static str {
        match self {
            StopReason::BudgetReached => "budget",
            StopReason::ScoreBelowTol => "score-tol",
            StopReason::ErrorTargetMet => "error-target",
            StopReason::DeadlineExpired => "deadline",
            StopReason::Exhausted => "exhausted",
        }
    }
}

/// A paused, resumable column-selection run.
///
/// Implemented by every sequential sampler
/// ([`OasisSession`](super::oasis::OasisSession),
/// [`SisSession`](super::sis::SisSession),
/// [`FarahatSession`](super::farahat::FarahatSession),
/// [`IcdSession`](super::icd::IcdSession),
/// [`AdaptiveRandomSession`](super::adaptive_random::AdaptiveRandomSession)),
/// by the distributed coordinator
/// ([`OasisPSession`](crate::coordinator::leader::OasisPSession)) and by
/// the PJRT-accelerated path
/// ([`PjrtOasisSession`](crate::runtime::accel::PjrtOasisSession)).
pub trait SamplerSession {
    /// Method name as used in the paper's tables.
    fn name(&self) -> &'static str;

    /// Matrix dimension n.
    fn n(&self) -> usize;

    /// Λ — every index selected so far, in selection order.
    fn indices(&self) -> &[usize];

    /// Number of selected columns so far (including seed columns).
    fn k(&self) -> usize {
        self.indices().len()
    }

    /// Per-step record of the run so far.
    fn trace(&self) -> &SelectionTrace;

    /// Seconds of selection work so far (time spent inside `step`/
    /// construction — idle time between steps is not charged, so
    /// serving-style callers get honest selection costs).
    fn selection_secs(&self) -> f64;

    /// A cheap estimate of the current relative approximation error, if
    /// the method can provide one from session state. The Schur-complement
    /// methods use the residual trace ratio `Σ|Δᵢ| / Σ|dᵢ|` (the residual
    /// diagonal is exactly Δ); the residual-deflation methods report the
    /// exact `‖E‖_F / ‖G‖_F`. `None` when the method has no estimator.
    fn error_estimate(&self) -> Option<f64> {
        None
    }

    /// The selected data points `Z_Λ[from..]` in selection order, for
    /// sessions whose driver holds no dataset to look them up in. The
    /// distributed coordinator mirrors them on its leader (shard-read
    /// runs leave the serving layer with no materialized dataset, and
    /// queries/saves only ever touch Λ's points); sessions whose callers
    /// own the dataset return `None` (the default) and are looked up
    /// directly. `from` lets a caller that already mirrored a prefix
    /// fetch only the new tail — selection is append-only.
    fn selected_points(&self, from: usize) -> Option<Vec<Vec<f64>>> {
        let _ = from;
        None
    }

    /// Per-worker coordinator counters (columns served, argmax rounds,
    /// wire bytes, heartbeat age, liveness) as a JSON array, for the
    /// serving layer's `/metrics` endpoint. `None` (the default) for
    /// non-distributed sessions — only the oASIS-P coordinator has
    /// workers to report on.
    fn worker_stats(&self) -> Option<crate::util::json::Json> {
        None
    }

    /// Perform one selection step. Idempotent once exhausted.
    fn step(&mut self) -> Result<StepOutcome>;

    /// Assemble a [`NystromApprox`] from the current state *without*
    /// consuming the session — the run can continue afterwards.
    ///
    /// Snapshot cost (the serving layer calls this repeatedly while a
    /// session grows): the oASIS session amortizes via
    /// [`IncrementalAssembler`](crate::nystrom::IncrementalAssembler)
    /// (O(n·m) for m columns added since the last snapshot, plus one
    /// O(n·k) copy); SIS/ICD/Farahat/adaptive-random re-assemble from
    /// their fetched columns at O(n·k); the distributed session performs
    /// one non-terminal column gather across its workers.
    fn snapshot(&self) -> Result<NystromApprox>;

    /// Consume the session and assemble the final approximation.
    fn finish(self: Box<Self>) -> Result<NystromApprox> {
        self.snapshot()
    }
}

/// One pluggable stopping condition (combine via [`StoppingRule`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StoppingCriterion {
    /// Stop once `k` columns are selected (the classic ℓ budget; seed
    /// columns count).
    ColumnBudget(usize),
    /// Stop once the most recent selection score |Δ| drops below ε.
    /// Fires only after at least one scored (non-seed) selection.
    ScoreBelow(f64),
    /// Stop once [`SamplerSession::error_estimate`] reaches the target.
    /// Never fires on sessions without an estimator.
    ErrorBelow(f64),
    /// Stop once the driver has run for this long. Measured from
    /// [`run_to_completion`] entry, so resuming grants a fresh deadline.
    Deadline(Duration),
}

impl StoppingCriterion {
    /// Check against the current session state; `elapsed` is driver time.
    pub fn check(
        &self,
        session: &dyn SamplerSession,
        elapsed: Duration,
    ) -> Option<StopReason> {
        match *self {
            StoppingCriterion::ColumnBudget(l) => {
                (session.k() >= l).then_some(StopReason::BudgetReached)
            }
            StoppingCriterion::ScoreBelow(eps) => match session.trace().deltas.last() {
                Some(&d) if d.is_finite() && d.abs() < eps => {
                    Some(StopReason::ScoreBelowTol)
                }
                _ => None,
            },
            StoppingCriterion::ErrorBelow(target) => match session.error_estimate() {
                Some(e) if e <= target => Some(StopReason::ErrorTargetMet),
                _ => None,
            },
            StoppingCriterion::Deadline(d) => {
                (elapsed >= d).then_some(StopReason::DeadlineExpired)
            }
        }
    }
}

/// A composable any-of stopping rule.
///
/// Criteria are evaluated **in the order they were added**, before every
/// step; the first criterion that holds determines the reported
/// [`StopReason`]. An empty rule never stops the driver externally — the
/// run continues until the session itself is exhausted (rank reached or
/// every column selected), which is well-defined for every sampler here.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StoppingRule {
    criteria: Vec<StoppingCriterion>,
}

impl StoppingRule {
    /// An empty rule (run until the session exhausts itself).
    pub fn new() -> StoppingRule {
        StoppingRule::default()
    }

    /// The classic fixed-ℓ rule.
    pub fn budget(l: usize) -> StoppingRule {
        StoppingRule::new().with(StoppingCriterion::ColumnBudget(l))
    }

    /// Add a criterion (builder style).
    pub fn with(mut self, c: StoppingCriterion) -> StoppingRule {
        self.criteria.push(c);
        self
    }

    pub fn criteria(&self) -> &[StoppingCriterion] {
        &self.criteria
    }

    /// Clamp every [`ColumnBudget`](StoppingCriterion::ColumnBudget)
    /// criterion to `n` — a budget past n is just "all columns", and
    /// without the clamp such a run would report
    /// [`Exhausted`](StopReason::Exhausted) instead of
    /// [`BudgetReached`](StopReason::BudgetReached). Applied by the
    /// engine once the dataset size is known.
    pub fn clamp_budget(mut self, n: usize) -> StoppingRule {
        for c in &mut self.criteria {
            if let StoppingCriterion::ColumnBudget(l) = c {
                *l = (*l).min(n);
            }
        }
        self
    }

    /// First criterion (in insertion order) that holds, if any.
    pub fn evaluate(
        &self,
        session: &dyn SamplerSession,
        elapsed: Duration,
    ) -> Option<StopReason> {
        self.criteria
            .iter()
            .find_map(|c| c.check(session, elapsed))
    }
}

/// One completed selection step, as reported to a
/// [`run_to_completion_observed`] observer — the convergence-telemetry
/// record the CLI's `approximate --trajectory` writes per row and the
/// serving layer mirrors into each session's trajectory ring.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    /// 1-based step number within this driver call.
    pub step: u64,
    /// columns selected so far (including seed columns), after the step.
    pub k: usize,
    /// global index of the column this step selected.
    pub index: usize,
    /// the method's selection score (NaN for unscored randomized draws).
    pub score: f64,
    /// [`SamplerSession::error_estimate`] after the step, if available.
    pub error_estimate: Option<f64>,
    /// wall-clock duration of this step, in microseconds.
    pub step_us: u64,
}

/// Drive a session until the rule fires or the session exhausts itself,
/// returning why the run stopped. The rule is evaluated before every step
/// (so a session already past a budget stops immediately and a resumed
/// session with a larger budget simply keeps extending its index set).
pub fn run_to_completion(
    session: &mut dyn SamplerSession,
    rule: &StoppingRule,
) -> Result<StopReason> {
    run_to_completion_observed(session, rule, |_| {})
}

/// [`run_to_completion`] with a per-step observer: `observe` is called
/// once after every *successful* selection with that step's
/// [`StepRecord`]. The observer sits outside the step's trace span, so
/// recording telemetry never inflates the measured step latency.
pub fn run_to_completion_observed(
    session: &mut dyn SamplerSession,
    rule: &StoppingRule,
    mut observe: impl FnMut(StepRecord),
) -> Result<StopReason> {
    let started = std::time::Instant::now();
    let mut steps: u64 = 0;
    loop {
        if let Some(reason) = rule.evaluate(session, started.elapsed()) {
            return Ok(reason);
        }
        let t0 = std::time::Instant::now();
        let outcome = {
            let _step_span = crate::obs::span("sampler_step", "sampling");
            session.step()?
        };
        match outcome {
            StepOutcome::Selected { index, score } => {
                steps += 1;
                observe(StepRecord {
                    step: steps,
                    k: session.k(),
                    index,
                    score,
                    error_estimate: session.error_estimate(),
                    step_us: t0.elapsed().as_micros() as u64,
                });
            }
            StepOutcome::Exhausted(reason) => return Ok(reason),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The serving layer (`oasis::server`) constructs sessions inside
    /// dedicated actor threads, which requires every session type to be
    /// movable to (and constructible on) another thread. This
    /// compile-time assertion documents that guarantee: every oracle is
    /// `Sync` (so `&dyn ColumnOracle` is `Send`) and session state is
    /// plain owned data.
    #[test]
    fn all_sessions_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<crate::sampling::oasis::OasisSession<'static>>();
        assert_send::<crate::sampling::sis::SisSession<'static>>();
        assert_send::<crate::sampling::farahat::FarahatSession<'static>>();
        assert_send::<crate::sampling::icd::IcdSession<'static>>();
        assert_send::<
            crate::sampling::adaptive_random::AdaptiveRandomSession<'static>,
        >();
        assert_send::<crate::coordinator::OasisPSession>();
    }

    /// A scripted fake session: selects indices 0,1,2,… with scores from a
    /// list, and a fixed error-estimate schedule.
    struct Fake {
        indices: Vec<usize>,
        trace: SelectionTrace,
        scores: Vec<f64>,
        errors: Vec<f64>,
    }

    impl Fake {
        fn new(scores: Vec<f64>, errors: Vec<f64>) -> Fake {
            Fake {
                indices: Vec::new(),
                trace: SelectionTrace::default(),
                scores,
                errors,
            }
        }
    }

    impl SamplerSession for Fake {
        fn name(&self) -> &'static str {
            "fake"
        }

        fn n(&self) -> usize {
            self.scores.len()
        }

        fn indices(&self) -> &[usize] {
            &self.indices
        }

        fn trace(&self) -> &SelectionTrace {
            &self.trace
        }

        fn selection_secs(&self) -> f64 {
            0.0
        }

        fn error_estimate(&self) -> Option<f64> {
            self.errors.get(self.k()).copied()
        }

        fn step(&mut self) -> Result<StepOutcome> {
            let k = self.k();
            if k >= self.scores.len() {
                return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
            }
            let score = self.scores[k];
            self.indices.push(k);
            self.trace.order.push(k);
            self.trace.cum_secs.push(k as f64);
            self.trace.deltas.push(score);
            Ok(StepOutcome::Selected { index: k, score })
        }

        fn snapshot(&self) -> Result<NystromApprox> {
            Ok(NystromApprox {
                indices: self.indices.clone(),
                c: crate::linalg::Mat::zeros(self.n(), self.k()),
                winv: crate::linalg::Mat::zeros(self.k(), self.k()),
                selection_secs: 0.0,
            })
        }
    }

    #[test]
    fn budget_stops_at_l() {
        let mut s = Fake::new(vec![1.0; 10], vec![]);
        let reason = run_to_completion(&mut s, &StoppingRule::budget(4)).unwrap();
        assert_eq!(reason, StopReason::BudgetReached);
        assert_eq!(s.k(), 4);
    }

    #[test]
    fn empty_rule_runs_to_exhaustion() {
        let mut s = Fake::new(vec![1.0; 6], vec![]);
        let reason = run_to_completion(&mut s, &StoppingRule::new()).unwrap();
        assert_eq!(reason, StopReason::Exhausted);
        assert_eq!(s.k(), 6);
    }

    #[test]
    fn score_below_fires_after_scored_step() {
        let mut s = Fake::new(vec![1.0, 0.5, 0.01, 0.001], vec![]);
        let rule = StoppingRule::new().with(StoppingCriterion::ScoreBelow(0.1));
        let reason = run_to_completion(&mut s, &rule).unwrap();
        assert_eq!(reason, StopReason::ScoreBelowTol);
        // stopped right after the 0.01 selection, before selecting 0.001
        assert_eq!(s.k(), 3);
    }

    #[test]
    fn error_target_stops_early() {
        // error estimate after k selections: 1/(k+1)
        let errors: Vec<f64> = (0..10).map(|k| 1.0 / (k as f64 + 1.0)).collect();
        let mut s = Fake::new(vec![1.0; 10], errors);
        let rule = StoppingRule::budget(10).with(StoppingCriterion::ErrorBelow(0.26));
        let reason = run_to_completion(&mut s, &rule).unwrap();
        assert_eq!(reason, StopReason::ErrorTargetMet);
        assert!(s.k() < 10, "stopped at k = {}", s.k());
    }

    #[test]
    fn criteria_fire_in_insertion_order() {
        // both hold from the start: the first added wins
        let mut a = Fake::new(vec![1.0; 5], vec![0.0; 6]);
        let rule_a = StoppingRule::new()
            .with(StoppingCriterion::ErrorBelow(0.5))
            .with(StoppingCriterion::ColumnBudget(0));
        assert_eq!(
            run_to_completion(&mut a, &rule_a).unwrap(),
            StopReason::ErrorTargetMet
        );
        let mut b = Fake::new(vec![1.0; 5], vec![0.0; 6]);
        let rule_b = StoppingRule::new()
            .with(StoppingCriterion::ColumnBudget(0))
            .with(StoppingCriterion::ErrorBelow(0.5));
        assert_eq!(
            run_to_completion(&mut b, &rule_b).unwrap(),
            StopReason::BudgetReached
        );
    }

    #[test]
    fn zero_deadline_stops_immediately() {
        let mut s = Fake::new(vec![1.0; 5], vec![]);
        let rule = StoppingRule::budget(5)
            .with(StoppingCriterion::Deadline(Duration::ZERO));
        // budget listed first but not met at k=0; deadline fires
        assert_eq!(
            run_to_completion(&mut s, &rule).unwrap(),
            StopReason::DeadlineExpired
        );
        assert_eq!(s.k(), 0);
    }

    #[test]
    fn observed_run_reports_every_selection() {
        let mut s = Fake::new(vec![1.0, 0.5, 0.25, 0.125], vec![]);
        let mut seen = Vec::new();
        let reason = run_to_completion_observed(
            &mut s,
            &StoppingRule::budget(3),
            |r| seen.push(r),
        )
        .unwrap();
        assert_eq!(reason, StopReason::BudgetReached);
        assert_eq!(seen.len(), 3);
        for (i, r) in seen.iter().enumerate() {
            assert_eq!(r.step, i as u64 + 1);
            assert_eq!(r.k, i + 1);
            assert_eq!(r.index, i);
            assert_eq!(r.score, [1.0, 0.5, 0.25][i]);
        }
    }

    #[test]
    fn resume_extends_with_larger_budget() {
        let mut s = Fake::new(vec![1.0; 8], vec![]);
        run_to_completion(&mut s, &StoppingRule::budget(3)).unwrap();
        assert_eq!(s.k(), 3);
        let reason = run_to_completion(&mut s, &StoppingRule::budget(6)).unwrap();
        assert_eq!(reason, StopReason::BudgetReached);
        assert_eq!(s.indices(), &[0, 1, 2, 3, 4, 5]);
    }
}
