//! Column-subset-selection samplers: oASIS (the paper's contribution), the
//! naive SIS oracle it accelerates, and every baseline the paper compares
//! against (uniform random, leverage scores, Farahat greedy, K-means
//! Nyström).
//!
//! All samplers speak to the kernel matrix through [`ColumnOracle`], which
//! abstracts over explicit matrices (Table I), implicit on-the-fly kernels
//! (Table II), and sparse k-NN kernels (§V-E). Hot paths pull columns in
//! batches through [`ColumnOracle::columns_into`].
//!
//! ## Two ways to run a sampler
//!
//! * **One-shot** — [`ColumnSampler::sample`] selects up to the
//!   constructor's column budget and assembles the approximation. This is
//!   a thin adapter over the session API below.
//! * **Stepwise** — the sequential samplers expose a `session(…)`
//!   constructor returning a [`SamplerSession`]: one selection per
//!   [`step`](SamplerSession::step), assembly on demand via
//!   [`snapshot`](SamplerSession::snapshot)/[`finish`](SamplerSession::finish),
//!   and stopping policy supplied externally as a [`StoppingRule`] driven
//!   by [`run_to_completion`].
//!
//! ## Stopping-criterion semantics
//!
//! A [`StoppingRule`] is an *any-of* list of [`StoppingCriterion`]s,
//! evaluated against the session state **before every step**, in the
//! order they were added; the first that holds names the returned
//! [`StopReason`]. The criteria:
//!
//! * [`ColumnBudget(ℓ)`](StoppingCriterion::ColumnBudget) — `k ≥ ℓ`,
//!   counting seed columns. Equivalent to the legacy `max_cols` budget.
//! * [`ScoreBelow(ε)`](StoppingCriterion::ScoreBelow) — the most recent
//!   selection score `|Δ|` fell below ε. Independent of (and checked
//!   after) the session-internal numerical floor
//!   ([`effective_tol`]), which always applies: a session refuses to
//!   select a numerically-zero Δ no matter what the rule says, because
//!   `s = 1/Δ` would poison the Eq. 5 update.
//! * [`ErrorBelow(t)`](StoppingCriterion::ErrorBelow) — the session's
//!   [`error_estimate`](SamplerSession::error_estimate) reached `t`.
//!   Schur-complement sessions estimate with the residual trace ratio
//!   `Σ|Δᵢ|/Σ|dᵢ|` (cheap, refreshed every scoring sweep); residual-
//!   deflation sessions report the exact `‖E‖_F/‖G‖_F`.
//! * [`Deadline(d)`](StoppingCriterion::Deadline) — wall clock since
//!   [`run_to_completion`] entry exceeded `d`; resuming grants a fresh
//!   deadline.
//!
//! Sessions are resumable: driving the same session again with a larger
//! budget extends the selected index set — it never restarts.

pub mod adaptive_random;
pub mod farahat;
pub mod icd;
pub mod kmeans;
pub mod leverage;
pub mod oasis;
pub mod oracle;
pub mod session;
pub mod sis;
pub mod uniform;

pub use oracle::{ColumnOracle, ExplicitOracle, ImplicitOracle, SparseKnnOracle};
pub use session::{
    run_to_completion, run_to_completion_observed, SamplerSession,
    StepOutcome, StepRecord, StopReason, StoppingCriterion, StoppingRule,
};

use crate::nystrom::NystromApprox;
use crate::Result;

/// A column-subset-selection method producing a Nyström approximation.
pub trait ColumnSampler {
    /// Method name as used in the paper's tables.
    fn name(&self) -> &'static str;

    /// Select columns from the oracle and assemble the approximation.
    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox>;
}

/// Per-step record of a sequential selection run, used by the Fig. 6/7
/// benches: prefix `order[..k]` is the index set after k selections and
/// `cum_secs[k-1]` the wall-clock spent to get there.
#[derive(Clone, Debug, Default)]
pub struct SelectionTrace {
    /// Λ in selection order.
    pub order: Vec<usize>,
    /// cumulative selection seconds after each column.
    pub cum_secs: Vec<f64>,
    /// |Δ| (or method-specific score) at each adaptive selection;
    /// NaN for seed columns / methods without scores.
    pub deltas: Vec<f64>,
}

/// Sequential samplers that can expose their per-step trace.
pub trait TracedSampler: ColumnSampler {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)>;
}

/// `‖M‖_F` with row-streaming threaded accumulation — shared by the
/// residual-deflation sessions' exact error estimates.
pub(crate) fn fro_norm(m: &crate::linalg::Mat, threads: usize) -> f64 {
    let parts = crate::util::parallel::map_ranges(m.rows, threads, |range| {
        let mut acc = 0.0f64;
        for i in range {
            for &v in m.row(i) {
                acc += v * v;
            }
        }
        acc
    });
    parts.into_iter().sum::<f64>().sqrt()
}

/// The effective stopping tolerance for Schur-complement selection: the
/// user tolerance floored at machine-precision relative to the diagonal
/// scale. Selecting a numerically-zero Δ would make `s = 1/Δ` explode and
/// poison the Eq. 5 update, so every oASIS implementation (sequential,
/// PJRT, distributed, naive SIS) applies this same guard — keeping their
/// selection sequences identical.
pub fn effective_tol(user_tol: f64, diag: &[f64]) -> f64 {
    let scale = diag.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
    user_tol.max(1e-12 * scale.max(1e-300))
}

/// Assemble a [`NystromApprox`] from a chosen index set: forms C with one
/// batched [`ColumnOracle::columns_into`] fill (contiguous row-major
/// writes instead of a strided scatter per column) and computes W⁺ by
/// pseudo-inverse of the rows of C already fetched — the oracle is not
/// queried again for W. Used by the baselines that select Λ without
/// maintaining W⁻¹ themselves.
pub fn assemble_from_indices(
    oracle: &dyn ColumnOracle,
    indices: Vec<usize>,
    selection_secs: f64,
) -> NystromApprox {
    let n = oracle.n();
    let k = indices.len();
    let mut c = crate::linalg::Mat::zeros(n, k);
    oracle.columns_into(&indices, &mut c);
    let w = c.select_rows(&indices);
    let winv = crate::linalg::pinv_psd(&w, 1e-12);
    NystromApprox { indices, c, winv, selection_secs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;

    #[test]
    fn assemble_produces_consistent_approx() {
        let ds = two_moons(30, 0.05, 1);
        let kern = Gaussian::new(0.8);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = assemble_from_indices(&oracle, vec![0, 5, 10, 20], 0.0);
        assert_eq!(approx.k(), 4);
        assert_eq!(approx.n(), 30);
        // C columns match oracle columns
        let mut col = vec![0.0; 30];
        oracle.column_into(5, &mut col);
        for i in 0..30 {
            assert_eq!(approx.c.at(i, 1), col[i]);
        }
    }
}
