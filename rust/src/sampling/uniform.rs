//! Uniform random column sampling (paper §II-D1) — the fastest-to-select
//! baseline: O(1) per index, but no adaptivity, so redundant columns are
//! common on clustered data ("birthday problem", §V-E) and W is frequently
//! rank-deficient, forcing a pseudo-inverse.

use super::{
    assemble_from_indices, ColumnOracle, ColumnSampler, SelectionTrace,
    TracedSampler,
};
use crate::nystrom::NystromApprox;
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::Result;

/// Uniform random sampling without replacement.
#[derive(Clone, Debug)]
pub struct Uniform {
    pub cols: usize,
    pub seed: u64,
}

impl Uniform {
    pub fn new(cols: usize, seed: u64) -> Uniform {
        Uniform { cols, seed }
    }

    pub fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let k = self.cols.min(n);
        let order = Pcg64::new(self.seed).sample_without_replacement(n, k);
        let select_secs = sw.secs();
        let mut trace = SelectionTrace::default();
        for (i, &j) in order.iter().enumerate() {
            trace.order.push(j);
            // index selection is O(1); spread the measured time evenly
            trace.cum_secs.push(select_secs * (i + 1) as f64 / k as f64);
            trace.deltas.push(f64::NAN);
        }
        // `selection_secs` reports only the O(1) index draw, matching the
        // paper's Table I convention (its Random column shows 0.01 s).
        // Forming C and computing W⁺ is *not* free — the end-to-end
        // sample+form cost is what Table III / end_to_end measure — but it
        // is not "selection".
        let approx = assemble_from_indices(oracle, order, select_secs);
        Ok((approx, trace))
    }
}

impl ColumnSampler for Uniform {
    fn name(&self) -> &'static str {
        "Random"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for Uniform {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        Uniform::sample_traced(self, oracle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::nystrom::relative_frobenius_error;
    use crate::sampling::ImplicitOracle;

    #[test]
    fn selects_distinct_indices() {
        let ds = two_moons(50, 0.05, 1);
        let kern = Gaussian::new(0.5);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = Uniform::new(20, 3).sample(&oracle).unwrap();
        let set: std::collections::HashSet<_> = approx.indices.iter().collect();
        assert_eq!(set.len(), 20);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = two_moons(40, 0.05, 2);
        let kern = Gaussian::new(0.5);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let a = Uniform::new(10, 7).sample(&oracle).unwrap();
        let b = Uniform::new(10, 7).sample(&oracle).unwrap();
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn error_reasonable_with_many_columns() {
        let ds = two_moons(100, 0.05, 3);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.2);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = Uniform::new(60, 5).sample(&oracle).unwrap();
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 0.2, "err {err}");
    }

    #[test]
    fn handles_k_equals_n() {
        let ds = two_moons(15, 0.05, 4);
        let kern = Gaussian::new(1.0);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = Uniform::new(100, 5).sample(&oracle).unwrap();
        assert_eq!(approx.k(), 15);
        let err = relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "err {err}");
    }
}
