//! Column oracles: how samplers read the kernel matrix.
//!
//! The key property oASIS exploits (paper §III-A) is that only the sampled
//! columns and the diagonal are ever needed — so the oracle interface
//! exposes exactly that, and the implicit implementations never form G.

use crate::data::Dataset;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::util::parallel;

/// Read access to columns/diagonal/entries of a symmetric PSD matrix.
pub trait ColumnOracle: Sync {
    /// Matrix dimension n.
    fn n(&self) -> usize;

    /// diag(G).
    fn diag(&self) -> Vec<f64>;

    /// Write column j of G into `out` (length n).
    fn column_into(&self, j: usize, out: &mut [f64]);

    /// A single entry G(i, j) (used by sampled-error estimation).
    fn entry(&self, i: usize, j: usize) -> f64;

    /// Convenience: column j as a fresh Vec.
    fn column(&self, j: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.n()];
        self.column_into(j, &mut out);
        out
    }
}

/// Oracle over an explicitly stored kernel matrix (Table I class).
pub struct ExplicitOracle<'a> {
    g: &'a Mat,
}

impl<'a> ExplicitOracle<'a> {
    pub fn new(g: &'a Mat) -> Self {
        assert_eq!(g.rows, g.cols, "kernel matrix must be square");
        ExplicitOracle { g }
    }
}

impl ColumnOracle for ExplicitOracle<'_> {
    fn n(&self) -> usize {
        self.g.rows
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.g.rows).map(|i| self.g.at(i, i)).collect()
    }

    fn column_into(&self, j: usize, out: &mut [f64]) {
        // symmetric ⇒ column j == row j (contiguous in row-major storage)
        out.copy_from_slice(self.g.row(j));
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.g.at(i, j)
    }
}

/// Oracle that computes kernel columns on the fly from the data — the
/// Table II "implicit" class where G is never formed.
pub struct ImplicitOracle<'a> {
    ds: &'a Dataset,
    kernel: &'a dyn Kernel,
}

impl<'a> ImplicitOracle<'a> {
    pub fn new(ds: &'a Dataset, kernel: &'a dyn Kernel) -> Self {
        ImplicitOracle { ds, kernel }
    }

    pub fn dataset(&self) -> &Dataset {
        self.ds
    }

    pub fn kernel(&self) -> &dyn Kernel {
        self.kernel
    }
}

impl ColumnOracle for ImplicitOracle<'_> {
    fn n(&self) -> usize {
        self.ds.n()
    }

    fn diag(&self) -> Vec<f64> {
        crate::kernels::kernel_diag(self.ds, self.kernel)
    }

    fn column_into(&self, j: usize, out: &mut [f64]) {
        crate::kernels::kernel_column_into(self.ds, self.kernel, j, out);
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.kernel.eval(self.ds.point(i), self.ds.point(j))
    }
}

/// Sparse k-NN-truncated kernel oracle (§V-E): each column keeps only the
/// `knn` largest kernel values (plus the diagonal), all others are exactly
/// zero. Columns are precomputed in CSR-like storage; symmetrized so the
/// matrix stays symmetric (an entry survives if it is in either point's
/// neighbor list).
pub struct SparseKnnOracle {
    n: usize,
    diag: Vec<f64>,
    /// per-column (row index, value) pairs, sorted by row
    cols: Vec<Vec<(u32, f64)>>,
}

impl SparseKnnOracle {
    pub fn build(ds: &Dataset, kernel: &dyn Kernel, knn: usize) -> Self {
        let n = ds.n();
        let diag = crate::kernels::kernel_diag(ds, kernel);
        // neighbor lists per column (threaded)
        let lists: Vec<Vec<(u32, f64)>> = parallel::map_ranges(
            n,
            parallel::default_threads(),
            |range| {
                let mut out = Vec::with_capacity(range.len());
                let mut buf: Vec<(u32, f64)> = Vec::with_capacity(n);
                for j in range {
                    buf.clear();
                    let zj = ds.point(j);
                    for i in 0..n {
                        if i != j {
                            buf.push((i as u32, kernel.eval(ds.point(i), zj)));
                        }
                    }
                    buf.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                    let mut kept: Vec<(u32, f64)> =
                        buf.iter().take(knn).copied().collect();
                    kept.sort_by_key(|e| e.0);
                    out.push(kept);
                }
                out
            },
        )
        .into_iter()
        .flatten()
        .collect();
        // symmetrize: union of (i in knn(j)) and (j in knn(i))
        let mut sets: Vec<std::collections::BTreeMap<u32, f64>> = lists
            .iter()
            .map(|l| l.iter().copied().collect())
            .collect();
        for j in 0..n {
            for &(i, v) in &lists[j] {
                sets[i as usize].entry(j as u32).or_insert(v);
            }
        }
        let cols: Vec<Vec<(u32, f64)>> = sets
            .into_iter()
            .map(|m| m.into_iter().collect())
            .collect();
        SparseKnnOracle { n, diag, cols }
    }

    /// Fraction of nonzero entries (including the diagonal).
    pub fn density(&self) -> f64 {
        let nnz: usize = self.cols.iter().map(|c| c.len()).sum::<usize>() + self.n;
        nnz as f64 / (self.n as f64 * self.n as f64)
    }
}

impl ColumnOracle for SparseKnnOracle {
    fn n(&self) -> usize {
        self.n
    }

    fn diag(&self) -> Vec<f64> {
        self.diag.clone()
    }

    fn column_into(&self, j: usize, out: &mut [f64]) {
        out.fill(0.0);
        out[j] = self.diag[j];
        for &(i, v) in &self.cols[j] {
            out[i as usize] = v;
        }
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        if i == j {
            return self.diag[j];
        }
        match self.cols[j].binary_search_by_key(&(i as u32), |e| e.0) {
            Ok(pos) => self.cols[j][pos].1,
            Err(_) => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::{kernel_matrix, Gaussian};

    #[test]
    fn explicit_and_implicit_agree() {
        let ds = two_moons(40, 0.05, 3);
        let kern = Gaussian::new(0.9);
        let g = kernel_matrix(&ds, &kern);
        let exp = ExplicitOracle::new(&g);
        let imp = ImplicitOracle::new(&ds, &kern);
        assert_eq!(exp.n(), imp.n());
        let de = exp.diag();
        let di = imp.diag();
        for j in [0usize, 13, 39] {
            assert!((de[j] - di[j]).abs() < 1e-14);
            let ce = exp.column(j);
            let ci = imp.column(j);
            for i in 0..40 {
                assert!((ce[i] - ci[i]).abs() < 1e-14);
                assert!((exp.entry(i, j) - imp.entry(i, j)).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn sparse_knn_is_symmetric() {
        let ds = two_moons(50, 0.05, 4);
        let kern = Gaussian::new(0.5);
        let o = SparseKnnOracle::build(&ds, &kern, 5);
        for i in 0..50 {
            for j in 0..50 {
                assert!(
                    (o.entry(i, j) - o.entry(j, i)).abs() < 1e-14,
                    "asymmetry at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn sparse_knn_preserves_top_neighbors_and_zeros() {
        let ds = two_moons(60, 0.05, 5);
        let kern = Gaussian::new(0.4);
        let o = SparseKnnOracle::build(&ds, &kern, 4);
        let dense = ImplicitOracle::new(&ds, &kern);
        let col_s = o.column(7);
        let col_d = dense.column(7);
        // nonzeros match the dense kernel exactly
        for i in 0..60 {
            if col_s[i] != 0.0 {
                assert!((col_s[i] - col_d[i]).abs() < 1e-14);
            }
        }
        // sparsity actually happened
        assert!(o.density() < 0.5, "density {}", o.density());
        // diagonal kept
        assert_eq!(col_s[7], 1.0);
    }
}
