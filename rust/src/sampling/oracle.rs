//! Column oracles: how samplers read the kernel matrix.
//!
//! The key property oASIS exploits (paper §III-A) is that only the sampled
//! columns and the diagonal are ever needed — so the oracle interface
//! exposes exactly that, and the implicit implementations never form G.

use crate::data::Dataset;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::util::parallel;

/// Read access to columns/diagonal/entries of a symmetric PSD matrix.
pub trait ColumnOracle: Sync {
    /// Matrix dimension n.
    fn n(&self) -> usize;

    /// diag(G).
    fn diag(&self) -> Vec<f64>;

    /// Write column j of G into `out` (length n).
    fn column_into(&self, j: usize, out: &mut [f64]);

    /// A single entry G(i, j) (used by sampled-error estimation).
    fn entry(&self, i: usize, j: usize) -> f64;

    /// Convenience: column j as a fresh Vec.
    fn column(&self, j: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.n()];
        self.column_into(j, &mut out);
        out
    }

    /// Batched column access for the hot paths: write columns `js` of G
    /// into the n×|js| row-major matrix `out`, i.e.
    /// `out(i, t) = G(i, js[t])`.
    ///
    /// The default implementation fetches one column at a time and
    /// scatters it with stride-|js| writes; every oracle in this module
    /// overrides it with a parallel fill whose writes are contiguous per
    /// row chunk. Used by [`super::assemble_from_indices`], the oASIS
    /// seed phase, and residual materialization in the deflation-based
    /// baselines.
    fn columns_into(&self, js: &[usize], out: &mut Mat) {
        let n = self.n();
        let k = js.len();
        assert_eq!(out.rows, n, "columns_into: out has {} rows, n = {n}", out.rows);
        assert_eq!(out.cols, k, "columns_into: out has {} cols for {k} indices", out.cols);
        if k == 0 {
            return;
        }
        let mut col = vec![0.0; n];
        for (t, &j) in js.iter().enumerate() {
            self.column_into(j, &mut col);
            for (i, &v) in col.iter().enumerate() {
                out.data[i * k + t] = v;
            }
        }
    }
}

/// Thread count for a batched fill of `n × k` entries: stay single-
/// threaded for small blocks where spawn overhead dominates.
fn batch_threads(n: usize, k: usize) -> usize {
    if n.saturating_mul(k) >= 16_384 {
        parallel::default_threads()
    } else {
        1
    }
}

/// Oracle over an explicitly stored kernel matrix (Table I class).
pub struct ExplicitOracle<'a> {
    g: &'a Mat,
}

impl<'a> ExplicitOracle<'a> {
    pub fn new(g: &'a Mat) -> Self {
        assert_eq!(g.rows, g.cols, "kernel matrix must be square");
        ExplicitOracle { g }
    }
}

impl ColumnOracle for ExplicitOracle<'_> {
    fn n(&self) -> usize {
        self.g.rows
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.g.rows).map(|i| self.g.at(i, i)).collect()
    }

    fn column_into(&self, j: usize, out: &mut [f64]) {
        // symmetric ⇒ column j == row j (contiguous in row-major storage)
        out.copy_from_slice(self.g.row(j));
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.g.at(i, j)
    }

    /// Batched gather: each output row i reads `g.row(i)` (hot in cache)
    /// and writes contiguously — no strided passes over G.
    fn columns_into(&self, js: &[usize], out: &mut Mat) {
        let n = self.g.rows;
        let k = js.len();
        assert_eq!((out.rows, out.cols), (n, k));
        if k == 0 {
            return;
        }
        let g = self.g;
        parallel::for_each_chunk_mut(
            &mut out.data,
            k,
            batch_threads(n, k),
            |range, chunk| {
                for (local, i) in range.clone().enumerate() {
                    let row = g.row(i);
                    let dst = &mut chunk[local * k..(local + 1) * k];
                    for (o, &j) in dst.iter_mut().zip(js) {
                        *o = row[j];
                    }
                }
            },
        );
    }
}

/// Oracle that computes kernel columns on the fly from the data — the
/// Table II "implicit" class where G is never formed.
pub struct ImplicitOracle<'a> {
    ds: &'a Dataset,
    kernel: &'a dyn Kernel,
}

impl<'a> ImplicitOracle<'a> {
    pub fn new(ds: &'a Dataset, kernel: &'a dyn Kernel) -> Self {
        ImplicitOracle { ds, kernel }
    }

    pub fn dataset(&self) -> &Dataset {
        self.ds
    }

    pub fn kernel(&self) -> &dyn Kernel {
        self.kernel
    }
}

impl ColumnOracle for ImplicitOracle<'_> {
    fn n(&self) -> usize {
        self.ds.n()
    }

    fn diag(&self) -> Vec<f64> {
        crate::kernels::kernel_diag(self.ds, self.kernel)
    }

    fn column_into(&self, j: usize, out: &mut [f64]) {
        crate::kernels::kernel_column_into(self.ds, self.kernel, j, out);
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.kernel.eval(self.ds.point(i), self.ds.point(j))
    }

    /// Batched evaluation: one parallel sweep computes all |js| kernel
    /// columns (the per-column path would launch |js| separate sweeps).
    /// Rows are processed in contiguous blocks of the point-major data —
    /// one [`Kernel::eval_rows`] call per (selected point, row block),
    /// i.e. one virtual dispatch amortized over the whole block with the
    /// kernel math statically inlined — and each block's column segment
    /// is then scattered into the row-major output tile while hot.
    fn columns_into(&self, js: &[usize], out: &mut Mat) {
        let n = self.ds.n();
        let k = js.len();
        assert_eq!((out.rows, out.cols), (n, k));
        if k == 0 {
            return;
        }
        let pts: Vec<&[f64]> = js.iter().map(|&j| self.ds.point(j)).collect();
        let dim = self.ds.dim();
        let flat = self.ds.flat();
        let kernel = self.kernel;
        // block × k output tile + scratch column sized to stay L1-hot
        let block = (4096 / k).clamp(8, 512);
        parallel::for_each_chunk_mut(
            &mut out.data,
            k,
            batch_threads(n, k),
            |range, chunk| {
                let mut col = vec![0.0; block.min(range.len())];
                let mut lo = range.start;
                while lo < range.end {
                    let hi = (lo + block).min(range.end);
                    let rows = &flat[lo * dim..hi * dim];
                    for (t, &zj) in pts.iter().enumerate() {
                        let seg = &mut col[..hi - lo];
                        kernel.eval_rows(rows, dim, zj, seg);
                        let base = (lo - range.start) * k + t;
                        for (local, &v) in seg.iter().enumerate() {
                            chunk[base + local * k] = v;
                        }
                    }
                    lo = hi;
                }
            },
        );
    }
}

/// Sparse k-NN-truncated kernel oracle (§V-E): each column keeps only the
/// `knn` largest kernel values (plus the diagonal), all others are exactly
/// zero. Columns are precomputed in CSR-like storage; symmetrized so the
/// matrix stays symmetric (an entry survives if it is in either point's
/// neighbor list).
pub struct SparseKnnOracle {
    n: usize,
    diag: Vec<f64>,
    /// per-column (row index, value) pairs, sorted by row
    cols: Vec<Vec<(u32, f64)>>,
}

impl SparseKnnOracle {
    pub fn build(ds: &Dataset, kernel: &dyn Kernel, knn: usize) -> Self {
        let n = ds.n();
        let diag = crate::kernels::kernel_diag(ds, kernel);
        // neighbor lists per column (threaded)
        let lists: Vec<Vec<(u32, f64)>> = parallel::map_ranges(
            n,
            parallel::default_threads(),
            |range| {
                let mut out = Vec::with_capacity(range.len());
                let mut buf: Vec<(u32, f64)> = Vec::with_capacity(n);
                for j in range {
                    buf.clear();
                    let zj = ds.point(j);
                    for i in 0..n {
                        if i != j {
                            buf.push((i as u32, kernel.eval(ds.point(i), zj)));
                        }
                    }
                    buf.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                    let mut kept: Vec<(u32, f64)> =
                        buf.iter().take(knn).copied().collect();
                    kept.sort_by_key(|e| e.0);
                    out.push(kept);
                }
                out
            },
        )
        .into_iter()
        .flatten()
        .collect();
        // symmetrize: union of (i in knn(j)) and (j in knn(i))
        let mut sets: Vec<std::collections::BTreeMap<u32, f64>> = lists
            .iter()
            .map(|l| l.iter().copied().collect())
            .collect();
        for j in 0..n {
            for &(i, v) in &lists[j] {
                sets[i as usize].entry(j as u32).or_insert(v);
            }
        }
        let cols: Vec<Vec<(u32, f64)>> = sets
            .into_iter()
            .map(|m| m.into_iter().collect())
            .collect();
        SparseKnnOracle { n, diag, cols }
    }

    /// Fraction of nonzero entries (including the diagonal).
    pub fn density(&self) -> f64 {
        let nnz: usize = self.cols.iter().map(|c| c.len()).sum::<usize>() + self.n;
        nnz as f64 / (self.n as f64 * self.n as f64)
    }
}

impl ColumnOracle for SparseKnnOracle {
    fn n(&self) -> usize {
        self.n
    }

    fn diag(&self) -> Vec<f64> {
        self.diag.clone()
    }

    fn column_into(&self, j: usize, out: &mut [f64]) {
        out.fill(0.0);
        out[j] = self.diag[j];
        for &(i, v) in &self.cols[j] {
            out[i as usize] = v;
        }
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        if i == j {
            return self.diag[j];
        }
        match self.cols[j].binary_search_by_key(&(i as u32), |e| e.0) {
            Ok(pos) => self.cols[j][pos].1,
            Err(_) => 0.0,
        }
    }

    /// Batched sparse fill: each thread owns a contiguous row range and
    /// walks every requested column's (sorted) nonzeros restricted to it,
    /// so no thread touches another's rows and each column list is
    /// scanned exactly once in total.
    fn columns_into(&self, js: &[usize], out: &mut Mat) {
        let n = self.n;
        let k = js.len();
        assert_eq!((out.rows, out.cols), (n, k));
        if k == 0 {
            return;
        }
        let diag = &self.diag;
        let cols = &self.cols;
        parallel::for_each_chunk_mut(
            &mut out.data,
            k,
            batch_threads(n, k),
            |range, chunk| {
                chunk.fill(0.0);
                for (t, &j) in js.iter().enumerate() {
                    if range.contains(&j) {
                        chunk[(j - range.start) * k + t] = diag[j];
                    }
                    let col = &cols[j];
                    let start =
                        col.partition_point(|e| (e.0 as usize) < range.start);
                    for &(i, v) in &col[start..] {
                        let i = i as usize;
                        if i >= range.end {
                            break;
                        }
                        chunk[(i - range.start) * k + t] = v;
                    }
                }
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::{kernel_matrix, Gaussian};

    #[test]
    fn explicit_and_implicit_agree() {
        let ds = two_moons(40, 0.05, 3);
        let kern = Gaussian::new(0.9);
        let g = kernel_matrix(&ds, &kern);
        let exp = ExplicitOracle::new(&g);
        let imp = ImplicitOracle::new(&ds, &kern);
        assert_eq!(exp.n(), imp.n());
        let de = exp.diag();
        let di = imp.diag();
        for j in [0usize, 13, 39] {
            assert!((de[j] - di[j]).abs() < 1e-14);
            let ce = exp.column(j);
            let ci = imp.column(j);
            for i in 0..40 {
                assert!((ce[i] - ci[i]).abs() < 1e-14);
                assert!((exp.entry(i, j) - imp.entry(i, j)).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn sparse_knn_is_symmetric() {
        let ds = two_moons(50, 0.05, 4);
        let kern = Gaussian::new(0.5);
        let o = SparseKnnOracle::build(&ds, &kern, 5);
        for i in 0..50 {
            for j in 0..50 {
                assert!(
                    (o.entry(i, j) - o.entry(j, i)).abs() < 1e-14,
                    "asymmetry at ({i},{j})"
                );
            }
        }
    }

    /// Every oracle's batched `columns_into` must agree bitwise with the
    /// one-column-at-a-time path (the default implementation), including
    /// duplicate and out-of-order index lists.
    #[test]
    fn batched_columns_match_single_column_path() {
        let ds = two_moons(70, 0.05, 6);
        let kern = Gaussian::new(0.6);
        let g = kernel_matrix(&ds, &kern);
        let exp = ExplicitOracle::new(&g);
        let imp = ImplicitOracle::new(&ds, &kern);
        let sparse = SparseKnnOracle::build(&ds, &kern, 6);
        let oracles: [&dyn ColumnOracle; 3] = [&exp, &imp, &sparse];
        let js = vec![3usize, 69, 0, 17, 17, 42];
        for oracle in oracles {
            let mut batched = crate::linalg::Mat::zeros(70, js.len());
            oracle.columns_into(&js, &mut batched);
            let mut col = vec![0.0; 70];
            for (t, &j) in js.iter().enumerate() {
                oracle.column_into(j, &mut col);
                for i in 0..70 {
                    assert_eq!(
                        batched.at(i, t),
                        col[i],
                        "mismatch at ({i}, {t}) for column {j}"
                    );
                }
            }
        }
    }

    /// The batched fill must also be exact on blocks large enough to take
    /// the threaded path (n·k ≥ the parallel cutoff).
    #[test]
    fn batched_columns_threaded_path_exact() {
        let ds = two_moons(600, 0.05, 8);
        let kern = Gaussian::new(0.5);
        let imp = ImplicitOracle::new(&ds, &kern);
        let sparse = SparseKnnOracle::build(&ds, &kern, 8);
        let js: Vec<usize> = (0..40).map(|t| (t * 13) % 600).collect();
        for oracle in [&imp as &dyn ColumnOracle, &sparse] {
            let mut batched = crate::linalg::Mat::zeros(600, js.len());
            oracle.columns_into(&js, &mut batched);
            let mut col = vec![0.0; 600];
            for (t, &j) in js.iter().enumerate() {
                oracle.column_into(j, &mut col);
                for i in 0..600 {
                    assert_eq!(batched.at(i, t), col[i]);
                }
            }
        }
    }

    #[test]
    fn sparse_knn_preserves_top_neighbors_and_zeros() {
        let ds = two_moons(60, 0.05, 5);
        let kern = Gaussian::new(0.4);
        let o = SparseKnnOracle::build(&ds, &kern, 4);
        let dense = ImplicitOracle::new(&ds, &kern);
        let col_s = o.column(7);
        let col_d = dense.column(7);
        // nonzeros match the dense kernel exactly
        for i in 0..60 {
            if col_s[i] != 0.0 {
                assert!((col_s[i] - col_d[i]).abs() < 1e-14);
            }
        }
        // sparsity actually happened
        assert!(o.density() < 0.5, "density {}", o.density());
        // diagonal kept
        assert_eq!(col_s[7], 1.0);
    }
}
